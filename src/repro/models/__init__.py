from repro.models.config import ModelConfig, SHAPES, ShapeSpec, shape_applicable
from repro.models.transformer import (
    cache_specs,
    decode_step,
    decoder_layout,
    forward,
    loss_fn,
    param_specs,
)
from repro.models.params import ParamSpec, abstract_params, init_params

__all__ = [
    "ModelConfig", "SHAPES", "ShapeSpec", "shape_applicable",
    "param_specs", "cache_specs", "forward", "decode_step", "loss_fn",
    "decoder_layout", "ParamSpec", "abstract_params", "init_params",
]
