"""Seeded, fully deterministic fault injector for the emulated ZNS fleet.

A :class:`FaultInjector` is consulted by the device submit paths — once per
submission attempt, inside the same critical section that lands the data
effect — and answers with a :class:`FaultDecision`: inject nothing, a
retryable media-error completion, a virtual-time latency spike, a torn
append, or a hung (never-completing) command.

Determinism is the whole point: decisions are **pure functions** of
``(seed, fault key, op, per-(key, op) sequence number)`` via a
splitmix64-style hash, NOT draws from shared mutable RNG state. Two runs
with the same seed and the same per-device submission order replay the
*identical* fault schedule even when reactor threads interleave
differently across devices — each (key, op) stream advances its own
counter, so cross-device thread races cannot perturb another device's
draws. The array fan-out submits member transfers under the array lock in
plan order, so per-device submission order is itself deterministic.

``force`` schedules an exact fault at an exact sequence number (tests and
the crash harness script precise scenarios); ``schedule_log`` returns the
ordered list of injected faults per (key, op) — the replay transcript the
determinism tests compare across runs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

__all__ = ["FaultSpec", "FaultDecision", "FaultInjector"]

_MASK = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer: avalanche one 64-bit lane."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return x ^ (x >> 31)


def _fold_str(s: str) -> int:
    """FNV-1a over a short string — stable across runs and processes
    (``hash()`` is salted per interpreter, so it would break replay)."""
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h = ((h ^ ch) * 0x100000001B3) & _MASK
    return h


def _u01(seed: int, key: int, op: str, seq: int, salt: int) -> float:
    """Uniform float in [0, 1) as a pure function of the draw coordinates."""
    h = _mix64(seed ^ _mix64(key ^ _mix64(_fold_str(op) ^ _mix64(seq ^ salt))))
    return (h >> 11) * (1.0 / (1 << 53))


# per-fault-class salts: independent draws per class, so e.g. raising the
# media-error rate never shifts which submissions hang
_SALT_HANG = 0x68616E67
_SALT_TORN = 0x746F726E
_SALT_MEDIA = 0x6D656469
_SALT_SPIKE = 0x7370696B
_SALT_TORN_KEEP = 0x6B656570
_SALT_JITTER = 0x6A697474


@dataclass(frozen=True)
class FaultSpec:
    """Per-key fault rates (probabilities per submission attempt) and
    magnitudes. All default to zero — an attached injector with a default
    spec is a no-op."""

    read_error_rate: float = 0.0      # retryable media error on reads
    append_error_rate: float = 0.0    # retryable media error on appends
    latency_spike_rate: float = 0.0   # extra service time on the zone clock
    latency_spike_s: float = 0.002
    hang_rate: float = 0.0            # command whose completion never arrives
    torn_append_rate: float = 0.0     # partial landing + non-retryable error


@dataclass(frozen=True)
class FaultDecision:
    """One submission attempt's verdict. ``kind`` is ``None`` (healthy),
    ``"media"``, ``"hang"``, or ``"torn"``; ``extra_latency_s`` adds to the
    attempt's emulated service time; ``torn_keep`` is the fraction of the
    payload that lands before a torn append fails."""

    kind: Optional[str] = None
    extra_latency_s: float = 0.0
    torn_keep: float = 0.5


_NO_FAULT = FaultDecision()


class FaultInjector:
    """Deterministic fault source shared by any number of devices.

    ``key`` identifies the fault stream a device draws from — use a stable
    identity (the member index in an array), not the process-global device
    ordinal, so schedules replay across runs that construct devices in
    different orders. ``spec`` is the default rate card; ``per_key`` maps
    specific keys to their own :class:`FaultSpec` (e.g. one sick member).
    """

    def __init__(self, seed: int, spec: Optional[FaultSpec] = None, *,
                 per_key: Optional[dict] = None):
        self.seed = int(seed) & _MASK
        self.spec = spec if spec is not None else FaultSpec()
        self.per_key = dict(per_key or {})
        self._lock = threading.Lock()
        self._seq: dict[tuple, int] = {}        # (key, op) -> next seq
        self._forced: dict[tuple, FaultDecision] = {}   # (key, op, seq)
        self._log: dict[tuple, list] = {}       # (key, op) -> [(seq, kind)]
        # per-kind injection totals (host-visible; devices also count their
        # own faults_injected)
        self.injected: dict[str, int] = {"media": 0, "hang": 0, "torn": 0,
                                         "latency": 0}

    # ----------------------------------------------------------- wiring
    def spec_for(self, key) -> FaultSpec:
        return self.per_key.get(key, self.spec)

    def attach(self, device, key=None, *, policy=None) -> None:
        """Point ``device``'s submit paths at this injector under fault
        stream ``key`` (defaults to the device's ordinal); optionally set
        its :class:`~repro.faults.retry.RetryPolicy` in the same breath."""
        device.fault_injector = self
        device.fault_key = key if key is not None else device.dev_ordinal
        if policy is not None:
            device.retry_policy = policy

    def attach_array(self, array, *, policy=None) -> None:
        """Attach every member of a striped array, keyed by member index —
        the stable identity that makes schedules replay across runs."""
        for i, d in enumerate(array.devices):
            self.attach(d, key=i, policy=policy)

    # --------------------------------------------------------- decisions
    def force(self, key, op: str, seq: int, kind: Optional[str], *,
              extra_latency_s: float = 0.0, torn_keep: float = 0.5) -> None:
        """Script an exact decision for the ``seq``-th ``op`` submission on
        ``key`` (0-based), overriding the hashed draw — precise scenarios
        for tests and the crash harness."""
        self._forced[(key, op, int(seq))] = FaultDecision(
            kind=kind, extra_latency_s=extra_latency_s, torn_keep=torn_keep)

    def decide(self, key, op: str, zone_id: int, nblocks: int, *,
               retry: bool = False) -> FaultDecision:
        """One submission attempt's fault verdict; advances the (key, op)
        sequence counter. ``retry=True`` marks a re-submission — a torn
        draw degrades to a plain media error there, because the original
        payload already landed in full (only the completion is re-run)."""
        with self._lock:
            sk = (key, op)
            seq = self._seq.get(sk, 0)
            self._seq[sk] = seq + 1
        d = self._forced.get((key, op, seq))
        if d is None:
            d = self._draw(key, op, seq)
        if d.kind == "torn" and (retry or op != "append" or nblocks < 2):
            # a tear needs >=2 blocks of fresh payload to be partial;
            # otherwise it is indistinguishable from a media error
            d = FaultDecision(kind="media",
                              extra_latency_s=d.extra_latency_s)
        if d.kind is not None or d.extra_latency_s:
            with self._lock:
                self._log.setdefault(sk, []).append(
                    (seq, d.kind or "latency"))
                self.injected[d.kind or "latency"] += 1
        return d

    def _draw(self, key, op: str, seq: int) -> FaultDecision:
        spec = self.spec_for(key)
        kseed = key if isinstance(key, int) else _fold_str(str(key))
        if spec.hang_rate and \
                _u01(self.seed, kseed, op, seq, _SALT_HANG) < spec.hang_rate:
            return FaultDecision(kind="hang")
        if op == "append" and spec.torn_append_rate and \
                _u01(self.seed, kseed, op, seq,
                     _SALT_TORN) < spec.torn_append_rate:
            keep = 0.25 + 0.5 * _u01(self.seed, kseed, op, seq,
                                     _SALT_TORN_KEEP)
            return FaultDecision(kind="torn", torn_keep=keep)
        rate = spec.read_error_rate if op == "read" else spec.append_error_rate
        if rate and _u01(self.seed, kseed, op, seq, _SALT_MEDIA) < rate:
            return FaultDecision(kind="media")
        if spec.latency_spike_rate and \
                _u01(self.seed, kseed, op, seq,
                     _SALT_SPIKE) < spec.latency_spike_rate:
            return FaultDecision(extra_latency_s=spec.latency_spike_s)
        return _NO_FAULT

    def jitter01(self, key, op: str) -> float:
        """Seeded uniform in [0, 1) for retry-backoff jitter; advances its
        own (key, op) counter, so jitter draws never perturb fault draws."""
        with self._lock:
            sk = (key, op, "jitter")
            seq = self._seq.get(sk, 0)
            self._seq[sk] = seq + 1
        kseed = key if isinstance(key, int) else _fold_str(str(key))
        return _u01(self.seed, kseed, op, seq, _SALT_JITTER)

    # ----------------------------------------------------------- reports
    def schedule_log(self) -> dict[tuple, list]:
        """Ordered injected-fault transcript: ``{(key, op): [(seq, kind),
        ...]}`` — byte-identical across two runs with the same seed and
        submission order (the determinism tests' witness)."""
        with self._lock:
            return {k: list(v) for k, v in self._log.items()}

    def __repr__(self) -> str:
        return (f"FaultInjector(seed={self.seed}, injected={self.injected})")
