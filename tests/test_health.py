"""Health telemetry: event log, SMART monitors, alert engine, instrumentation.

The fault-path sweep lives in ``benchmarks/bench_health.py`` (it needs a
live array under offload traffic); these tests pin the layer contracts —
bounded event-log memory, exact counts under get-or-create races, the
HEALTHY→SUSPECT→DEGRADED→OFFLINE state walk, edge-triggered alerting —
on private registries/logs so nothing leaks between tests.
"""
from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.telemetry.alerts import (AlertEngine, ErrorRateRule,
                                    HealthPromotionRule, TenantLatencySLORule)
from repro.telemetry.events import EventLog, Severity, event_log
from repro.telemetry.health import DeviceHealthMonitor, HealthStatus
from repro.telemetry.metrics import MetricsRegistry
from repro.zns import ZonedDevice
from repro.zns.device import ZoneStateError


# -------------------------------------------------------------- event log
class TestEventLog:
    def test_publish_filter_and_since_seq(self):
        log = EventLog()
        log.publish("zone.offline", severity=Severity.ERROR, zone=3)
        log.publish("zone.read_only", severity=Severity.WARNING)
        log.publish("health.status", severity=Severity.INFO)
        assert len(log.snapshot(name="zone")) == 2       # dotted prefix
        assert len(log.snapshot(name="zone.offline")) == 1
        assert len(log.snapshot(min_severity=Severity.ERROR)) == 1
        seq = log.snapshot(name="zone.read_only")[0].seq
        later = log.snapshot(since_seq=seq)
        assert [e.name for e in later] == ["health.status"]
        assert log.snapshot(name="zone.offline")[0].tags["zone"] == 3

    def test_bounded_memory_under_sustained_publishing(self, tmp_path):
        """The ring is a CQ: sustained publishing overwrites the oldest
        entries and counts the loss — memory never grows past capacity."""
        log = EventLog(capacity=256)
        n = 10_000
        for i in range(n):
            log.publish("flood", severity=Severity.DEBUG, i=i)
        assert len(log) == 256
        assert log.published == n
        assert log.dropped == n - 256
        tail = log.snapshot()
        # the survivors are exactly the newest 256, in order
        assert [e.tags["i"] for e in tail] == list(range(n - 256, n))
        path = tmp_path / "events.jsonl"
        assert log.export_jsonl(str(path)) == 256
        assert len(path.read_text().splitlines()) == 256

    def test_export_jsonl_round_trips(self, tmp_path):
        log = EventLog()
        log.publish("a.b", severity=Severity.WARNING, message="hi", k=1)
        path = tmp_path / "events.jsonl"
        assert log.export_jsonl(str(path)) == 1
        rec = json.loads(path.read_text())
        assert rec["name"] == "a.b"
        assert rec["severity"] == "WARNING"
        assert rec["tags"] == {"k": 1}

    def test_subscriber_errors_are_swallowed_and_unsubscribe_works(self):
        log = EventLog()
        seen: list[str] = []
        log.subscribe(lambda e: 1 / 0)           # must not break publish
        unsub = log.subscribe(lambda e: seen.append(e.name))
        log.publish("x", severity=Severity.INFO)
        unsub()
        log.publish("y", severity=Severity.INFO)
        assert seen == ["x"]

    def test_concurrent_publishers_exact_accounting(self):
        log = EventLog(capacity=128)
        n_threads, per_thread = 8, 2000
        start = threading.Barrier(n_threads)

        def work():
            start.wait()
            for _ in range(per_thread):
                log.publish("race", severity=Severity.DEBUG)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = n_threads * per_thread
        assert log.published == total
        assert log.dropped == total - 128
        assert len(log) == 128


# ----------------------------------------------------- tenant series races
class TestTenantSeriesRace:
    def test_get_or_create_same_histogram_exact_counts(self):
        """8 threads race the first touch of one ``tenant.*`` histogram:
        everyone must land on the SAME object and no observation may be
        lost — the property the per-tenant accounting path relies on."""
        reg = MetricsRegistry("race")
        n_threads, per_thread = 8, 5000
        start = threading.Barrier(n_threads)
        got: list = [None] * n_threads

        def work(i: int):
            start.wait()
            h = reg.histogram("tenant.alice.offload_latency_seconds")
            got[i] = h
            for j in range(per_thread):
                h.observe(1e-5 * (1 + j % 5))
                reg.counter("tenant.alice.ops").inc()

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(h is got[0] for h in got)
        assert got[0].count == n_threads * per_thread
        assert reg.counter("tenant.alice.ops").value == n_threads * per_thread


# ------------------------------------------------- device instrumentation
class TestDeviceHealthCounters:
    def test_zone_death_counts_and_publishes(self):
        log = event_log()
        seq0 = log.last_seq()
        dev = ZonedDevice(num_zones=2, zone_bytes=1 << 20, block_bytes=4096)
        dev.set_read_only(0)
        dev.set_offline(1)
        snap = dev.metrics.snapshot()
        assert snap["zone_readonly_transitions"] == 1
        assert snap["zone_offline_transitions"] == 1
        names = [e.name for e in log.snapshot(since_seq=seq0)]
        assert "zone.read_only" in names and "zone.offline" in names
        # idempotent kill: no double-count, no duplicate event
        dev.set_offline(1)
        assert dev.metrics.snapshot()["zone_offline_transitions"] == 1

    def test_read_errors_counter_moves_on_failed_read(self):
        dev = ZonedDevice(num_zones=1, zone_bytes=1 << 20, block_bytes=4096)
        dev.zone_append(0, np.arange(4096 // 4, dtype=np.int32))
        dev.set_offline(0)
        with pytest.raises(ZoneStateError):
            dev.read_blocks(0, 0, 1)
        assert dev.stats["read_errors"] == 1


# --------------------------------------------------------- health monitor
class _FakeState:
    def __init__(self, value: str):
        self.value = value


class _FakeZone:
    def __init__(self, state: str):
        self.state = _FakeState(state)


class _FakeDevice:
    """Duck-typed device: lets tests drive latency windows synthetically."""

    dev_ordinal = 99

    def __init__(self, n_zones: int = 2):
        self.metrics = MetricsRegistry("fake")
        self.states = ["empty"] * n_zones

    def report_zones(self):
        return [_FakeZone(s) for s in self.states]


class TestDeviceHealthMonitor:
    def test_zone_state_escalation_walk(self):
        log = EventLog()
        dev = ZonedDevice(num_zones=4, zone_bytes=1 << 20, block_bytes=4096)
        mon = DeviceHealthMonitor(dev, events=log, name="m0")
        assert mon.sample() is HealthStatus.HEALTHY
        dev.set_offline(0)                      # 1/4 offline: visibly wrong
        assert mon.sample() is HealthStatus.SUSPECT
        dev.set_offline(1)                      # 2/4 >= 0.5 fraction
        assert mon.sample() is HealthStatus.DEGRADED
        dev.set_offline(2)
        dev.set_offline(3)                      # all gone
        assert mon.sample() is HealthStatus.OFFLINE
        walk = [(e.tags["from_status"], e.tags["to_status"])
                for e in log.snapshot(name="health.status")]
        assert walk == [("HEALTHY", "SUSPECT"), ("SUSPECT", "DEGRADED"),
                        ("DEGRADED", "OFFLINE")]

    def test_latency_outlier_detection_and_recovery(self):
        log = EventLog()
        dev = _FakeDevice()
        mon = DeviceHealthMonitor(dev, events=log, name="m0",
                                  outlier_factor=4.0, min_baseline_windows=3,
                                  suspect_memory_windows=3)
        h = dev.metrics.histogram("read.service_seconds")
        for _ in range(3):                      # warm the EWMA baseline
            for _ in range(10):
                h.observe(1e-3)
            assert mon.sample() is HealthStatus.HEALTHY
        for _ in range(10):                     # a 100x-slower window
            h.observe(1e-1)
        assert mon.sample() is HealthStatus.SUSPECT
        assert mon.latency_outliers == 1
        assert log.snapshot(name="health.latency_outlier")
        # outlier windows are EXCLUDED from the baseline (a sick device
        # must not teach the monitor that sick is normal): a normal window
        # right after is not an outlier, and suspicion decays
        for _ in range(10):
            h.observe(1e-3)
        assert mon.sample() is HealthStatus.SUSPECT   # memory window
        mon.sample()
        assert mon.sample() is HealthStatus.HEALTHY
        assert mon.latency_outliers == 1

    def test_window_errors_mark_suspect_and_smart_log_shape(self):
        dev = _FakeDevice()
        mon = DeviceHealthMonitor(dev, events=EventLog(), name="m0")
        dev.metrics.counter("read_errors").inc()
        dev.metrics.counter("blocks_read").inc(1000)
        assert mon.sample() is HealthStatus.SUSPECT   # 1/1000 < 1% threshold
        smart = mon.smart_log()
        for key in ("status", "read_errors", "media_errors", "zones",
                    "zones_offline", "latency_outliers", "sample_windows"):
            assert key in smart, key
        assert smart["status"] == "SUSPECT"
        assert smart["read_errors"] == 1

    def test_error_rate_past_threshold_degrades(self):
        dev = _FakeDevice()
        mon = DeviceHealthMonitor(dev, events=EventLog(),
                                  error_rate_threshold=0.01)
        dev.metrics.counter("read_errors").inc(5)
        dev.metrics.counter("blocks_read").inc(100)   # 5% >= 1%
        assert mon.sample() is HealthStatus.DEGRADED

    def test_register_on_folds_smart_into_snapshot(self):
        reg = MetricsRegistry("global-ish")
        dev = _FakeDevice()
        mon = DeviceHealthMonitor(dev, events=EventLog(), name="m7")
        mon.register_on(reg)
        snap = reg.snapshot()
        assert snap["health.m7.status_code"] == 0
        assert snap["health.m7.read_errors"] == 0


# ------------------------------------------------------------ alert engine
class TestAlertEngine:
    def _engine(self, rules):
        reg = MetricsRegistry("alerts")
        log = EventLog()
        return AlertEngine(rules, metrics=reg, events=log), reg, log

    def test_error_rate_rule_edge_triggers_and_resolves(self):
        engine, reg, log = self._engine([ErrorRateRule()])
        c = reg.counter("read_errors")
        assert engine.evaluate() == []          # zero baseline: quiet
        c.inc(3)
        fired = engine.evaluate()
        assert [a.rule for a in fired] == ["error_rate"]
        assert engine.evaluate() == []          # still broken, no re-page
        resolved = log.snapshot(name="alert.resolved")
        assert len(resolved) == 1               # growth stopped: cleared
        c.inc()
        assert len(engine.evaluate()) == 1      # a NEW incident re-fires

    def test_tenant_slo_rule_fires_per_breaching_tenant_only(self):
        engine, reg, log = self._engine([TenantLatencySLORule(0.01)])
        reg.histogram("tenant.a.offload_latency_seconds").observe(0.2)
        reg.histogram("tenant.b.offload_latency_seconds").observe(0.001)
        reg.histogram("tenant.idle.offload_latency_seconds")  # no samples
        fired = engine.evaluate()
        assert [a.tags["tenant"] for a in fired] == ["a"]
        assert log.snapshot(name="alert.tenant_p99_slo")
        # empty histograms publish no p99 key, so the idle tenant can
        # never breach (the satellite contract the rule relies on)
        assert "tenant.idle.offload_latency_seconds.p99" not in reg.snapshot()

    def test_health_promotion_rule_drives_sampling_and_callbacks(self):
        log = EventLog()
        dev = ZonedDevice(num_zones=2, zone_bytes=1 << 20, block_bytes=4096)
        mon = DeviceHealthMonitor(dev, events=log, name="m0")
        engine = AlertEngine([HealthPromotionRule(mon)],
                             metrics=MetricsRegistry("x"), events=log)
        reactions: list = []
        engine.on_alert(reactions.append)
        assert engine.evaluate() == []
        dev.set_offline(0)                      # 1/2 >= 0.5: DEGRADED
        fired = engine.evaluate()
        assert [a.rule for a in fired] == ["member_degraded"]
        assert reactions and reactions[0].tags["status"] == "DEGRADED"
        assert mon.status is HealthStatus.DEGRADED   # rule drove sample()
        assert log.snapshot(name="alert.member_degraded")

    def test_broken_rule_does_not_stop_the_sweep(self):
        class Broken(ErrorRateRule):
            def check(self, ctx):
                raise RuntimeError("boom")

        engine, reg, _ = self._engine([Broken(), ErrorRateRule()])
        engine.evaluate()
        reg.counter("x_errors").inc()
        assert [a.rule for a in engine.evaluate()] == ["error_rate"]

    def test_broken_callback_does_not_wedge_evaluation(self):
        """A raising on_alert callback (e.g. a promotion handler hitting an
        exhausted spare pool) must be isolated like a raising rule: the
        other callbacks still run, evaluate() returns normally, and the
        incident still clears with ``alert.resolved`` later."""
        engine, reg, log = self._engine([ErrorRateRule()])
        seen: list = []

        def broken(alert):
            raise RuntimeError("promotion handler crashed")

        engine.on_alert(broken)
        engine.on_alert(seen.append)            # registered AFTER the bomb
        c = reg.counter("read_errors")
        engine.evaluate()                       # baseline
        c.inc(2)
        fired = engine.evaluate()               # must not raise
        assert [a.rule for a in fired] == ["error_rate"]
        assert len(seen) == 1                   # later callback still ran
        errs = log.snapshot(name="alert.callback_error")
        assert errs and errs[0].tags["rule"] == "error_rate"
        assert "broken" in errs[0].message
        # growth stopped: the incident must still resolve on the next sweep
        engine.evaluate()
        assert log.snapshot(name="alert.resolved")
        assert engine.active("error_rate")["error_rate"] == set()


# ------------------------------------------------------- queue event hooks
class TestQueueEvents:
    def test_sq_reject_publishes_event(self):
        from repro.array.queues import (OffloadCommand, QueueFullError,
                                        SubmissionQueue)
        log = event_log()
        seq0 = log.last_seq()
        sq = SubmissionQueue("t0", depth=1)

        def cmd():
            return OffloadCommand(program=None, zone_id=0, block_off=0,
                                  n_blocks=None, tier=None, tenant="t0")

        sq.submit(cmd())
        with pytest.raises(QueueFullError):
            sq.submit(cmd())
        rejects = log.snapshot(name="sq.reject", since_seq=seq0)
        assert rejects and rejects[0].tags["tenant"] == "t0"
