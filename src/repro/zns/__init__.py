"""Zoned Namespace (ZNS) storage substrate.

Software emulation of an NVMe ZNS device (host-memory or file backed), faithful
to the semantics the paper builds on: fixed-size zones, append-only writes at a
per-zone write pointer, explicit zone states (EMPTY/OPEN/FULL/READ_ONLY),
host-managed reset (garbage collection), and block-granular reads.
"""
from repro.zns.device import (
    Zone,
    ZoneState,
    ZonedDevice,
    ZNSError,
    ZoneFullError,
    ZoneStateError,
    OutOfBoundsError,
)

__all__ = [
    "Zone",
    "ZoneState",
    "ZonedDevice",
    "ZNSError",
    "ZoneFullError",
    "ZoneStateError",
    "OutOfBoundsError",
]
