"""Fault injection: the datapath rides through transient errors, loud in CI.

The retry/timeout machinery is only trustworthy if injected faults change
NOTHING observable but latency — same offload answers, same bytes, nobody
ejected — and if the operator-facing signals (retry counters, retry-storm
alert, crash-consistency sweep) actually fire. Every stage is a hard
tripwire (same posture as ``bench_health``/``bench_rebuild``):

  * **clean baseline** — an 8-member raid1 array serving offloads with no
    injector attached (the fast path: zero fault branches taken);
  * **1% / 5% transient media errors** — the same workload with seeded
    read-error injection and a bounded-retry policy: every offload result
    must equal the healthy answer, every zone must read back bit-identical,
    retries must have been absorbed (5% run), no member may leave the
    HEALTHY/SUSPECT band, and p99 must stay within a generous factor of the
    clean baseline (retries cost backoff, not correctness);
  * **retry storm** — a high-rate burst trips the default
    :func:`retry_storm_rule` through the :class:`AlertEngine` (the pager
    fires BEFORE any budget exhausts into ``read_errors``);
  * **crash sweep** — a :class:`PowerLossHarness` pass over a striped
    checkpoint workload: power loss between every pair of member append
    completions recovers to a committed checkpoint or refuses cleanly —
    never a torn restore.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.faults import FaultInjector, FaultSpec, RetryPolicy
from repro.faults.crash import PowerLossHarness
from repro.telemetry import (
    AlertEngine,
    ArrayHealthMonitor,
    HealthStatus,
    registry,
    retry_storm_rule,
)
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096
N_DEVICES = 8
SEED = 2112
# generous CI bound: backoff + retried transfers, not a hang or a storm
MAX_P99_FACTOR = 50.0
MAX_P99_FLOOR_S = 0.25


def _mk_array(num_zones: int, member_zone_bytes: int, *,
              read_us_per_block: float = 0.5) -> StripedZoneArray:
    devices = [ZonedDevice(num_zones=num_zones,
                           zone_bytes=member_zone_bytes, block_bytes=BLOCK,
                           read_us_per_block=read_us_per_block)
               for _ in range(N_DEVICES)]
    return StripedZoneArray(devices, stripe_blocks=64, redundancy="raid1")


def _workload(array: StripedZoneArray, program, expected, baseline,
              runs: int) -> list[float]:
    """Offload every zone ``runs`` times; assert answers and bytes match
    the healthy truth. Returns per-op wall seconds."""
    lat = []
    with OffloadScheduler(array) as sched:
        sched.register_tenant("bench")
        for _ in range(runs):
            for z in range(len(expected)):
                t0 = time.perf_counter()
                sched.nvm_cmd_bpf_run(program, z, tenant="bench")
                lat.append(time.perf_counter() - t0)
                got = int(sched.nvm_cmd_bpf_result())
                assert got == expected[z], (
                    f"offload under faults differs from healthy answer: "
                    f"zone {z} got {got} want {expected[z]}")
    for z in range(len(expected)):
        assert np.array_equal(array.read_zone(z), baseline[z]), \
            f"zone {z} not bit-identical under fault injection"
    return lat


def run_injected(*, data_mib: int = 8, runs: int = 3) -> dict:
    """Clean vs 1% vs 5% injected read-error rate on an 8-member raid1."""
    member_zone_bytes = max(64 * BLOCK,
                            data_mib * 1024 * 1024 // (N_DEVICES // 2))
    num_zones = 2
    rng = np.random.default_rng(0)
    program = filter_count("int32", "gt", RAND_MAX // 2)

    def build(rate: float):
        array = _mk_array(num_zones, member_zone_bytes)
        expected, baseline = [], []
        for z in range(num_zones):
            data = rng.integers(0, RAND_MAX,
                                array.zone_blocks * BLOCK // 8,
                                dtype=np.int32)   # half of each logical zone
            array.zone_append(z, data)
            expected.append(int((data > RAND_MAX // 2).sum()))
            baseline.append(array.read_zone(z).copy())
        injector = None
        if rate > 0:
            # fills above ran clean; only the offload reads see faults
            injector = FaultInjector(SEED, FaultSpec(read_error_rate=rate))
            injector.attach_array(array, policy=RetryPolicy(
                max_attempts=6, backoff_base_s=50e-6))
        return array, expected, baseline, injector

    out: dict = {}
    for label, rate in (("clean", 0.0), ("1pct", 0.01), ("5pct", 0.05)):
        array, expected, baseline, injector = build(rate)
        lat = _workload(array, program, expected, baseline, runs)
        stats = [d.stats for d in array.devices]
        res = {
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "ops": len(lat),
            "injected": sum(s["faults_injected"] for s in stats),
            "retries": sum(s["retries"] for s in stats),
            "timeouts": sum(s["io_timeouts"] for s in stats),
            "exhausted": sum(s["read_errors"] + s["append_errors"]
                             for s in stats),
        }
        if rate > 0:
            monitor = ArrayHealthMonitor(array)
            worst = max(m.sample() for m in monitor.members)
            assert worst <= HealthStatus.SUSPECT, (
                f"{label}: member left the serving band under transient "
                f"faults (worst={worst.name})")
            assert res["exhausted"] == 0, (
                f"{label}: {res['exhausted']} retry budget(s) exhausted — "
                f"a member would have been declared dead")
            assert sum(1 for z in range(num_zones)
                       if array.zone(z).state.value == "offline") == 0
            res["worst_health"] = worst.name
        if rate >= 0.05:
            assert res["injected"] > 0 and res["retries"] > 0, (
                f"{label}: injector armed but nothing injected/retried "
                f"({res['injected']}/{res['retries']}) — dead code?")
        out[label] = res
    bound = max(MAX_P99_FACTOR * out["clean"]["p99_s"], MAX_P99_FLOOR_S)
    for label in ("1pct", "5pct"):
        assert out[label]["p99_s"] <= bound, (
            f"{label}: offload p99 {out[label]['p99_s'] * 1e3:.1f}ms exceeds "
            f"{MAX_P99_FACTOR:g}x clean baseline "
            f"{out['clean']['p99_s'] * 1e3:.1f}ms")
    return out


def run_retry_storm() -> dict:
    """A high-rate transient burst pages through the retry-storm rule."""
    zone_bytes = 256 * BLOCK
    devices = [ZonedDevice(num_zones=2, zone_bytes=zone_bytes,
                           block_bytes=BLOCK) for _ in range(2)]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy="raid1")
    data = np.random.default_rng(2).integers(0, RAND_MAX, zone_bytes // 4,
                                             dtype=np.int32)
    array.zone_append(0, data)
    injector = FaultInjector(SEED, FaultSpec(read_error_rate=0.3))
    injector.attach_array(array, policy=RetryPolicy(max_attempts=10,
                                                    backoff_base_s=0.0))
    monitor = ArrayHealthMonitor(array)
    monitor.register_on(registry())
    engine = AlertEngine(rules=[retry_storm_rule()])
    assert not any(a.rule == "retry_storm" for a in engine.evaluate())

    t0 = time.perf_counter()
    for _ in range(20):
        array.read_blocks(0, 0, array.zone_blocks // 4)
    for m in monitor.members:
        m.sample()
    fired = engine.evaluate()
    elapsed = time.perf_counter() - t0
    retries = sum(d.stats["retries"] for d in array.devices)
    assert retries > 0, "30% injection produced zero retries"
    assert any(a.rule == "retry_storm" for a in fired), (
        f"retry-storm rule did not fire ({retries} retries absorbed; "
        f"fired={[(a.rule, a.key) for a in fired]})")
    return {"elapsed_s": elapsed, "retries": retries,
            "alerts": sum(1 for a in fired if a.rule == "retry_storm")}


def run_crash_sweep(*, stride: int = 1) -> dict:
    """Power loss at every member append-completion boundary of a striped
    checkpoint workload recovers clean (see repro.faults.crash)."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as td:
        h = PowerLossHarness(td, num_devices=4, num_zones=6,
                             member_zone_bytes=256 * 1024, stripe_blocks=4,
                             redundancy="raid1", stride=stride)
        trees = [(s, {"w": np.arange(700, dtype=np.float32) + s,
                      "b": np.full((41,), s, dtype=np.int32)})
                 for s in (1, 2, 3)]
        h.run(trees)                       # raises on any torn recovery
        summary = h.summary()
    assert summary["all_ok"] and summary["boundaries"] >= 2
    summary["elapsed_s"] = time.perf_counter() - t0
    return summary


def main(data_mib: int = 8, runs: int = 3, stride: int = 1) -> list[str]:
    rows = []
    inj = run_injected(data_mib=data_mib, runs=runs)
    for label in ("clean", "1pct", "5pct"):
        r = inj[label]
        rows.append(
            f"faults_{label},{r['p99_s'] * 1e6:.0f},"
            f"p50_us={r['p50_s'] * 1e6:.0f};ops={r['ops']};"
            f"injected={r['injected']};retries={r['retries']};"
            f"timeouts={r['timeouts']};exhausted={r['exhausted']}"
            + (f";worst_health={r['worst_health']}" if label != "clean"
               else ";bitwise=identical")
        )
    s = run_retry_storm()
    rows.append(
        f"faults_retry_storm,{s['elapsed_s'] * 1e6:.0f},"
        f"retries={s['retries']};alerts={s['alerts']};outcome=paged"
    )
    c = run_crash_sweep(stride=stride)
    rows.append(
        f"faults_crash_sweep,{c['elapsed_s'] * 1e6:.0f},"
        f"boundaries={c['boundaries']};journal={c['journal_len']};"
        f"restores={c['restores']};refusals={c['refusals']};"
        f"outcome=never_torn"
    )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
