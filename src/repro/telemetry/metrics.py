"""Metrics registry: counters, gauges, and fixed-bucket latency histograms.

The emulator's instrumentation grew as scattered ad-hoc ``stats`` dicts —
``ZonedDevice.stats``, ``ArrayOffloadStats``, checkpoint/pipeline counters —
each with its own shape and, worse, unlocked read-modify-write increments
racing under the reactor and gather threads. This module is the one
substrate they all migrate onto:

  * :class:`Counter` — monotonically increasing integer, atomic ``inc``
    (a private lock; Python's ``d[k] += n`` is NOT atomic across threads);
  * :class:`Gauge` — last-write-wins float (queue occupancy, ratios);
  * :class:`Histogram` — fixed log-spaced buckets with exact count/sum/
    min/max and interpolated p50/p95/p99 (the latency quantiles the
    multi-tenant QoS work reports per tenant);
  * :class:`MetricsRegistry` — a named namespace of the above with
    ``snapshot()`` / ``delta()`` semantics and a text ``dump()``. Collector
    callbacks fold externally-owned stats (compile cache, reactor) into the
    same snapshot so one call shows the whole offload picture.

Components that exist in unbounded numbers (devices, checkpoint stores) own
a PRIVATE registry (``obj.metrics``) and expose their legacy dict-shaped
``stats`` through :class:`StatsView` — the dict API stays source-compatible
while every increment becomes atomic. Process-wide singletons (the reactor,
the gather pool, the per-tenant queues, the shared compile cache) publish to
the global :func:`registry`.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Callable, Iterable, Iterator, MutableMapping, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "registry",
    "default_latency_buckets",
]


class Counter:
    """Monotonic integer counter with atomic increments.

    ``set`` exists only for the legacy dict API (tests zero device counters
    with ``dev.stats["blocks_read"] = 0``); new code should only ``inc``.
    """

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, value: int) -> None:
        with self._lock:
            self._value = int(value)

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Gauge:
    """Last-write-wins scalar (occupancy, depth, a ratio)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        self._value = float(value)

    def max(self, value: float) -> None:
        """Raise the gauge to ``value`` if it is higher (high-water marks)."""
        with self._lock:
            if value > self._value:
                self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self._value})"


def default_latency_buckets() -> tuple[float, ...]:
    """Log-spaced seconds boundaries, 1 µs .. ~67 s at ratio 2 — one decade
    of relative error per bucket is plenty for p50/p95/p99 of emulated I/O."""
    return tuple(1e-6 * 2.0 ** i for i in range(27))


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    Buckets are upper-bound boundaries (values land in the first bucket whose
    bound is >= value; an overflow bucket catches the rest). Exact ``count``,
    ``sum``, ``min``, ``max`` are kept alongside, so means are exact and
    quantiles are only as coarse as the bucket geometry. ``observe`` takes
    one lock — cheap enough for the emulated-I/O hot path, and exact under
    the reactor/gather/dispatcher thread mix (asserted by the telemetry
    concurrency stress test).
    """

    __slots__ = ("name", "bounds", "_counts", "_overflow", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, buckets: Optional[Iterable[float]] = None):
        self.name = name
        self.bounds = tuple(sorted(buckets)) if buckets is not None \
            else default_latency_buckets()
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * len(self.bounds)
        self._overflow = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            if i < len(self.bounds):
                self._counts[i] += 1
            else:
                self._overflow += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Linear-interpolated quantile estimate (``q`` in [0, 100]).

        Within the target bucket the mass is assumed uniform between the
        bucket's bounds (clamped to the observed min/max), so the error is
        bounded by the bucket width at that value.
        """
        with self._lock:
            count = self._count
            if count == 0:
                return 0.0
            rank = q / 100.0 * count
            seen = 0.0
            lo = max(self._min, 0.0) if self._min != math.inf else 0.0
            for bound, c in zip(self.bounds, self._counts):
                if c:
                    hi = min(bound, self._max)
                    blo = max(lo, self._min)
                    if seen + c >= rank:
                        frac = min(max((rank - seen) / c, 0.0), 1.0)
                        return blo + (hi - blo) * frac if hi > blo else hi
                    seen += c
                lo = bound
            # overflow bucket: interpolate toward the observed max
            c = self._overflow
            if c:
                blo = max(lo, self._min)
                hi = self._max
                frac = min(max((rank - seen) / c, 0.0), 1.0)
                return blo + (hi - blo) * frac if hi > blo else hi
            return self._max

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self._count, self._sum
            mn = self._min if self._count else 0.0
            mx = self._max if self._count else 0.0
        out = {
            "count": count,
            "sum": total,
            "mean": total / count if count else 0.0,
            "min": mn,
            "max": mx,
        }
        if count:
            # no quantile keys for an empty histogram: a fabricated p99 of
            # 0.0 reads as "perfect latency" to SLO rules and dashboards,
            # the opposite of "no data" — absent keys make idle series
            # unambiguous (and keep idle tenants from ever paging)
            out.update(p50=self.percentile(50), p95=self.percentile(95),
                       p99=self.percentile(99))
        return out

    def __repr__(self) -> str:
        return f"Histogram({self.name} n={self._count} mean={self.mean:.3g})"


class StatsView(MutableMapping):
    """Dict-shaped view over named :class:`Counter` objects.

    Source-compatible stand-in for the old ad-hoc ``stats`` dicts:
    ``stats["k"]`` reads the counter, ``stats["k"] = v`` resets it (a
    test-suite idiom), ``items()``/iteration/``len`` work, and extra
    key/value pairs (computed aggregates like the array's
    ``degraded_reads``) can be layered on. The OWNING component must
    increment through the counters (``c.inc(n)``), never through this view —
    that is what makes the increments atomic.
    """

    def __init__(self, counters: dict[str, Counter]):
        self._counters = dict(counters)

    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self._counters[key].set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("stats keys are fixed at construction")

    def __iter__(self) -> Iterator[str]:
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def __repr__(self) -> str:
        return repr(dict(self))


class MetricsRegistry:
    """A named namespace of metrics with snapshot/delta semantics.

    ``counter``/``gauge``/``histogram`` get-or-create by name (with a type
    check, so one name cannot be two kinds of metric). ``snapshot()``
    flattens everything into one ``{name: value}`` dict — histograms expand
    to ``name.count``/``.sum``/``.mean``/``.min``/``.max``/``.p50``/``.p95``/
    ``.p99`` — and folds in every registered collector. ``delta(old)``
    subtracts a previous snapshot's cumulative values (counters, histogram
    counts/sums) while keeping point-in-time values (gauges, quantiles)
    as-is, which is what benchmarks want for a measurement window.
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}
        self._collectors: dict[str, Callable[[], dict]] = {}

    # ------------------------------------------------------------- creation
    def _get_or_create(self, name: str, kind: type, factory: Callable):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str,
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        return self._get_or_create(name, Histogram,
                                   lambda: Histogram(name, buckets))

    def register_collector(self, name: str, fn: Callable[[], dict]) -> None:
        """``fn()`` returns ``{suffix: number}`` folded into ``snapshot()``
        under ``name.suffix`` — for stats owned elsewhere (compile cache,
        reactor) that should appear in the same picture. Re-registering a
        name replaces the collector (idempotent wiring)."""
        with self._lock:
            self._collectors[name] = fn

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> dict:
        with self._lock:
            metrics = list(self._metrics.items())
            collectors = list(self._collectors.items())
        out: dict[str, float] = {}
        for name, m in metrics:
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            else:
                for k, v in m.snapshot().items():
                    out[f"{name}.{k}"] = v
        for name, fn in collectors:
            try:
                for k, v in fn().items():
                    out[f"{name}.{k}"] = v
            except Exception:
                pass  # a dead collector must not poison the snapshot
        return out

    _CUMULATIVE_SUFFIXES = (".count", ".sum")

    def delta(self, old: dict, new: Optional[dict] = None) -> dict:
        """Subtract cumulative values in ``old`` from ``new`` (default: a
        fresh snapshot). Counters and histogram ``.count``/``.sum`` subtract;
        gauges/quantiles/min/max pass through as point-in-time values."""
        if new is None:
            new = self.snapshot()
        out = dict(new)
        for k, v in old.items():
            if k not in out or not isinstance(v, (int, float)):
                continue
            if isinstance(out[k], int) or k.endswith(self._CUMULATIVE_SUFFIXES):
                out[k] = out[k] - v
        return out

    def dump(self) -> str:
        """Human-readable metrics table, sorted by name."""
        snap = self.snapshot()
        width = max((len(k) for k in snap), default=0)
        lines = [f"# metrics{' ' + self.name if self.name else ''} "
                 f"({len(snap)} series)"]
        for k in sorted(snap):
            v = snap[k]
            sv = f"{v:d}" if isinstance(v, int) else f"{v:.6g}"
            lines.append(f"{k:<{width}}  {sv}")
        return "\n".join(lines)

    def reset(self) -> None:
        """Drop every metric and collector (tests / benchmark isolation on
        the global registry)."""
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()


_global = MetricsRegistry("global")


def registry() -> MetricsRegistry:
    """The process-wide registry: reactor, gather pool, per-tenant queues,
    scheduler phase timings, and the shared compile cache publish here, so
    one ``registry().snapshot()`` shows the whole offload picture."""
    return _global
