"""Static verifier for offload programs.

Mirrors the role of the Linux eBPF verifier in the paper's stack: before a
program is admitted to the device, prove

  1. **bounded execution** — the program is a linear (jump-free) instruction
     sequence, so the dynamic instruction count is exactly
     ``n_insns × n_pages``; we enforce a device instruction budget on it
     (the kernel eBPF analogue of the 1M-insn complexity limit);
  2. **memory safety** — every zone access the program can make is inside
     the zone's *written* extent (reads beyond the write pointer are ZNS
     protocol errors); SELECT results are capacity-bounded so the return
     buffer cannot overflow;
  3. **type safety** — dtypes supported, int-only bitwise ops not applied to
     floats, immediates representable in the stream dtype, histogram/select
     parameters sane;
  4. **structural safety** — exactly one terminal instruction, in final
     position; FIELD projection (if any) first, with a stride that divides
     the page's element count so record boundaries never straddle pages.

A rejected program never reaches any execution tier — the same contract the
paper relies on for safe multi-tenant CSDs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.programs import (
    ALU_OPS,
    CMP_OPS,
    INT_ONLY_OPS,
    NO_IMM_OPS,
    SUPPORTED_DTYPES,
    TERMINAL_OPS,
    Instruction,
    OpCode,
    Program,
)

__all__ = ["VerifyError", "VerifierLimits", "verify_program"]

MAX_INSNS = 4096                 # static program size limit (kernel eBPF parity)
MAX_DYNAMIC_INSNS = 1 << 33      # dynamic budget: n_insns * n_pages
MAX_HIST_BINS = 65536
MAX_SELECT_CAPACITY = 1 << 28


class VerifyError(Exception):
    """Program rejected by the verifier."""


@dataclass(frozen=True)
class VerifierLimits:
    max_insns: int = MAX_INSNS
    max_dynamic_insns: int = MAX_DYNAMIC_INSNS
    max_hist_bins: int = MAX_HIST_BINS
    max_select_capacity: int = MAX_SELECT_CAPACITY


def _check_imm_fits(imm, dtype: np.dtype, insn: Instruction) -> None:
    if np.issubdtype(dtype, np.integer):
        if not isinstance(imm, (int, np.integer)):
            raise VerifyError(f"{insn}: immediate {imm!r} not an integer for {dtype}")
        info = np.iinfo(dtype)
        if not info.min <= int(imm) <= info.max:
            raise VerifyError(f"{insn}: immediate {imm} out of {dtype} range")
    else:
        if not isinstance(imm, (int, float, np.integer, np.floating)):
            raise VerifyError(f"{insn}: immediate {imm!r} not numeric")


def verify_program(
    program: Program,
    *,
    page_elems: int,
    n_pages: int,
    limits: VerifierLimits = VerifierLimits(),
) -> int:
    """Verify ``program`` against a zone of ``n_pages`` pages of
    ``page_elems`` elements each. Returns the proven dynamic instruction
    bound (the number the device's stats report as ``insns_verified``).

    Raises :class:`VerifyError` on any violation.
    """
    if program.input_dtype not in SUPPORTED_DTYPES:
        raise VerifyError(f"unsupported dtype {program.input_dtype!r}")
    dtype = np.dtype(program.input_dtype)

    if not program.insns:
        raise VerifyError("empty program")
    if program.n_insns > limits.max_insns:
        raise VerifyError(f"program too long: {program.n_insns} > {limits.max_insns}")

    # (1) bounded execution: linear programs execute n_insns per page.
    dyn = program.n_insns * max(n_pages, 1)
    if dyn > limits.max_dynamic_insns:
        raise VerifyError(
            f"dynamic instruction bound {dyn} exceeds budget {limits.max_dynamic_insns}"
        )

    # (4) structure: one terminal, last; FIELD first.
    for i, insn in enumerate(program.insns):
        is_last = i == program.n_insns - 1
        if insn.op in TERMINAL_OPS and not is_last:
            raise VerifyError(f"terminal {insn} at position {i} is not last")
        if is_last and insn.op not in TERMINAL_OPS:
            raise VerifyError(f"last instruction {insn} is not a terminal")
        if insn.op == OpCode.FIELD and i != 0:
            raise VerifyError("FIELD projection must be the first instruction")

    stream_dtype = dtype
    for insn in program.insns:
        op = insn.op
        if op in NO_IMM_OPS:
            if insn.imm is not None:
                raise VerifyError(f"{insn}: op takes no immediate")
            continue
        if op == OpCode.FIELD:
            if (not isinstance(insn.imm, tuple)) or len(insn.imm) != 2:
                raise VerifyError(f"{insn}: FIELD imm must be (stride, index)")
            stride, index = insn.imm
            if not (isinstance(stride, int) and isinstance(index, int)):
                raise VerifyError(f"{insn}: FIELD stride/index must be ints")
            if stride <= 0 or not 0 <= index < stride:
                raise VerifyError(f"{insn}: invalid FIELD (stride={stride}, index={index})")
            if page_elems % stride != 0:
                raise VerifyError(
                    f"{insn}: record stride {stride} does not divide page "
                    f"element count {page_elems} (records would straddle pages)"
                )
            continue
        if op in ALU_OPS or op in CMP_OPS:
            if op in INT_ONLY_OPS and not np.issubdtype(stream_dtype, np.integer):
                raise VerifyError(f"{insn}: bitwise op on non-integer stream {stream_dtype}")
            if op in (OpCode.SHL, OpCode.SHR):
                if not isinstance(insn.imm, (int, np.integer)) or not 0 <= insn.imm < 64:
                    raise VerifyError(f"{insn}: shift amount must be in [0, 64)")
                continue
            if op in (OpCode.MOD,) and (insn.imm == 0):
                raise VerifyError(f"{insn}: modulo by zero")
            _check_imm_fits(insn.imm, stream_dtype, insn)
            continue
        if op == OpCode.RED_HIST:
            if (not isinstance(insn.imm, tuple)) or len(insn.imm) != 3:
                raise VerifyError(f"{insn}: RED_HIST imm must be (lo, hi, bins)")
            lo, hi, bins = insn.imm
            if not isinstance(bins, int) or not 1 <= bins <= limits.max_hist_bins:
                raise VerifyError(f"{insn}: bins {bins} out of [1,{limits.max_hist_bins}]")
            if not lo < hi:
                raise VerifyError(f"{insn}: empty histogram range [{lo},{hi})")
            continue
        if op in (OpCode.SELECT, OpCode.SELECT_REC):
            cap = program.select_capacity
            if cap is None:
                raise VerifyError(f"{op.value} requires select_capacity")
            if not isinstance(cap, int) or not 1 <= cap <= limits.max_select_capacity:
                raise VerifyError(f"select_capacity {cap} out of bounds")
            if op == OpCode.SELECT_REC and program.insns[0].op != OpCode.FIELD:
                raise VerifyError(
                    "SELECT_REC requires a FIELD projection to define records")
            continue
        raise VerifyError(f"unknown instruction {insn}")

    return dyn


def verify_zone_access(
    *, zone_write_pointer: int, block_off: int, n_blocks: int
) -> None:
    """(2) memory safety of the requested zone extent — rejected at attach
    time so no execution tier can read unwritten/out-of-zone blocks."""
    if block_off < 0 or n_blocks <= 0:
        raise VerifyError(f"invalid zone extent [{block_off}, +{n_blocks})")
    if block_off + n_blocks > zone_write_pointer:
        raise VerifyError(
            f"extent [{block_off},{block_off + n_blocks}) exceeds zone write "
            f"pointer {zone_write_pointer}"
        )
