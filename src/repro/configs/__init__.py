"""Assigned architecture registry: ``get_config("<arch-id>")``.

Each module defines ``CONFIG`` (the exact published configuration) and
``reduced()`` (a family-preserving shrink for CPU smoke tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeSpec, shape_applicable

_MODULES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "starcoder2-3b": "starcoder2_3b",
    "granite-8b": "granite_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-780m": "mamba2_780m",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()


__all__ = ["ARCH_IDS", "get_config", "get_reduced", "SHAPES", "ShapeSpec",
           "shape_applicable", "ModelConfig"]
