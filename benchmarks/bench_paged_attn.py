"""Zoned-KV paged decode attention: Pallas kernel (interpret) vs jnp reference.

On CPU the interpret-mode wall time is NOT TPU-representative; the benchmark
exists to (a) pin functional parity at serving-realistic shapes and (b) track
the kernel's VMEM working set (one zone block) vs the reference's full-cache
materialization."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attn.ops import paged_attention
from repro.kernels.paged_attn.ref import paged_attention_ref


def main() -> list[str]:
    rows = []
    B, H, KV, hd = 4, 8, 2, 64
    NZ, ZL, MZ = 16, 64, 6
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((NZ, ZL, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((NZ, ZL, KV, hd)), jnp.float32)
    ztab = np.full((B, MZ), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    for b in range(B):
        nz = rng.integers(1, MZ + 1)
        ztab[b, :nz] = rng.choice(NZ, nz, replace=False)
        lengths[b] = rng.integers(1, nz * ZL + 1)
    ztab, lengths = jnp.asarray(ztab), jnp.asarray(lengths)

    ref = jax.jit(paged_attention_ref)
    out_ref = ref(q, k, v, ztab, lengths)
    t = time.perf_counter()
    for _ in range(10):
        ref(q, k, v, ztab, lengths)[0].block_until_ready()
    ref_us = (time.perf_counter() - t) / 10 * 1e6

    out_k = paged_attention(q, k, v, ztab, lengths)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)
    t = time.perf_counter()
    for _ in range(3):
        paged_attention(q, k, v, ztab, lengths).block_until_ready()
    kern_us = (time.perf_counter() - t) / 3 * 1e6

    vmem_block = ZL * KV * hd * 4 * 2
    full_cache = B * MZ * ZL * KV * hd * 4 * 2
    rows.append(f"paged_attn_ref,{ref_us:.0f},full_cache_kb={full_cache // 1024}")
    rows.append(f"paged_attn_pallas_interp,{kern_us:.0f},"
                f"vmem_block_kb={vmem_block // 1024};parity=ok")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
