"""Unified observability for the emulator: span tracing + metrics.

Two halves, one import point:

  * :mod:`repro.telemetry.trace` — lock-light span recorder on wall AND
    reactor virtual time, exportable as Chrome ``trace_event`` JSON
    (Perfetto-loadable). Off by default; ``trace.set_enabled(True)`` or the
    ``tracing()`` context manager turn it on.
  * :mod:`repro.telemetry.metrics` — counters/gauges/histograms with
    snapshot/delta semantics. The global :func:`metrics.registry` aggregates
    process-wide components (reactor, gather pool, tenant queues, compile
    caches); per-instance components expose ``obj.metrics``.
"""
from . import metrics, trace
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, StatsView,
                      registry)
from .trace import span, instant, event_complete, tracing, set_enabled

__all__ = [
    "metrics",
    "trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "registry",
    "span",
    "instant",
    "event_complete",
    "tracing",
    "set_enabled",
]
