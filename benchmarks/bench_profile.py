"""Traced array fan-out profile: name the serialization point, don't guess.

The original thread-per-member fan-out stopped scaling past 2 devices
(675 -> 1153 -> 979 -> 760 MiB/s at 1/2/4/8) and this profile named the
culprit: ``worker.compute`` — N GIL-contending per-worker JAX dispatches —
blew up ~60x on the straggler's critical path. The ISSUE-10 pipeline
(read stage -> ONE array-wide batched dispatch -> gather-pool combine)
removed that axis entirely, and this profile now attributes the staged
offload wall clock so any NEW serialization point gets a name:

  * per width, every ``offload.execute`` span is decomposed into its
    sequential dispatcher phases (``offload.plan`` / ``offload.stage.read``
    / ``offload.stage.compute`` / ``offload.stage.combine`` — asserted to
    cover >= 90% of the measured wall, so the attribution is honest, not
    vibes);
  * inside the compute phase, the dispatcher-side children split the story:
    ``stage.read_wait`` (blocked on ring completions + staging memcpys —
    the number that grows if the pipeline serializes on I/O),
    ``stage.dispatch`` (the single batched compiled call per group) and
    ``stage.serve_chunk`` (individually re-served tail/degraded chunks);
    ``offload.stage.combine`` is the rendezvous with the gather-pool
    combiner, which absorbs the trailing group's XLA materialization;
  * the dominant serialization point is the largest critical-path component
    that FAILED to shrink with width (seconds at max width >= half its
    1-device seconds) — reported by name in the diagnosis row, which the
    refactor must keep AWAY from the old per-worker-compute shape;
  * a tracing-overhead tripwire measures the DISABLED-path primitive costs
    (no-op span, counter inc, histogram observe, enabled check) and asserts
    the per-offload instrumentation budget stays under 3% of a measured
    single-device offload — the "observability must not slow the hot path"
    contract, enforced in bench-smoke.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import CsdTier, NvmCsd, filter_count
from repro.telemetry import trace as _trace
from repro.telemetry.metrics import MetricsRegistry, registry as _registry
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096

# phase coverage the attribution must reach before we trust the diagnosis
MIN_ATTRIBUTION = 0.90
# disabled-tracing overhead budget on the single-device offload row
MAX_DISABLED_OVERHEAD = 0.03

# critical-path components that can be "the serialization point" (everything
# dispatcher-serial plus the staged read wait itself — if stage.read_wait
# still dominates at max width the reads are NOT overlapping and that IS
# the finding)
_CP_COMPONENTS = ("stage.read_wait", "stage.dispatch", "stage.serve_chunk",
                  "offload.plan", "offload.stage.read",
                  "offload.stage.combine")


def _spans(events: list[dict], name: str) -> list[dict]:
    return [e for e in events if e["type"] == "span" and e["name"] == name]


def _children(events: list[dict], parent: dict, name: str,
              same_tid: bool = False) -> list[dict]:
    lo = parent["ts"] - 1e-9
    hi = parent["ts"] + parent["dur"] + 1e-6
    out = []
    for e in _spans(events, name):
        if e["ts"] >= lo and e["ts"] + e["dur"] <= hi:
            if same_tid and e["tid"] != parent["tid"]:
                continue
            out.append(e)
    return out


def _critical_path(events: list[dict], execute: dict) -> dict:
    """Decompose ONE offload.execute span into named critical-path seconds.

    plan / stage.read / stage.compute / stage.combine are the sequential
    phases of the ONE dispatcher thread (the pipeline has no per-member
    workers to straggle); inside the compute phase its read_wait / staging
    / dispatch / serve_chunk children split the time. The residuals get
    their own names (compute.other, execute.other) so every second of the
    wall is accounted somewhere."""
    cp = {c: 0.0 for c in _CP_COMPONENTS}
    cp.update({"stage.staging": 0.0, "compute.other": 0.0,
               "execute.other": 0.0})
    plan = sum(e["dur"] for e in _children(events, execute, "offload.plan"))
    read = sum(e["dur"]
               for e in _children(events, execute, "offload.stage.read"))
    combine = sum(e["dur"] for e in
                  _children(events, execute, "offload.stage.combine"))
    computes = _children(events, execute, "offload.stage.compute")
    compute = sum(e["dur"] for e in computes)
    cp["offload.plan"] = plan
    cp["offload.stage.read"] = read
    cp["offload.stage.combine"] = combine
    inner = 0.0
    for ph in computes:
        for nm in ("stage.read_wait", "stage.staging", "stage.dispatch",
                   "stage.serve_chunk"):
            s = sum(e["dur"] for e in
                    _children(events, ph, nm, same_tid=True))
            cp[nm] += s
            inner += s
    cp["compute.other"] = max(compute - inner, 0.0)
    cp["execute.other"] = max(
        execute["dur"] - plan - read - compute - combine, 0.0)
    cp["_phase_coverage"] = (plan + read + compute + combine) \
        / execute["dur"] if execute["dur"] > 0 else 1.0
    return cp


def run_profile(
    *,
    widths: tuple[int, ...] = (1, 2, 4, 8),
    data_mib: int = 16,
    stripe_blocks: int = 64,
    read_us_per_block: float = 16.0,
    runs: int = 3,
    seed: int = 0,
) -> list[dict]:
    """bench_array's fan-out, re-run under tracing, with per-component
    wall-time attribution per width."""
    data_bytes = data_mib * 1024 * 1024
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)

    out: list[dict] = []
    for n in widths:
        devices = [
            ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=BLOCK,
                        read_us_per_block=read_us_per_block)
            for _ in range(n)
        ]
        with StripedZoneArray(devices, stripe_blocks=stripe_blocks) as array:
            array.zone_append(0, data)
            with OffloadScheduler(array) as sched:
                sched.nvm_cmd_bpf_run(program, 0)   # warm-up pays the JIT
                gather0 = _registry().snapshot()
                _trace.clear()
                times = []
                with _trace.tracing(True):
                    for _ in range(runs):
                        t = time.perf_counter()
                        sched.nvm_cmd_bpf_run(program, 0)
                        times.append(time.perf_counter() - t)
                assert int(sched.nvm_cmd_bpf_result()) == expected
                events = _trace.drain()
                gather_delta = _registry().delta(gather0)
            dev_read_s = sum(
                d.metrics.snapshot().get("read.service_seconds.sum", 0.0)
                for d in devices)

        executes = _spans(events, "offload.execute")
        assert len(executes) >= runs, (
            f"traced {len(executes)} offload.execute spans for {runs} runs — "
            "tracing lost the measured offloads")
        # take the LAST `runs` executes (warm-up ran before clear(), but be
        # defensive about any stray command)
        executes = sorted(executes, key=lambda e: e["ts"])[-runs:]
        agg: dict[str, float] = {}
        coverage = []
        for ex in executes:
            cp = _critical_path(events, ex)
            coverage.append(cp.pop("_phase_coverage"))
            for k, v in cp.items():
                agg[k] = agg.get(k, 0.0) + v
        execute_wall = sum(e["dur"] for e in executes)
        attributed = min(coverage)
        assert attributed >= MIN_ATTRIBUTION, (
            f"phase attribution covers only {attributed:.0%} of the "
            f"{n}-device offload wall (need >= {MIN_ATTRIBUTION:.0%}) — "
            "a phase span went missing")
        seconds = float(np.mean(times))
        out.append({
            "devices": n,
            "seconds": seconds,
            "mib_per_s": data_mib / seconds,
            "execute_wall_seconds": execute_wall,
            "attributed": attributed,
            "critical_path_seconds": {k: round(v, 6)
                                      for k, v in agg.items()},
            "dev_read_service_seconds": dev_read_s,
            "gather_queue_wait_seconds":
                gather_delta.get("gather.queue_wait_seconds.sum", 0.0),
            "trace_events": len(events),
            "trace_dropped": _trace.dropped(),
        })
        _trace.clear()
    return out


def diagnose(results: list[dict]) -> dict:
    """Name the dominant serialization point: the largest critical-path
    component at max width that failed to shrink with the device count."""
    first, last = results[0], results[-1]
    cp1 = first["critical_path_seconds"]
    cpN = last["critical_path_seconds"]
    candidates = {}
    for c in _CP_COMPONENTS:
        s1, sN = cp1.get(c, 0.0), cpN.get(c, 0.0)
        scaling = sN / s1 if s1 > 0 else float("inf") if sN > 0 else 0.0
        candidates[c] = {"w1_seconds": s1, "wmax_seconds": sN,
                         "scaling": scaling}
    non_scaling = {c: v for c, v in candidates.items()
                   if v["wmax_seconds"] > 0 and v["scaling"] >= 0.5}
    pool = non_scaling or candidates
    top = max(pool, key=lambda c: pool[c]["wmax_seconds"])
    return {"top_serialization_point": top,
            "widths": (first["devices"], last["devices"]),
            "components": candidates}


def measure_overhead(data_mib: int = 4, runs: int = 3) -> dict:
    """Disabled-path instrumentation budget vs a measured offload.

    There is no uninstrumented build to diff against, so the tripwire is a
    deterministic primitive-cost bound: time each disabled primitive (no-op
    span, counter inc, histogram observe, enabled check), charge the hot
    path DOUBLE its actual per-offload primitive count as safety margin,
    and require the total under 3% of a real single-device offload."""
    assert not _trace.enabled()
    n = 200_000

    t0 = time.perf_counter()
    for _ in range(n):
        with _trace.span("ovh"):
            pass
    span_s = (time.perf_counter() - t0) / n

    t0 = time.perf_counter()
    for _ in range(n):
        _trace.enabled()
    enabled_s = (time.perf_counter() - t0) / n

    reg = MetricsRegistry("bench_overhead")
    c = reg.counter("c")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    inc_s = (time.perf_counter() - t0) / n

    h = reg.histogram("h")
    t0 = time.perf_counter()
    for _ in range(n):
        h.observe(1e-4)
    observe_s = (time.perf_counter() - t0) / n

    # single-device JIT offload per call: 2 tier spans, 2 device histogram
    # observes, 2 counter incs, 1 enabled check — charged at 2x
    per_offload = 2 * (2 * span_s + 2 * observe_s + 2 * inc_s + enabled_s)

    data_bytes = data_mib * 1024 * 1024
    dev = ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=BLOCK)
    rng = np.random.default_rng(0)
    dev.zone_append(0, rng.integers(0, RAND_MAX, data_bytes // 4,
                                    dtype=np.int32))
    csd = NvmCsd(dev)
    program = filter_count("int32", "gt", RAND_MAX // 2)
    csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)   # warm-up
    times = []
    for _ in range(runs):
        t = time.perf_counter()
        csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        times.append(time.perf_counter() - t)
    read_row_s = float(np.mean(times))
    ratio = per_offload / read_row_s
    assert ratio < MAX_DISABLED_OVERHEAD, (
        f"disabled-tracing overhead {ratio:.2%} of the read row exceeds the "
        f"{MAX_DISABLED_OVERHEAD:.0%} budget (noop span {span_s * 1e9:.0f}ns, "
        f"inc {inc_s * 1e9:.0f}ns, observe {observe_s * 1e9:.0f}ns)")
    return {"noop_span_ns": span_s * 1e9, "enabled_ns": enabled_s * 1e9,
            "counter_inc_ns": inc_s * 1e9, "observe_ns": observe_s * 1e9,
            "per_offload_overhead_us": per_offload * 1e6,
            "read_row_us": read_row_s * 1e6, "ratio": ratio}


def main(data_mib: int = 16, runs: int = 3) -> list[str]:
    rows = []
    results = run_profile(data_mib=data_mib, runs=runs)
    for r in results:
        cp = r["critical_path_seconds"]
        rows.append(
            f"profile_{r['devices']}dev,{r['seconds'] * 1e6:.0f},"
            f"mib_per_s={r['mib_per_s']:.1f};attributed={r['attributed']:.2f};"
            f"read_wait_ms={cp.get('stage.read_wait', 0) * 1e3:.1f};"
            f"staging_ms={cp.get('stage.staging', 0) * 1e3:.1f};"
            f"dispatch_ms={cp.get('stage.dispatch', 0) * 1e3:.1f};"
            f"serve_ms={cp.get('stage.serve_chunk', 0) * 1e3:.1f};"
            f"submit_ms={cp.get('offload.stage.read', 0) * 1e3:.1f};"
            f"combine_ms={cp.get('offload.stage.combine', 0) * 1e3:.1f};"
            f"plan_ms={cp.get('offload.plan', 0) * 1e3:.1f};"
            f"events={r['trace_events']};dropped={r['trace_dropped']}"
        )
    diag = diagnose(results)
    top = diag["top_serialization_point"]
    comp = diag["components"][top]
    rows.append(
        f"profile_diagnosis,0,"
        f"top_serialization_point={top};"
        f"w1_ms={comp['w1_seconds'] * 1e3:.1f};"
        f"wmax_ms={comp['wmax_seconds'] * 1e3:.1f};"
        f"scaling={comp['scaling']:.2f}x;"
        f"widths={diag['widths'][0]}-{diag['widths'][1]}"
    )
    o = measure_overhead()
    rows.append(
        f"profile_overhead,{o['per_offload_overhead_us']:.3f},"
        f"ratio={o['ratio']:.4f};noop_span_ns={o['noop_span_ns']:.0f};"
        f"counter_inc_ns={o['counter_inc_ns']:.0f};"
        f"observe_ns={o['observe_ns']:.0f};"
        f"read_row_us={o['read_row_us']:.0f}"
    )
    return rows


if __name__ == "__main__":
    for row in main(data_mib=16, runs=3):
        print(row)
