from repro.kernels.zone_filter.ops import (
    KERNELIZABLE_TERMINALS,
    kernel_program,
    kernel_program_batched,
    run_program_kernel,
    run_program_kernel_batched,
    zone_filter_count,
)

__all__ = ["zone_filter_count", "run_program_kernel",
           "run_program_kernel_batched", "kernel_program",
           "kernel_program_batched", "KERNELIZABLE_TERMINALS"]
