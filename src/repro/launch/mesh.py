"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``xla_force_host_platform_device_count`` before first JAX init and only then
builds meshes.

Production topology (TPU v5e): one pod = 16 x 16 = 256 chips,
axes (data, model); two pods = (2, 16, 16), axes (pod, data, model).
The "pod" axis is outer data-parallel (gradient all-reduce crosses DCN);
"model" is the intra-pod tensor/expert-parallel axis on ICI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))
