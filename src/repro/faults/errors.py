"""Transient-error taxonomy for the fault-injection subsystem.

The existing :class:`~repro.zns.device.ZNSError` family models *protocol*
violations and *permanent* failures: a read past the write pointer, an
append to a FULL zone, an OFFLINE member. Real ZNS devices additionally
return transient NVMe statuses — media errors that succeed on retry,
commands that exceed their latency budget, appends whose payload only
partially reached the media (the anomalies arXiv:2010.06243 documents on
production hardware). Those deserve a *distinct* taxonomy: a caller that
treats a retryable media error like a dead zone amputates members it could
have ridden through.

Every class here is an **error completion**, not a submit-time exception:
the device stages it on the transfer's :class:`~repro.zns.ring.IoFuture`
and the completion ring delivers it at the emulated deadline, exactly like
a late NVMe CQE with a non-success status code.

``retryable`` is the one bit the retry engine consults: media errors and
timeouts are worth another attempt, a torn append is not (the zone's write
pointer is indeterminate — the host must fence and recover, as on real
hardware).
"""
from __future__ import annotations

__all__ = ["TransientIOError", "TornAppendError", "IoTimeoutError"]


class TransientIOError(Exception):
    """A transient device-level I/O failure delivered via the completion
    ring (retryable NVMe status analogue). NOT a :class:`ZNSError` — the
    protocol was honored; the media/transport hiccuped."""

    kind = "media"
    retryable = True

    def __init__(self, message: str, *, op: str = "io", device: str = "",
                 zone_id: int = -1, attempt: int = 1):
        super().__init__(message)
        self.op = op
        self.device = device
        self.zone_id = zone_id
        self.attempt = attempt


class TornAppendError(TransientIOError):
    """An append whose payload only partially reached the media before the
    command failed: the zone's write pointer is indeterminate past the last
    durable block. Non-retryable — blindly re-appending would interleave
    garbage into the stripe stream; the owner must fence the zone."""

    kind = "torn_append"
    retryable = False


class IoTimeoutError(TransientIOError):
    """A command that exceeded its per-op timeout budget (either a hung
    command whose completion never arrived, or a latency spike past the
    policy's patience). Raised to the caller only after the retry budget is
    exhausted."""

    kind = "timeout"
