"""zcsd-top: a live terminal dashboard over the telemetry stack.

Renders the operator's view of an emulated array the way ``iostat``/``ztop``
would: per-member SMART health, per-tenant QoS (bytes / ops / p50 / p99 /
degraded reads, straight off the global registry's ``tenant.*`` series),
currently-active alerts, rebuild/scrub progress (per-seat progress bars off
the :class:`~repro.array.rebuild.ArrayManager` plus the ``scrub.*``
counters), and the tail of the structured event log — one refreshing frame
per interval.

The renderer is a pure function (:func:`render`) over whatever monitors /
engine / log the caller hands it, so tests can assert on a frame without a
terminal. Run as a script it drives a demo workload — a two-member raid1
array serving two tenants, with a member zone killed partway through — so
every pane has something to show::

    PYTHONPATH=src python benchmarks/top.py              # live, ctrl-C to quit
    PYTHONPATH=src python benchmarks/top.py --once       # single frame (CI)
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.telemetry import (
    AlertEngine,
    ArrayHealthMonitor,
    ErrorRateRule,
    HealthPromotionRule,
    TenantLatencySLORule,
    event_log,
    registry,
    retry_storm_rule,
)

_STATUS_GLYPH = {"HEALTHY": "ok", "SUSPECT": "??", "DEGRADED": "!!",
                 "OFFLINE": "XX"}


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def tenant_rows(snapshot: dict) -> list[dict]:
    """Pull ``{tenant, ops, bytes, errors, degraded, p50_s, p99_s}`` rows
    out of a registry snapshot's ``tenant.*`` series."""
    tenants = sorted({k.split(".")[1] for k in snapshot
                      if k.startswith("tenant.") and k.count(".") >= 2})
    rows = []
    for t in tenants:
        pfx = f"tenant.{t}."
        ops = snapshot.get(pfx + "ops", 0)
        if not ops:
            continue                    # registered but idle: keep the pane quiet
        rows.append({
            "tenant": t,
            "ops": ops,
            "bytes": snapshot.get(pfx + "bytes", 0),
            "errors": snapshot.get(pfx + "errors", 0),
            "degraded": snapshot.get(pfx + "degraded_reads", 0),
            "p50_s": snapshot.get(pfx + "offload_latency_seconds.p50", 0.0),
            "p99_s": snapshot.get(pfx + "offload_latency_seconds.p99", 0.0),
        })
    return rows


def render(*, monitor: ArrayHealthMonitor | None = None,
           engine: AlertEngine | None = None, manager=None,
           log=None, snapshot: dict | None = None, events_tail: int = 8,
           width: int = 78) -> str:
    """One dashboard frame as a string (no terminal control codes)."""
    snap = snapshot if snapshot is not None else registry().snapshot()
    log = log if log is not None else event_log()
    bar = "=" * width
    thin = "-" * width
    lines = [bar,
             f"zcsd-top  {time.strftime('%H:%M:%S')}   "
             f"events={len(log)} (dropped={log.dropped})",
             bar]

    lines.append("POOL HEALTH")
    if monitor is not None:
        lines.append(f"  {'member':<18}{'status':<10}{'zones':>6}"
                     f"{'off/ro':>8}{'errs':>6}{'outliers':>9}"
                     f"{'read p99':>10}")
        for smart in monitor.smart_logs():
            glyph = _STATUS_GLYPH.get(smart["status"], "?")
            lines.append(
                f"  {smart['device']:<18}"
                f"{glyph + ' ' + smart['status']:<10}"
                f"{smart['zones']:>6}"
                f"{str(smart['zones_offline']) + '/' + str(smart['zones_read_only']):>8}"
                f"{smart['media_errors']:>6}"
                f"{smart['latency_outliers']:>9}"
                f"{smart['read_p99_s'] * 1e6:>9.0f}u")
    else:
        lines.append("  (no array monitor attached)")
    lines.append(thin)

    lines.append("FAULTS")
    smarts = monitor.smart_logs() if monitor is not None else []
    if any(s.get("faults_injected") or s.get("retries")
           or s.get("io_timeouts") for s in smarts):
        lines.append(f"  {'member':<18}{'injected':>9}{'retried':>9}"
                     f"{'timed-out':>10}{'exhausted':>10}")
        for smart in smarts:
            lines.append(
                f"  {smart['device']:<18}"
                f"{smart.get('faults_injected', 0):>9}"
                f"{smart.get('retries', 0):>9}"
                f"{smart.get('io_timeouts', 0):>10}"
                f"{smart['media_errors']:>10}")
    else:
        lines.append("  (no faults injected)")
    lines.append(thin)

    lines.append("TENANTS")
    rows = tenant_rows(snap)
    if rows:
        lines.append(f"  {'tenant':<12}{'ops':>8}{'bytes':>10}{'errs':>6}"
                     f"{'degraded':>9}{'p50':>10}{'p99':>10}")
        for r in rows:
            lines.append(
                f"  {r['tenant']:<12}{r['ops']:>8}"
                f"{_fmt_bytes(r['bytes']):>10}{r['errors']:>6}"
                f"{r['degraded']:>9}"
                f"{r['p50_s'] * 1e3:>8.2f}ms"
                f"{r['p99_s'] * 1e3:>8.2f}ms")
    else:
        lines.append("  (no tenant traffic yet)")
    lines.append(thin)

    lines.append("ALERTS")
    active = {r: keys for r, keys in (engine.active() if engine else {}).items()
              if keys}
    if active:
        for rule, keys in sorted(active.items()):
            for key in sorted(keys):
                lines.append(f"  FIRING  {rule:<18} {key}")
    else:
        lines.append("  (none firing)")
    if engine is not None and engine.fired:
        last = engine.fired[-1]
        lines.append(f"  last: [{last.severity.name}] {last.message[:width - 10]}")
    lines.append(thin)

    lines.append("REBUILD / SCRUB")
    seats = manager.status() if manager is not None else {}
    if seats:
        for member, st in sorted(seats.items()):
            total = st.get("zones_total", 0)
            done = st.get("zones_done", 0)
            frac = done / total if total else 0.0
            fill = int(round(frac * 20))
            bar_s = "#" * fill + "." * (20 - fill)
            lines.append(
                f"  member {member} -> spare dev{st.get('spare', '?')}  "
                f"{st.get('state', '?'):<9}[{bar_s}] {done}/{total} zones"
                + (f"  restarts={st['restarts']}" if st.get("restarts") else "")
                + (f"  failed={st['zones_failed']}"
                   if st.get("zones_failed") else ""))
    elif manager is not None:
        lines.append("  (no rebuild has run)")
    if manager is not None:
        lines.append(
            f"  spares available: {manager.spare_count}   scrub: "
            f"passes={snap.get('scrub.passes', 0)} "
            f"rows={snap.get('scrub.rows_verified', 0)} "
            f"mismatches={snap.get('scrub.mismatches', 0)}")
    else:
        lines.append("  (no array manager attached)")
    lines.append(thin)

    lines.append(f"EVENTS (last {events_tail})")
    tail = log.tail(events_tail)
    if tail:
        for e in tail:
            lines.append(f"  {e.seq:>5} [{e.severity.name:<8}] "
                         f"{e.name:<22} {e.message[:width - 42]}")
    else:
        lines.append("  (event log empty)")
    lines.append(bar)
    return "\n".join(lines)


# ----------------------------------------------------------- demo workload
def _demo(stop: threading.Event):
    """Two tenants hammering a raid1 pair; a member dies mid-run and the
    self-healing manager rebuilds it onto a hot spare while a background
    scrub ticks. Returns (monitor, engine, manager, thread)."""
    from repro.array import ArrayManager, OffloadScheduler, StripedZoneArray
    from repro.core import filter_count
    from repro.faults import FaultInjector, FaultSpec, RetryPolicy
    from repro.zns import ZonedDevice

    data_bytes = 2 * 1024 * 1024
    rng = np.random.default_rng(0)
    data = rng.integers(0, 2**31 - 1, data_bytes // 4, dtype=np.int32)
    devices = [ZonedDevice(num_zones=4, zone_bytes=data_bytes,
                           block_bytes=4096, read_us_per_block=1.0)
               for _ in range(2)]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy="raid1")
    array.zone_append(0, data)
    # transient media errors on the datapath, absorbed by bounded retries —
    # feeds the FAULTS pane and the retry-storm alert without ejecting anyone
    injector = FaultInjector(7, FaultSpec(read_error_rate=0.02))
    injector.attach_array(array, policy=RetryPolicy(max_attempts=4,
                                                    backoff_base_s=50e-6))
    program = filter_count("int32", "gt", 2**30)

    monitor = ArrayHealthMonitor(array)
    monitor.register_on(registry())
    engine = AlertEngine(rules=[
        HealthPromotionRule(monitor),
        ErrorRateRule(pattern="health.*_errors"),
        retry_storm_rule(max_per_second=5.0),
        TenantLatencySLORule(0.5),
    ])
    spare = ZonedDevice(num_zones=4, zone_bytes=data_bytes, block_bytes=4096,
                        append_us_per_block=20.0)   # paced: progress visible
    manager = ArrayManager(array, spares=[spare], monitor=monitor)
    manager.attach(engine)
    manager.start_scrub(interval=2.0)

    def loop():
        sched = OffloadScheduler(array)
        sched.register_tenant("alice", weight=3)
        sched.register_tenant("bob", weight=1)
        n = 0
        with sched:
            while not stop.is_set():
                sched.nvm_cmd_bpf_run(program, 0,
                                      tenant="alice" if n % 4 else "bob")
                n += 1
                if n == 12:             # fault injection: past the DEGRADED
                    array.set_offline(0, device=1)  # threshold (2/4 zones),
                    array.set_offline(1, device=1)  # so promotion fires
                stop.wait(0.05)

    t = threading.Thread(target=loop, name="top-demo", daemon=True)
    t.start()
    return monitor, engine, manager, t


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh interval seconds")
    ap.add_argument("--frames", type=int, default=0,
                    help="stop after N frames (0 = until ctrl-C)")
    ap.add_argument("--once", action="store_true",
                    help="render a single frame and exit")
    args = ap.parse_args(argv)
    if args.once:
        args.frames = 1

    stop = threading.Event()
    monitor, engine, manager, worker = _demo(stop)
    frames = 0
    try:
        while True:
            time.sleep(0.0 if args.once else args.interval)
            engine.evaluate()           # doubles as the SMART sampling tick
            frame = render(monitor=monitor, engine=engine, manager=manager)
            if not args.once:
                sys.stdout.write("\x1b[2J\x1b[H")   # clear + home
            print(frame, flush=True)
            frames += 1
            if args.frames and frames >= args.frames:
                return 0
    except KeyboardInterrupt:
        return 0
    finally:
        stop.set()
        manager.stop()
        worker.join(timeout=5.0)


if __name__ == "__main__":
    sys.exit(main())
