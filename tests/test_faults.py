"""Deterministic fault injection: taxonomy, retry/timeout datapath, crash
harness.

Layer contracts pinned here (the live-traffic sweeps ride
``benchmarks/bench_faults.py``):

  * the injector is a pure function of (seed, key, op, seq) — identical
    schedules across instances and runs, per-class salt independence,
    scriptable ``force`` overrides;
  * injected faults are **error completions through the ring** (never
    submit-time raises), absorbed by the bounded retry policy, escalated
    into the existing health/degraded pipeline only on budget exhaustion;
  * torn appends fence the logical zone at completion time; hung commands
    are rescued by per-op timeouts or diagnosed by ``result(timeout=)``;
  * two runs with one seed produce byte-identical offload results and the
    identical ordered fault/retry event sequence (raid1 and xor);
  * power loss at every append-completion boundary of a striped checkpoint
    save recovers to a committed checkpoint or refuses cleanly.
"""
from __future__ import annotations

import time

import numpy as np
import pytest

from repro.array import ArrayOffloadError, OffloadScheduler, StripedZoneArray
from repro.core import CsdTier, filter_count, filter_sum
from repro.faults import (FaultInjector, FaultSpec, IoTimeoutError,
                          RetryPolicy, TornAppendError, TransientIOError)
from repro.faults.crash import CrashConsistencyError, PowerLossHarness
from repro.telemetry import (AlertEngine, ArrayHealthMonitor, HealthStatus,
                             MetricsRegistry, retry_storm_rule)
from repro.telemetry.events import event_log
from repro.telemetry.health import DeviceHealthMonitor
from repro.zns import ZNSError, ZonedDevice

BLOCK = 4096
RAND_MAX = 2**31 - 1


def _dev(num_zones=2, zone_blocks=64, **kw) -> ZonedDevice:
    return ZonedDevice(num_zones=num_zones, zone_bytes=zone_blocks * BLOCK,
                       block_bytes=BLOCK, **kw)


def _blocks(n, fill=7) -> np.ndarray:
    return np.full(n * BLOCK, fill, dtype=np.uint8)


# ------------------------------------------------------------ the injector
class TestFaultInjector:
    def test_identical_seeds_identical_schedules(self):
        spec = FaultSpec(read_error_rate=0.2, append_error_rate=0.1,
                         latency_spike_rate=0.1, hang_rate=0.05,
                         torn_append_rate=0.1)
        runs = []
        for _ in range(2):
            inj = FaultInjector(42, spec)
            kinds = [(inj.decide(0, "read", 0, 8).kind,
                      inj.decide(1, "append", 1, 8).kind)
                     for _ in range(200)]
            runs.append((kinds, inj.schedule_log()))
        assert runs[0] == runs[1]
        # and the schedule is non-trivial at these rates
        assert any(k[0] or k[1] for k in runs[0][0])

    def test_different_seeds_diverge(self):
        spec = FaultSpec(read_error_rate=0.3)
        a = FaultInjector(1, spec)
        b = FaultInjector(2, spec)
        ka = [a.decide(0, "read", 0, 1).kind for _ in range(200)]
        kb = [b.decide(0, "read", 0, 1).kind for _ in range(200)]
        assert ka != kb

    def test_per_class_salt_independence(self):
        """Raising the media rate must not move WHICH submissions hang."""
        hangs = []
        for media_rate in (0.0, 0.5):
            inj = FaultInjector(9, FaultSpec(read_error_rate=media_rate,
                                             hang_rate=0.1))
            hangs.append([i for i in range(300)
                          if inj.decide(0, "read", 0, 1).kind == "hang"])
        assert hangs[0] == hangs[1] and hangs[0]

    def test_keys_draw_independent_streams(self):
        inj = FaultInjector(3, FaultSpec(read_error_rate=0.3))
        k0 = [inj.decide(0, "read", 0, 1).kind for _ in range(100)]
        k1 = [inj.decide(1, "read", 0, 1).kind for _ in range(100)]
        assert k0 != k1

    def test_force_overrides_the_draw(self):
        inj = FaultInjector(0)         # zero rates: never fires on its own
        inj.force(0, "read", 2, "media")
        kinds = [inj.decide(0, "read", 0, 1).kind for _ in range(4)]
        assert kinds == [None, None, "media", None]
        assert inj.injected["media"] == 1

    def test_torn_degrades_outside_fresh_multiblock_appends(self):
        inj = FaultInjector(0)
        for seq, (op, nblocks, retry) in enumerate(
                [("read", 8, False), ("append", 1, False),
                 ("append", 8, True)]):
            inj.force(0, op, seq if op == "read" else seq - 1, "torn")
        assert inj.decide(0, "read", 0, 8).kind == "media"
        assert inj.decide(0, "append", 0, 1).kind == "media"
        assert inj.decide(0, "append", 0, 8, retry=True).kind == "media"

    def test_per_key_spec_and_jitter(self):
        sick = FaultSpec(read_error_rate=1.0)
        inj = FaultInjector(5, per_key={3: sick})
        assert inj.spec_for(3) is sick
        assert inj.decide(3, "read", 0, 1).kind == "media"
        assert inj.decide(0, "read", 0, 1).kind is None
        js = [inj.jitter01(0, "read") for _ in range(50)]
        assert all(0.0 <= j < 1.0 for j in js)
        inj2 = FaultInjector(5)
        assert js == [inj2.jitter01(0, "read") for _ in range(50)]


# ------------------------------------------------------------ the taxonomy
class TestTaxonomy:
    def test_retryable_bits_and_zns_separation(self):
        assert TransientIOError("x").retryable
        assert IoTimeoutError("x").retryable
        assert not TornAppendError("x").retryable
        assert issubclass(TornAppendError, TransientIOError)
        assert issubclass(IoTimeoutError, TransientIOError)
        assert not issubclass(TransientIOError, ZNSError)

    def test_error_carries_diagnostics(self):
        e = TransientIOError("boom", op="read", device="dev7", zone_id=3,
                             attempt=2)
        assert (e.op, e.device, e.zone_id, e.attempt) == ("read", "dev7", 3, 2)


# --------------------------------------------------- device datapath faults
class TestDeviceDatapath:
    def test_error_is_a_completion_not_a_raise(self):
        d = _dev()
        d.zone_append(0, _blocks(4))
        inj = FaultInjector(0)
        inj.attach(d, key=0)           # no policy: single attempt
        inj.force(0, "read", 0, "media")
        fut = d.submit_read(0, 0, 4)   # must NOT raise at submit time
        with pytest.raises(TransientIOError):
            fut.result()
        assert isinstance(fut.error, TransientIOError)
        assert d.stats["read_errors"] == 1      # budget of 1 exhausted

    def test_retry_absorbs_transient_media_error(self):
        d = _dev()
        data = _blocks(4, fill=9)
        d.zone_append(0, data)
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=3,
                                                backoff_base_s=0.0))
        inj.force(0, "read", 0, "media")
        got = np.asarray(d.submit_read(0, 0, 4).result()).reshape(-1)
        assert np.array_equal(got, data)
        s = d.stats
        assert s["retries"] == 1 and s["faults_injected"] == 1
        assert s["read_errors"] == 0, "absorbed fault must stay soft"

    def test_exhausted_budget_escalates_once(self):
        d = _dev()
        d.zone_append(0, _blocks(2))
        inj = FaultInjector(0, FaultSpec(read_error_rate=1.0))
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=3,
                                                backoff_base_s=0.0))
        seq0 = event_log().last_seq()
        with pytest.raises(TransientIOError):
            d.read_blocks(0, 0, 2)     # sync path rides the same machinery
        s = d.stats
        assert s["retries"] == 2       # attempts 2 and 3
        assert s["read_errors"] == 1   # ONE escalation, not one per attempt
        names = [e.name for e in event_log().snapshot(since_seq=seq0)]
        assert names.count("io.retry") == 2
        assert names.count("io.retry_exhausted") == 1

    def test_latency_spike_injects_delay_not_error(self):
        d = _dev()
        d.zone_append(0, _blocks(2))
        inj = FaultInjector(0)
        inj.attach(d, key=0)
        inj.force(0, "read", 0, None, extra_latency_s=0.01)
        t0 = time.perf_counter()
        fut = d.submit_read(0, 0, 2)
        assert np.asarray(fut.result()).size == 2 * BLOCK
        # the spike occupies the zone's virtual-time die for 10ms
        assert time.perf_counter() - t0 >= 0.009
        assert d.stats["faults_injected"] == 1
        assert d.stats["read_errors"] == 0

    def test_hang_rescued_by_policy_timeout(self):
        d = _dev()
        d.zone_append(0, _blocks(2))
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=1,
                                                timeout_s=0.01))
        inj.force(0, "read", 0, "hang")
        with pytest.raises(IoTimeoutError):
            d.submit_read(0, 0, 2).result(timeout=5.0)
        assert d.stats["io_timeouts"] == 1

    def test_hang_then_timeout_then_retry_succeeds(self):
        d = _dev()
        data = _blocks(3, fill=5)
        d.zone_append(0, data)
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=2,
                                                backoff_base_s=0.0,
                                                timeout_s=0.01))
        inj.force(0, "read", 0, "hang")
        got = np.asarray(d.submit_read(0, 0, 3).result(timeout=5.0))
        assert np.array_equal(got.reshape(-1), data)
        # the timed-out attempt lands in io_timeouts (retries counts only
        # error-completion resubmissions), and nothing escalated hard
        assert d.stats["io_timeouts"] == 1
        assert d.stats["read_errors"] == 0

    def test_stuck_op_diagnostic_names_the_op(self):
        d = _dev()
        d.zone_append(0, _blocks(2))
        inj = FaultInjector(0)
        inj.attach(d, key=0)           # no timeout: genuinely stuck
        inj.force(0, "read", 0, "hang")
        fut = d.submit_read(0, 0, 2)
        with pytest.raises(TimeoutError) as ei:
            fut.result(timeout=0.02)
        msg = str(ei.value)
        assert "read" in msg and "zone 0" in msg and "dev" in msg

    def test_torn_append_lands_prefix_and_fails_hard(self):
        d = _dev()
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=4,
                                                backoff_base_s=0.0))
        inj.force(0, "append", 0, "torn", torn_keep=0.5)
        fut = d.submit_append(0, _blocks(4))
        with pytest.raises(TornAppendError):
            fut.result()
        assert d.zone(0).write_pointer == 2     # the prefix landed
        s = d.stats
        assert s["append_errors"] == 1          # non-retryable: no retries
        assert s["retries"] == 0

    def test_hung_append_lands_payload_without_completion(self):
        d = _dev()
        inj = FaultInjector(0)
        inj.attach(d, key=0)
        inj.force(0, "append", 0, "hang")
        fut = d.submit_append(0, _blocks(2))
        assert d.zone(0).write_pointer == 2     # durable on the media
        assert not fut.done()                   # the CQE never arrived

    def test_append_retry_replays_same_landing_block(self):
        d = _dev()
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=3,
                                                backoff_base_s=0.0))
        d.zone_append(0, _blocks(1))            # wp=1 before the fault
        inj.force(0, "append", 1, "media")      # seq 1: the next append
        landed = d.submit_append(0, _blocks(2)).result()
        assert landed == 1                      # data effect happened ONCE
        assert d.zone(0).write_pointer == 3
        assert d.stats["retries"] == 1


# ----------------------------------------------------------- array datapath
def _filled_array(n_dev=4, redundancy="raid1", zone_blocks=256,
                  num_zones=2, seed=0, **dev_kw):
    devices = [_dev(num_zones=num_zones, zone_blocks=zone_blocks, **dev_kw)
               for _ in range(n_dev)]
    array = StripedZoneArray(devices, stripe_blocks=16,
                             redundancy=redundancy)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, array.zone_blocks * BLOCK // 8,
                        dtype=np.int32)        # half the logical zone
    array.zone_append(0, data)
    return array, data


class TestArrayDatapath:
    def test_fanout_retries_keep_bits_identical(self):
        array, _ = _filled_array()
        baseline = array.read_zone(0).copy()
        inj = FaultInjector(11, FaultSpec(read_error_rate=0.2))
        inj.attach_array(array, policy=RetryPolicy(max_attempts=6,
                                                   backoff_base_s=0.0))
        for _ in range(3):
            assert np.array_equal(array.read_zone(0), baseline)
        assert sum(d.stats["retries"] for d in array.devices) > 0
        assert sum(d.stats["read_errors"] for d in array.devices) == 0

    def test_torn_member_append_fences_the_logical_zone(self):
        array, _ = _filled_array()
        inj = FaultInjector(0)
        inj.attach_array(array)
        inj.force(0, "append", 0, "torn")       # member 0's next append
        seq0 = event_log().last_seq()
        wp0 = array.zone(0).write_pointer
        committed = array.read_blocks(0, 0, wp0).copy()
        with pytest.raises(TornAppendError):
            array.zone_append(0, np.ones(array.stripe_blocks * 2 * BLOCK,
                                         np.uint8))
        assert array.zone(0).state.value == "read_only"
        assert event_log().snapshot(name="array.zone_fenced", since_seq=seq0)
        # pre-tear data still readable bit-identically; the torn extent and
        # fresh appends are refused cleanly, never served as garbage
        assert np.array_equal(array.read_blocks(0, 0, wp0), committed)
        with pytest.raises(ZNSError):
            array.read_zone(0)         # tail reaches the un-landed member blocks
        with pytest.raises(ZNSError) as ei:
            array.zone_append(0, _blocks(1))
        assert "fenced" in str(ei.value)
        # reset clears the fence (the documented recovery path)
        array.reset_zone(0)
        array.zone_append(0, _blocks(1))

    def test_fanout_join_timeout_names_stuck_member(self):
        array, _ = _filled_array()
        inj = FaultInjector(0)
        inj.attach_array(array)
        inj.force(0, "read", 0, "hang")         # member 0 hangs its chunk
        fut = array.submit_read(0, 0, array.stripe_blocks * 2)
        with pytest.raises(TimeoutError) as ei:
            fut.result(timeout=0.02)
        msg = str(ei.value)
        assert "array" in msg and "waiting on" in msg and "read" in msg

    def test_array_sync_reads_accept_timeout_kwarg(self):
        array, _ = _filled_array()
        inj = FaultInjector(0)
        inj.attach_array(array)
        inj.force(0, "read", 0, "hang")
        with pytest.raises(TimeoutError):
            array.read_blocks(0, 0, array.stripe_blocks, timeout=0.02)
        # healthy ops with a timeout budget just work
        assert array.read_blocks(0, 0, array.stripe_blocks,
                                 timeout=5.0).size


# ------------------------------------------------- scheduler + health chain
class TestOffloadUnderFaults:
    def test_offload_bit_identical_under_transients(self):
        array, data = _filled_array()
        expected = int((data > RAND_MAX // 2).sum())
        inj = FaultInjector(21, FaultSpec(read_error_rate=0.15))
        inj.attach_array(array, policy=RetryPolicy(max_attempts=6,
                                                   backoff_base_s=0.0))
        program = filter_count("int32", "gt", RAND_MAX // 2)
        with OffloadScheduler(array) as sched:
            sched.register_tenant("t")
            for _ in range(4):
                sched.nvm_cmd_bpf_run(program, 0, tenant="t")
                assert int(sched.nvm_cmd_bpf_result()) == expected
        assert sum(d.stats["retries"] for d in array.devices) > 0

    def test_exhausted_member_escalates_to_degraded_read(self):
        """A member whose budget exhausts is treated exactly like a dead
        member: the raid1 offload reconstructs from the mirror and still
        returns the healthy answer — the escalation path into the existing
        degraded pipeline."""
        array, data = _filled_array()
        # one full stripe group -> a single 16-block chunk per data member:
        # the batched path is skipped, so the exhaustion surfaces in the
        # per-chunk loop and must fall back to degraded reconstruction
        n_blocks = 2 * array.stripe_blocks
        sub = data[:n_blocks * array.block_bytes // 4]
        expected = int((sub > RAND_MAX // 2).sum())
        inj = FaultInjector(0)
        inj.attach_array(array, policy=RetryPolicy(max_attempts=2,
                                                   backoff_base_s=0.0))
        # member 0's chunk read fails on BOTH budgeted attempts
        inj.force(0, "read", 0, "media")
        inj.force(0, "read", 1, "media")
        seq0 = event_log().last_seq()
        program = filter_count("int32", "gt", RAND_MAX // 2)
        with OffloadScheduler(array) as sched:
            sched.register_tenant("t")
            st = sched.nvm_cmd_bpf_run(program, 0, n_blocks=n_blocks,
                                       tenant="t")
            assert int(sched.nvm_cmd_bpf_result()) == expected
        assert st.degraded_reads == 1
        assert array.devices[0].stats["read_errors"] == 1
        assert event_log().snapshot(name="io.retry_exhausted",
                                    since_seq=seq0)

    @pytest.mark.parametrize("mode,n", [("raid1", 4), ("xor", 3)])
    @pytest.mark.parametrize("tier", [CsdTier.JIT, CsdTier.KERNEL])
    def test_transient_mid_batch_reserves_member_bit_identical(
            self, mode, n, tier):
        """ISSUE 10 fault seam: a single member's read dying INSIDE the
        array-wide batched dispatch must not poison the batch — the
        surviving members' staged chunks still dispatch together, the dead
        member's chunks re-serve individually through degraded
        reconstruction (raid1 mirror / xor parity), and the answer stays
        bit-identical to the fault-free run at both compiled tiers."""
        array, data = _filled_array(n_dev=n, redundancy=mode)
        programs = (filter_count("int32", "gt", RAND_MAX // 2),
                    filter_sum("int32", "lt", RAND_MAX // 4))
        with OffloadScheduler(array) as sched:
            clean = [sched.run_and_fetch(p, 0, tier=tier)[0]
                     for p in programs]
            inj = FaultInjector(0)
            inj.attach_array(array, policy=RetryPolicy(max_attempts=2,
                                                       backoff_base_s=0.0))
            for p, want in zip(programs, clean):
                # member 0's next batched group read fails on BOTH budgeted
                # attempts -> exhaustion surfaces mid-batch
                seq = inj._seq.get((0, "read"), 0)
                inj.force(0, "read", seq, "media")
                inj.force(0, "read", seq + 1, "media")
                got, st = sched.run_and_fetch(p, 0, tier=tier)
                assert np.array_equal(np.asarray(want), np.asarray(got))
                assert st.degraded_reads > 0
                assert st.batched_chunks > 0   # survivors still batched
        assert array.devices[0].stats["read_errors"] > 0

    @pytest.mark.parametrize("tier", [CsdTier.JIT, CsdTier.KERNEL])
    def test_raid0_transients_retry_inside_batch_bit_identical(self, tier):
        """raid0 has no redundancy to re-serve from, so the same seam leans
        on the retry policy alone: transient faults inside the batched
        reads are absorbed below the scheduler and the answer is
        bit-identical; only an EXHAUSTED budget escalates to the clean
        aggregate failure."""
        array, data = _filled_array(n_dev=4, redundancy="raid0")
        expected = int((data > RAND_MAX // 2).sum())
        program = filter_count("int32", "gt", RAND_MAX // 2)
        inj = FaultInjector(33, FaultSpec(read_error_rate=0.2))
        inj.attach_array(array, policy=RetryPolicy(max_attempts=6,
                                                   backoff_base_s=0.0))
        with OffloadScheduler(array) as sched:
            for _ in range(3):
                st = sched.nvm_cmd_bpf_run(program, 0)
                assert int(sched.nvm_cmd_bpf_result()) == expected
                assert st.degraded_reads == 0
            assert sum(d.stats["retries"] for d in array.devices) > 0
            # now exhaust member 0's budget mid-batch: no mirror, no parity
            # -> the offload fails as an aggregate, loudly
            seq = inj._seq.get((0, "read"), 0)
            for k in range(6):
                inj.force(0, "read", seq + k, "media")
            with pytest.raises(ArrayOffloadError, match="degraded"):
                sched.run_and_fetch(program, 0)

    def test_soft_counters_classify_suspect_not_degraded(self):
        d = _dev()
        d.zone_append(0, _blocks(4))
        inj = FaultInjector(0)
        inj.attach(d, key=0, policy=RetryPolicy(max_attempts=4,
                                                backoff_base_s=0.0))
        mon = DeviceHealthMonitor(d)
        assert mon.sample() == HealthStatus.HEALTHY
        inj.force(0, "read", 0, "media")
        d.read_blocks(0, 0, 4)
        assert mon.sample() == HealthStatus.SUSPECT
        smart = mon.smart_log()
        assert smart["retries"] == 1
        assert smart["io_timeouts"] == 0 and smart["faults_injected"] == 1
        # soft counters carry no SUSPECT memory: a quiet window recovers
        assert mon.sample() == HealthStatus.HEALTHY

    def test_retry_storm_rule_fires_and_resolves(self):
        array, _ = _filled_array(n_dev=2, zone_blocks=128)
        inj = FaultInjector(0, FaultSpec(read_error_rate=0.5))
        inj.attach_array(array, policy=RetryPolicy(max_attempts=8,
                                                   backoff_base_s=0.0))
        reg = MetricsRegistry("test_faults_storm")
        monitor = ArrayHealthMonitor(array)
        monitor.register_on(reg)
        engine = AlertEngine(rules=[retry_storm_rule()], metrics=reg)
        assert engine.evaluate() == []
        for _ in range(10):
            array.read_blocks(0, 0, array.stripe_blocks)
        for m in monitor.members:
            m.sample()
        fired = engine.evaluate()
        assert any(a.rule == "retry_storm" for a in fired), fired
        # quiet window: the edge-triggered alert resolves
        for m in monitor.members:
            m.sample()
        engine.evaluate()
        assert not any(k for k in engine.active().get("retry_storm",
                                                      set()))


# ------------------------------------------------------ determinism witness
def _deterministic_offload_run(redundancy: str, n_dev: int):
    """One seeded faulty offload run; returns (result bytes, io-event
    sequence keyed by stable member tags, injector transcript)."""
    devices = [_dev(num_zones=2, zone_blocks=128) for _ in range(n_dev)]
    array = StripedZoneArray(devices, stripe_blocks=16,
                             redundancy=redundancy)
    rng = np.random.default_rng(7)
    data = rng.integers(0, RAND_MAX, array.zone_blocks * BLOCK // 8,
                        dtype=np.int32)
    array.zone_append(0, data)
    inj = FaultInjector(1234, FaultSpec(read_error_rate=0.15,
                                        latency_spike_rate=0.1,
                                        latency_spike_s=0.0))
    inj.attach_array(array, policy=RetryPolicy(max_attempts=6,
                                               backoff_base_s=0.0))
    program = filter_count("int32", "gt", RAND_MAX // 2)
    seq0 = event_log().last_seq()
    results = []
    with OffloadScheduler(array, max_workers=1) as sched:
        sched.register_tenant("t")
        for _ in range(4):
            sched.nvm_cmd_bpf_run(program, 0, tenant="t")
            results.append(int(sched.nvm_cmd_bpf_result()))
    raw = array.read_zone(0).tobytes()
    events = [(e.name, e.tags["member"], e.tags["zone"], e.tags["op"],
               e.tags.get("attempt"))
              for e in event_log().snapshot(since_seq=seq0)
              if e.name.startswith("io.")]
    return results, raw, events, inj.schedule_log()


class TestDeterminism:
    @pytest.mark.parametrize("redundancy,n_dev", [("raid1", 8), ("xor", 8)])
    def test_same_seed_same_results_and_fault_sequence(self, redundancy,
                                                       n_dev):
        a = _deterministic_offload_run(redundancy, n_dev)
        b = _deterministic_offload_run(redundancy, n_dev)
        assert a[0] == b[0], "offload results diverged across runs"
        assert a[1] == b[1], "zone bytes diverged across runs"
        assert a[2] == b[2], "fault/retry event sequence diverged"
        assert a[3] == b[3], "injector transcript diverged"
        assert a[2], "schedule injected nothing — determinism untested"


# --------------------------------------------------------- crash consistency
class TestCrashHarness:
    def test_raid1_sweep_never_torn(self, tmp_path):
        h = PowerLossHarness(tmp_path, num_devices=4, num_zones=6,
                             member_zone_bytes=256 * 1024, stripe_blocks=4,
                             redundancy="raid1")
        trees = [(s, {"w": np.arange(300, dtype=np.float32) + s,
                      "b": np.full((17,), s, np.int32)}) for s in (1, 2)]
        outcomes = h.run(trees)
        assert len(outcomes) == len(h.journal) + 1
        assert all(o.ok for o in outcomes)
        # boundary 0 = power loss before anything landed: clean refusal
        assert outcomes[0].refused and outcomes[0].recovered_step is None
        # final boundary = nothing lost: the newest step restores
        assert outcomes[-1].recovered_step == 2
        # monotone recovery: later cuts never restore older steps
        steps = [o.recovered_step for o in outcomes
                 if o.recovered_step is not None]
        assert steps == sorted(steps)

    def test_xor_sweep_and_stride(self, tmp_path):
        h = PowerLossHarness(tmp_path, num_devices=3, num_zones=6,
                             member_zone_bytes=256 * 1024, stripe_blocks=4,
                             redundancy="xor", stride=2)
        outcomes = h.run([(5, {"w": np.arange(200, dtype=np.float32)})])
        assert all(o.ok for o in outcomes)
        assert outcomes[-1].recovered_step == 5
        assert h.summary()["all_ok"]

    def test_violation_raises_with_boundary(self, tmp_path):
        """A harness whose journal LIES (claims a manifest completed that
        never landed) must fail the sweep — the detector detects."""
        h = PowerLossHarness(tmp_path, num_devices=4, num_zones=6,
                             member_zone_bytes=256 * 1024, stripe_blocks=4,
                             redundancy="raid1")
        trees = [(1, {"w": np.arange(64, dtype=np.float32)})]
        h._record_saves(trees)
        # claim step 1 was fully durable after its FIRST member append —
        # recovery at that cut must refuse (no manifest on disk), which now
        # violates the forged lo bound and trips the detector
        step, _end = h._step_end[0]
        h._step_end[0] = (step, 1)
        with pytest.raises(CrashConsistencyError):
            for k in h._boundaries():
                out = h._check_boundary(k, dict(trees), trees[0][1])
                if not out.ok:
                    raise CrashConsistencyError(out.detail)

    def test_checkpoint_store_rides_the_retry_datapath(self, tmp_path):
        """ZonedCheckpointStore.striped(fault_injector=...) saves/restores
        bit-identically under injected read faults, with retries absorbed
        by the member devices."""
        from repro.train.checkpoint import ZonedCheckpointStore
        inj = FaultInjector(77, FaultSpec(read_error_rate=0.1))
        store = ZonedCheckpointStore.striped(
            tmp_path / "ckpt", num_devices=4, num_zones=6,
            member_zone_bytes=256 * 1024, stripe_blocks=4,
            redundancy="raid1", fault_injector=inj,
            retry_policy=RetryPolicy(max_attempts=6, backoff_base_s=0.0))
        tree = {"w": np.arange(2000, dtype=np.float32),
                "b": np.arange(100, dtype=np.int32)}
        store.save(3, tree)
        got = store.restore(like=tree)
        assert np.array_equal(got["w"], tree["w"])
        assert np.array_equal(got["b"], tree["b"])
        assert sum(d.stats["retries"]
                   for d in store.device.devices) > 0
        assert sum(d.stats["read_errors"]
                   for d in store.device.devices) == 0
