"""Offload scheduler: verified programs fanned out across a striped array.

The single-device ``NvmCsd`` verifies and executes one extent synchronously.
The :class:`OffloadScheduler` scales that contract to a
:class:`~repro.array.striping.StripedZoneArray`:

  1. **verify once** — the program is checked by the same static verifier
     against the whole logical extent *before* it enters a submission queue;
     everything past the SQ is admitted work;
  2. **queue + arbitrate** — commands sit in per-tenant NVMe-style SQs with
     depth limits and are dispatched by weighted round-robin (see
     :mod:`repro.array.queues`);
  3. **fan out** — the logical extent decomposes into stripe chunks, each
     contiguous on exactly one member device; every device executes its
     chunks concurrently on the existing interp/jit/kernel tiers. Same-shape
     chunks are batched into ONE compiled call per device group: a vmapped
     XLA call on the JIT tier (:func:`repro.core.vm.jit_program_batched`) or
     a grid-batched Pallas call on the kernel tier
     (:func:`repro.kernels.zone_filter.ops.kernel_program_batched`), with
     every group's device read submitted to the completion ring up front so
     later groups' emulated transfers elapse while earlier groups execute
     (:mod:`repro.zns.ring`);
  4. **scatter-gather** — per-chunk results are re-combined in logical
     stripe order by a program-aware combiner: SUM/COUNT re-add (float SUM
     via Kahan compensated f64 accumulation, so results are identical for
     every array width over the same logical data), MIN/MAX re-reduce, HIST
     re-accumulates, SELECT/SELECT_REC concatenate the first ``capacity``
     matches in logical order — bit-identical to the single-device result
     for COUNT/MIN/MAX/SELECT and for SUM over integer streams (float SUM
     may differ from a chunk-free single device by summation order, exactly
     as the tiers already may);
  5. **aggregate stats** — one :class:`ArrayOffloadStats` per command rolls
     up bytes read on every member, bytes returned to the host, verify/JIT/
     read/exec time, compile-cache hits, and the fan-out shape.

A 1-device array degrades to the ``NvmCsd`` semantics — the degenerate path.
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cache import CompiledProgramCache
from repro.telemetry import trace as _trace
from repro.telemetry.metrics import registry as _registry
from repro.core.csd import (
    CsdTier,
    OffloadStats,
    execute_extent,
    extent_geometry,
    resolve_tier,
)
from repro.core.programs import OpCode, Program
from repro.core.verifier import VerifierLimits, verify_program, verify_zone_access
from repro.core.vm import _SUM_WIDEN, jit_program_batched
from repro.array.queues import (
    Completion,
    OffloadCommand,
    QueuePair,
    CompletionQueue,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.array.striping import StripeChunk, StripedZoneArray
from repro.faults.errors import TransientIOError
from repro.zns.device import ZNSError, block_aligned_dtype

__all__ = ["OffloadScheduler", "ArrayOffloadStats", "ArrayOffloadError"]


class ArrayOffloadError(Exception):
    """A member device failed mid-offload (e.g. an OFFLINE zone). The message
    names the member so the operator can degrade/repair explicitly."""


@dataclass
class ArrayOffloadStats(OffloadStats):
    """Per-command statistics aggregated over the whole array fan-out.

    ``read_seconds`` sums time spent inside member-device transfers across
    all worker threads; because group reads prefetch under execution, it may
    exceed the ``exec_seconds`` wall time — that surplus IS the overlap.
    """

    n_devices: int = 1
    n_chunks: int = 1
    batched_chunks: int = 0        # chunks executed via a batched compiled call
    # chunks served without their preferred member: raid1 mirror redirects
    # plus xor reconstructions (degraded offloads stay bit-identical; this
    # counter is how an operator notices the array is running degraded)
    degraded_reads: int = 0
    compute_seconds: float = 0.0   # time inside compiled/interp execution only
    # sum over device workers of max(read + compute - worker wall, 0): the
    # transfer time each worker hid WITHIN its own device via the prefetcher.
    # Measured per worker so cross-device parallelism cannot inflate it —
    # with prefetch disabled this is ~0 even on a wide array.
    overlap_seconds: float = 0.0
    # which tenant's SQ carried the command, plus that tenant's cumulative
    # accounting (bytes/ops/p50/p99/degraded_reads from the global registry)
    # as of this command's completion — the QoS view the ROADMAP asks for
    tenant: str = "default"
    tenant_totals: dict = field(default_factory=dict)

    @property
    def fanout(self) -> str:
        return f"{self.n_chunks} chunks / {self.n_devices} devices"

    @property
    def overlap_ratio(self) -> float:
        """Fraction of device-transfer time hidden under that same device's
        execution (1.0 = reads fully prefetched under compute)."""
        return min(self.overlap_seconds / self.read_seconds, 1.0) \
            if self.read_seconds > 0 else 0.0


@dataclass
class _DeviceRun:
    """Accumulator for one device worker's share of a fan-out (also used to
    merge the per-device shares into the command totals)."""

    vals: dict    # chunk index -> value
    compile_s: float = 0.0
    insns: int = 0
    batched: int = 0
    degraded: int = 0
    read_s: float = 0.0
    compute_s: float = 0.0
    overlap_s: float = 0.0
    hits: int = 0
    misses: int = 0

    def merge(self, other: "_DeviceRun") -> None:
        self.vals.update(other.vals)
        self.compile_s += other.compile_s
        self.insns += other.insns
        self.batched += other.batched
        self.degraded += other.degraded
        self.read_s += other.read_s
        self.compute_s += other.compute_s
        self.overlap_s += other.overlap_s
        self.hits += other.hits
        self.misses += other.misses


class _ExtentSource:
    """Duck-typed ``ZonedDevice`` over ONE reconstructed stripe chunk held in
    host memory, addressed at the chunk's member-local offsets.

    Degraded xor chunks have no single member to read from; the array's
    reconstruction (:meth:`StripedZoneArray.submit_read`) produces the bytes,
    and this adapter lets :func:`repro.core.csd.execute_extent` run the SAME
    interp/jit/kernel tier code over them — so a degraded offload is
    bit-identical to the healthy one by construction, not by a parallel
    re-implementation of the tiers.
    """

    read_us_per_block = 0.0   # no emulation: the survivor reads already paid

    def __init__(self, block_bytes: int, base_block: int, flat: np.ndarray):
        self.block_bytes = block_bytes
        self._base = base_block
        self._flat = flat          # uint8, len == n_blocks * block_bytes

    def read_blocks_view(self, zone_id: int, block_off: int,
                         n_blocks: int) -> np.ndarray:
        lo = (block_off - self._base) * self.block_bytes
        view = self._flat[lo: lo + n_blocks * self.block_bytes].view()
        view.flags.writeable = False
        return view

    def read_extent(self, zone_id: int, block_off: int, n_blocks: int,
                    dtype) -> np.ndarray:
        dtype = block_aligned_dtype(self.block_bytes, dtype)
        return self.read_blocks_view(zone_id, block_off, n_blocks).view(dtype)


class OffloadScheduler:
    """NVMe-style scheduler over a striped zone array.

    Exposes the same part-i API as :class:`~repro.core.csd.NvmCsd`
    (``nvm_cmd_bpf_run`` / ``nvm_cmd_bpf_result`` / ``run_and_fetch``) so the
    data pipeline and checkpoint store can treat a whole array as one CSD,
    plus the queued API (``submit`` / ``drain`` / ``start`` / ``wait``).
    """

    def __init__(
        self,
        array: StripedZoneArray,
        *,
        default_tier: str = CsdTier.JIT,
        pages_per_read: int = 1,
        limits: VerifierLimits = VerifierLimits(),
        max_workers: Optional[int] = None,
        queue_depth: int = 64,
        completion_backlog: int = 1024,
        cache: Optional[CompiledProgramCache] = None,
        prefetch_depth: int = 2,
        io_timeout_s: Optional[float] = None,
    ):
        if array.stripe_blocks % pages_per_read:
            raise ValueError(
                f"stripe_blocks {array.stripe_blocks} must be a multiple of "
                f"pages_per_read {pages_per_read} (chunks must tile into pages)"
            )
        self.array = array
        self.default_tier = default_tier
        self.pages_per_read = int(pages_per_read)
        self.limits = limits
        self.queue_depth = queue_depth
        self.completion_backlog = completion_backlog
        self.prefetch_depth = int(prefetch_depth)
        # per-op join patience for chunk reads: a hung member completion
        # surfaces as a diagnostic TimeoutError naming the stuck transfer
        # instead of stranding a worker forever (None = wait indefinitely)
        self.io_timeout_s = io_timeout_s
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max_workers or max(array.n_devices, 1))
        # ONE cache for every tier and batch shape; programs are
        # device-agnostic so sharing (also across schedulers/CSDs, via the
        # ``cache`` argument) maximizes compile reuse
        self.cache = cache if cache is not None else CompiledProgramCache()
        self._pairs: dict[str, QueuePair] = {}
        self._arbiter = WeightedRoundRobinArbiter()
        self._completions: dict[int, Completion] = {}
        self._watched: set[int] = set()   # cmd_ids a sync caller will wait() on
        self._pending: set[int] = set()   # submitted, not yet completed
        self._comp_cond = threading.Condition()
        self._result: Optional[Completion] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self.history: list[ArrayOffloadStats] = []
        self.register_tenant("default")

    # ------------------------------------------------------------ tenants
    def register_tenant(self, tenant: str, *, weight: int = 1,
                        depth: Optional[int] = None) -> QueuePair:
        """Create an SQ/CQ pair for ``tenant`` with a WRR ``weight``."""
        if tenant in self._pairs:
            raise ValueError(f"tenant {tenant!r} already registered")
        pair = QueuePair(
            SubmissionQueue(tenant, depth=depth or self.queue_depth,
                            weight=weight),
            CompletionQueue(tenant, depth=self.completion_backlog),
        )
        self._pairs[tenant] = pair
        self._arbiter.add(pair)
        return pair

    def queue_pair(self, tenant: str = "default") -> QueuePair:
        return self._pairs[tenant]

    # ------------------------------------------------------------- submit
    def submit(
        self,
        program: Program,
        zone_id: int,
        *,
        tenant: str = "default",
        block_off: int = 0,
        n_blocks: Optional[int] = None,
        tier: Optional[str] = None,
        block: bool = False,
        timeout: Optional[float] = None,
        _watch: bool = False,
    ) -> int:
        """Verify and enqueue an offload; returns the command id.

        Verification happens HERE — a rejected program never occupies a queue
        slot, and the SQ carries only admitted commands. A full SQ raises
        :class:`~repro.array.queues.QueueFullError` unless ``block=True``
        (backpressure).
        """
        pair = self._pairs[tenant]
        zone = self.array.zone(zone_id)
        if n_blocks is None:
            n_blocks = zone.write_pointer - block_off
        if block_off % self.pages_per_read:
            raise ValueError(
                f"block_off {block_off} not aligned to read granularity "
                f"{self.pages_per_read}")
        dtype = np.dtype(program.input_dtype)
        page_elems, n_pages = extent_geometry(
            self.array.block_bytes, dtype, n_blocks, self.pages_per_read)
        t_v = time.perf_counter()
        with _trace.span("offload.verify", tenant=tenant, zone=zone_id,
                         program=program.name):
            insns_verified = verify_program(
                program, page_elems=page_elems, n_pages=n_pages,
                limits=self.limits)
            verify_zone_access(
                zone_write_pointer=zone.write_pointer, block_off=block_off,
                n_blocks=n_blocks)
        _registry().histogram("sched.verify_seconds").observe(
            time.perf_counter() - t_v)
        cmd = OffloadCommand(
            program=program, zone_id=zone_id, block_off=block_off,
            n_blocks=n_blocks,
            tier=resolve_tier(tier or self.default_tier, program),
            tenant=tenant, insns_verified=insns_verified,
        )
        # register BEFORE the dispatcher can see the command: _pending lets
        # wait() distinguish in-flight from evicted/unknown, and a watch
        # protects a sync caller's completion from backlog eviction
        with self._comp_cond:
            self._pending.add(cmd.cmd_id)
            if _watch:
                self._watched.add(cmd.cmd_id)
        try:
            pair.sq.submit(cmd, block=block, timeout=timeout)
        except BaseException:
            with self._comp_cond:
                self._pending.discard(cmd.cmd_id)
                self._watched.discard(cmd.cmd_id)
            raise
        self._wake.set()
        return cmd.cmd_id

    # ------------------------------------------------------------ raw I/O
    def submit_io(
        self,
        io_op: str,
        zone_id: int,
        *,
        block_off: int = 0,
        n_blocks: Optional[int] = None,
        data: Optional[np.ndarray] = None,
        tenant: str = "default",
        member: Optional[int] = None,
        block: bool = False,
        timeout: Optional[float] = None,
        on_complete=None,
        _watch: bool = False,
    ) -> int:
        """Enqueue a RAW device I/O command ("read"/"append") on a tenant's
        SQ; returns the command id. The dispatcher forwards it to the array's
        completion ring WITHOUT blocking, so raw I/O (checkpoint traffic)
        overlaps with offload execution while paying its way through the same
        WRR arbitration as offloads. The SQ depth bounds QUEUED commands
        (admission, felt when the dispatcher is busy executing offloads); the
        number of in-flight transfers is bounded by the device's per-zone
        clocks, not the queue — forwarded commands leave the SQ immediately.

        ``member`` targets ONE array member instead of the logical array —
        the rebuild/scrub path: member-local addressing, same tenant SQs,
        same WRR metering against live offload traffic.
        """
        if io_op not in ("read", "append"):
            raise ValueError(f"unknown io_op {io_op!r}")
        pair = self._pairs[tenant]
        if io_op == "read":
            if member is None:
                zone = self.array.zone(zone_id)
            else:
                zone = self.array.devices[member].zone(zone_id)
            if n_blocks is None:
                n_blocks = zone.write_pointer - block_off
            verify_zone_access(
                zone_write_pointer=zone.write_pointer, block_off=block_off,
                n_blocks=n_blocks)
        elif data is None:
            raise ValueError("append command requires data")
        cmd = OffloadCommand(
            program=None, zone_id=zone_id, block_off=block_off,
            n_blocks=n_blocks, tier=None, tenant=tenant,
            io_op=io_op, data=data, member=member, on_complete=on_complete,
        )
        with self._comp_cond:
            self._pending.add(cmd.cmd_id)
            if _watch:
                self._watched.add(cmd.cmd_id)
        try:
            pair.sq.submit(cmd, block=block, timeout=timeout)
        except BaseException:
            with self._comp_cond:
                self._pending.discard(cmd.cmd_id)
                self._watched.discard(cmd.cmd_id)
            raise
        self._wake.set()
        return cmd.cmd_id

    # ----------------------------------------------------------- dispatch
    def dispatch_one(self) -> bool:
        """Arbitrate and launch ONE queued command. Returns False when every
        SQ is empty. Offload commands execute to completion here; raw I/O
        commands are forwarded to the completion ring and retire later (their
        completion lands via the reactor, not this thread)."""
        nxt = self._arbiter.next_command()
        if nxt is None:
            return False
        cmd, pair = nxt
        if _trace.enabled() and cmd.submitted_at:
            # SQ residency as a trace event on the tenant's own track —
            # emitted post-hoc now that the interval is known
            _trace.event_complete(
                "offload.queued", cmd.submitted_at,
                time.monotonic() - cmd.submitted_at,
                track=f"tenant/{cmd.tenant}", tenant=cmd.tenant,
                cmd=cmd.cmd_id)
        if cmd.io_op is not None:
            self._dispatch_io(cmd, pair)
            return True
        try:
            with _trace.span("offload.execute", tenant=cmd.tenant,
                             tier=cmd.tier, zone=cmd.zone_id,
                             program=cmd.program.name):
                value, stats = self._execute(cmd)
            comp = Completion(cmd.cmd_id, cmd.tenant, value=value, stats=stats)
            self.history.append(stats)
            self._publish_stats(stats)
        except Exception as e:  # surfaced via the CQ, never swallowed
            comp = Completion(cmd.cmd_id, cmd.tenant, error=e)
        self._finish(cmd, pair, comp)
        return True

    def _dispatch_io(self, cmd: OffloadCommand, pair: QueuePair) -> None:
        """Forward a raw I/O command to the array's submit path. Never blocks
        on the emulated transfer: the ring retires the completion, and the
        scheduler's completion bookkeeping runs from its done-callback."""
        try:
            target = self.array if cmd.member is None \
                else self.array.devices[cmd.member]
            if cmd.io_op == "append":
                fut = target.submit_append(cmd.zone_id, cmd.data)
            else:
                fut = target.submit_read(cmd.zone_id, cmd.block_off,
                                         cmd.n_blocks)
        except Exception as e:
            self._finish(cmd, pair, Completion(cmd.cmd_id, cmd.tenant, error=e))
            return
        fut.tenant = cmd.tenant    # stuck-op diagnostics name the owner
        fut.add_done_callback(lambda f: self._finish(
            cmd, pair,
            Completion(cmd.cmd_id, cmd.tenant,
                       value=None if f.error is not None else f.value,
                       error=f.error)))

    @staticmethod
    def _publish_stats(stats: ArrayOffloadStats) -> None:
        """Fold one command's ArrayOffloadStats into the global registry, so
        ``metrics.registry().snapshot()`` shows the rolling offload picture
        (commands, read/compute/overlap seconds, the latest overlap ratio)
        next to the cache and gather-pool series."""
        reg = _registry()
        reg.counter("offload.commands").inc()
        reg.histogram("offload.exec_seconds").observe(stats.exec_seconds)
        reg.histogram("offload.read_seconds").observe(stats.read_seconds)
        reg.histogram("offload.overlap_seconds").observe(stats.overlap_seconds)
        reg.gauge("offload.overlap_ratio").set(stats.overlap_ratio)

    def _account_tenant(self, cmd: OffloadCommand, comp: Completion) -> None:
        """Per-tenant QoS accounting at completion time (offloads AND raw
        I/O ride through here): bytes moved, ops, end-to-end command latency
        (SQ entry → completion, the SLO the alert rules watch), errors, and
        degraded-read counts. Tenant names are a bounded set (queues.py), so
        the series live on the global registry."""
        reg = _registry()
        t = cmd.tenant
        reg.counter(f"tenant.{t}.ops").inc()
        if comp.error is not None:
            reg.counter(f"tenant.{t}.errors").inc()
        if cmd.io_op == "append" and cmd.data is not None:
            nbytes = int(np.asarray(cmd.data).nbytes)
        else:
            nbytes = (cmd.n_blocks or 0) * self.array.block_bytes
        if nbytes:
            reg.counter(f"tenant.{t}.bytes").inc(nbytes)
        if cmd.submitted_at:
            reg.histogram(
                f"tenant.{t}.offload_latency_seconds").observe(
                    time.monotonic() - cmd.submitted_at)
        degraded = getattr(comp.stats, "degraded_reads", 0)
        if degraded:
            reg.counter(f"tenant.{t}.degraded_reads").inc(degraded)
        if comp.stats is not None:
            comp.stats.tenant_totals = self._tenant_snapshot(t)

    def _tenant_snapshot(self, tenant: str) -> dict:
        """One tenant's cumulative accounting, read straight off the series
        handles (no full registry snapshot on the completion path)."""
        reg = _registry()
        pfx = f"tenant.{tenant}."
        lat = reg.histogram(pfx + "offload_latency_seconds")
        return {
            "tenant": tenant,
            "bytes": reg.counter(pfx + "bytes").value,
            "ops": reg.counter(pfx + "ops").value,
            "errors": reg.counter(pfx + "errors").value,
            "degraded_reads": reg.counter(pfx + "degraded_reads").value,
            "p50_s": lat.percentile(50),
            "p99_s": lat.percentile(99),
        }

    def tenant_stats(self) -> dict[str, dict]:
        """``{tenant: {bytes, ops, errors, degraded_reads, p50_s, p99_s}}``
        for every registered tenant — the QoS report the ROADMAP's
        per-tenant accounting item asks for (``zcsd-top`` renders it live)."""
        return {t: self._tenant_snapshot(t) for t in self._pairs}

    def _finish(self, cmd: OffloadCommand, pair: QueuePair,
                comp: Completion) -> None:
        """Completion bookkeeping shared by the synchronous offload path and
        the ring-retired raw-I/O path (any thread may run this)."""
        self._account_tenant(cmd, comp)
        with self._comp_cond:
            watched = cmd.cmd_id in self._watched
        # when the payload has a dedicated consumer — a sync caller's wait()
        # (watched) or an on_complete hook — every OTHER completion surface
        # gets a payload-free record (stats/errors stay observable), so
        # neither the CQ ring nor the wait() rendezvous pins up to `depth`
        # dead result buffers (e.g. a queue-routed restore's leaf extents)
        stripped = Completion(cmd.cmd_id, cmd.tenant, value=None,
                              stats=comp.stats, error=comp.error) \
            if (watched or cmd.on_complete is not None) else comp
        pair.cq.push(stripped)
        stored = comp if watched else stripped
        with self._comp_cond:
            self._completions[cmd.cmd_id] = stored
            self._pending.discard(cmd.cmd_id)
            # bound the wait() rendezvous: consumers that read the CQ directly
            # never pop here, so evict oldest-first past the backlog limit —
            # but never a completion a sync caller has reserved with a watch
            while len(self._completions) > self.completion_backlog:
                victim = next((k for k in self._completions
                               if k not in self._watched), None)
                if victim is None:
                    break
                self._completions.pop(victim)
            if cmd.program is not None:
                # raw I/O must not clobber the part-i last-result register
                self._result = comp
            self._comp_cond.notify_all()
        if cmd.on_complete is not None:
            try:
                cmd.on_complete(comp)
            except Exception:
                pass  # a consumer hook must not kill the dispatcher/reactor

    def drain(self) -> int:
        """Dispatch until every submission queue is empty (synchronous pump)."""
        n = 0
        while self.dispatch_one():
            n += 1
        return n

    def wait(self, cmd_id: int, *, timeout: Optional[float] = None) -> Completion:
        """Block until ``cmd_id`` completes (requires a running dispatcher or
        a concurrent ``drain``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._comp_cond:
            while cmd_id not in self._completions:
                if cmd_id not in self._pending:
                    raise LookupError(
                        f"command {cmd_id} has no pending completion (already "
                        f"waited, evicted past completion_backlog, or unknown)")
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"command {cmd_id} still pending")
                self._comp_cond.wait(timeout=remaining)
            self._watched.discard(cmd_id)
            return self._completions.pop(cmd_id)

    def start(self) -> None:
        """Run the dispatcher on a background thread (async mode — the
        paper's stated future extension, at array scope)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.dispatch_one():
                    self._wake.wait(timeout=0.01)
                    self._wake.clear()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="offload-dispatcher")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def close(self) -> None:
        """Stop the dispatcher (if running) and release the fan-out worker
        threads. The scheduler is unusable afterwards; the array is not."""
        self.stop()
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "OffloadScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------- NvmCsd-compatible part-i
    def _run_sync(self, program: Program, zone_id: int, *,
                  block_off: int = 0, n_blocks: Optional[int] = None,
                  tier: Optional[str] = None,
                  tenant: str = "default") -> Completion:
        """Submit, wait, and return THIS command's completion (not the shared
        last-result register, which another tenant may overwrite)."""
        cmd_id = self.submit(program, zone_id, tenant=tenant,
                             block_off=block_off, n_blocks=n_blocks, tier=tier,
                             _watch=True)
        if self._thread is None:
            self.drain()
        # unbounded wait is safe: either a dispatcher thread is running, or
        # drain() returned with every SQ empty — meaning our command was
        # popped (possibly by a concurrent caller's drain) and its completion
        # is forthcoming
        comp = self.wait(cmd_id)
        if comp.error is not None:
            raise comp.error
        return comp

    def nvm_cmd_bpf_run(self, program: Program, zone_id: int, *,
                        block_off: int = 0, n_blocks: Optional[int] = None,
                        tier: Optional[str] = None,
                        tenant: str = "default") -> ArrayOffloadStats:
        """Synchronous verified offload over the whole array (the degenerate
        single-command path through the queue machinery)."""
        return self._run_sync(program, zone_id, block_off=block_off,
                              n_blocks=n_blocks, tier=tier, tenant=tenant).stats

    def nvm_cmd_bpf_result(self) -> object:
        if self._result is None or self._result.error is not None:
            raise RuntimeError("no offload result available")
        return self._result.value

    def run_and_fetch(self, program: Program, zone_id: int, **kw):
        comp = self._run_sync(program, zone_id, **kw)
        return comp.value, comp.stats

    # ---------------------------------------------------------- execution
    def _execute(self, cmd: OffloadCommand) -> tuple[object, ArrayOffloadStats]:
        program, zone_id, tier = cmd.program, cmd.zone_id, cmd.tier
        array = self.array
        reg = _registry()
        t_p = time.perf_counter()
        with _trace.span("offload.plan"):
            try:
                chunks = array.chunks(zone_id, cmd.block_off, cmd.n_blocks)
            except (ZNSError, TransientIOError) as e:
                # the PR 2 clean-error contract: callers handle degraded/
                # failed offloads via ArrayOffloadError, whether one raid0
                # member died or the loss defeated the redundancy mode
                raise ArrayOffloadError(
                    f"offload failed: zone {zone_id} unrecoverable under "
                    f"{array.redundancy}: {e}"
                ) from e
            by_dev: dict[int, list[StripeChunk]] = {}
            for c in chunks:
                by_dev.setdefault(c.device, []).append(c)
        reg.histogram("sched.plan_seconds").observe(time.perf_counter() - t_p)
        if any(c.degraded for c in chunks):
            array.note_degraded_serving(zone_id)

        t0 = time.perf_counter()
        with _trace.span("offload.fanout", devices=len(by_dev),
                         chunks=len(chunks)):
            futures = {
                self._pool.submit(self._run_device_chunks, d, zone_id,
                                  dev_chunks, program, tier): d
                for d, dev_chunks in by_dev.items()
            }
            per_chunk: dict[int, object] = {}
            agg = _DeviceRun({})
            errors: list[BaseException] = []
            for fut in concurrent.futures.as_completed(futures):
                try:
                    run = fut.result()
                except ArrayOffloadError as e:
                    errors.append(e)
                    continue
                per_chunk.update(run.vals)
                agg.merge(run)
        reg.histogram("sched.fanout_seconds").observe(
            time.perf_counter() - t0)
        if errors:
            raise errors[0]

        t_c = time.perf_counter()
        with _trace.span("offload.combine"):
            ordered = [per_chunk[c.index] for c in chunks]
            value = self._combine(program, ordered)
        reg.histogram("sched.combine_seconds").observe(
            time.perf_counter() - t_c)
        # keep exec and JIT time disjoint, as NvmCsd reports them (compiles
        # happen inside the fan-out wall time on cache misses)
        exec_seconds = max(time.perf_counter() - t0 - agg.compile_s, 0.0)

        if isinstance(value, tuple):
            bytes_returned = np.asarray(value[0]).nbytes + 8
        else:
            bytes_returned = np.asarray(value).nbytes
        stats = ArrayOffloadStats(
            program=program.name, tier=tier, zone_id=zone_id,
            pages=cmd.n_blocks // self.pages_per_read,
            insns_verified=cmd.insns_verified,
            insns_executed=agg.insns,
            bytes_read=cmd.n_blocks * array.block_bytes,
            bytes_returned=bytes_returned,
            jit_seconds=agg.compile_s, exec_seconds=exec_seconds,
            read_seconds=agg.read_s, compute_seconds=agg.compute_s,
            overlap_seconds=agg.overlap_s,
            cache_hits=agg.hits, cache_misses=agg.misses,
            n_devices=len(by_dev), n_chunks=len(chunks),
            batched_chunks=agg.batched, degraded_reads=agg.degraded,
            tenant=cmd.tenant,
        )
        return value, stats

    def _run_device_chunks(
        self, dev_idx: int, zone_id: int, dev_chunks: list[StripeChunk],
        program: Program, tier: str,
    ) -> "_DeviceRun":
        with _trace.span("worker.device", device=dev_idx,
                         chunks=len(dev_chunks)):
            return self._run_device_chunks_impl(
                dev_idx, zone_id, dev_chunks, program, tier)

    def _run_device_chunks_impl(
        self, dev_idx: int, zone_id: int, dev_chunks: list[StripeChunk],
        program: Program, tier: str,
    ) -> "_DeviceRun":
        """Execute one device's chunks (full-size chunks batched into one
        compiled call on the jit/kernel tiers, the rest singly).

        Chunks the array planner flagged ``reconstruct`` (their xor data
        member is OFFLINE) never touch this device directly — they rebuild
        through the array's degraded read and execute over the host buffer.
        A chunk whose member dies BETWEEN planning and execution retries the
        same way on redundant arrays; raid0 keeps the PR 2 clean-error
        contract and degrades the whole offload."""
        device = self.array.devices[dev_idx]
        stripe = self.array.stripe_blocks
        direct = [c for c in dev_chunks if not c.reconstruct]
        recon = [c for c in dev_chunks if c.reconstruct]
        full = [c for c in direct if c.n_blocks == stripe]
        rest = [c for c in direct if c.n_blocks != stripe]
        run = _DeviceRun({})
        t_worker = time.perf_counter()
        # a single full chunk reuses the plain single-chunk executable
        # (shared with NvmCsd) instead of compiling a batch-of-1 variant
        if tier in (CsdTier.JIT, CsdTier.KERNEL) and len(full) > 1:
            try:
                run.merge(self._run_batched(device, zone_id, full, program,
                                            tier))
                run.insns += program.n_insns * len(full) * (
                    stripe // self.pages_per_read)
                run.batched += len(full)
                run.degraded += sum(1 for c in full if c.degraded)
            except (ZNSError, TransientIOError) as e:
                # the member died mid-batch: re-run its chunks one by one so
                # each can fall back to degraded reconstruction
                self._member_failed(dev_idx, zone_id, e)
                rest = full + rest
        else:
            rest = full + rest
        # every reconstruct chunk's survivor reads go in flight UP FRONT,
        # BEFORE the direct-chunk execution loop: the ring elapses their
        # emulated transfers under direct execution (exactly as _run_batched
        # overlaps healthy group reads); execution consumes each as it
        # retires
        recon_futs = []
        for c in recon:
            try:
                recon_futs.append(
                    (c, self.array.submit_read(zone_id, c.logical_off,
                                               c.n_blocks)))
            except (ZNSError, TransientIOError) as e:
                raise ArrayOffloadError(
                    f"offload failed: chunk {c.index} of zone {zone_id} is "
                    f"unrecoverable under {self.array.redundancy}: {e}"
                ) from e
        for c in rest:
            try:
                result = execute_extent(
                    device, program, zone_id, c.local_off, c.n_blocks,
                    tier=tier, pages_per_read=self.pages_per_read,
                    cache=self.cache, prefetch_depth=self.prefetch_depth,
                )
            except (ZNSError, TransientIOError) as e:
                self._member_failed(dev_idx, zone_id, e)
                self._run_chunk_degraded(zone_id, c, program, tier, run)
                continue
            if c.degraded:
                run.degraded += 1
            run.vals[c.index] = result.value
            run.compile_s += result.compile_seconds
            run.insns += result.insns_executed
            run.read_s += result.read_seconds
            run.compute_s += result.exec_seconds
            run.hits += result.cache_hits
            run.misses += result.cache_misses
        for c, fut in recon_futs:
            self._run_chunk_degraded(zone_id, c, program, tier, run, fut=fut)
        # overlap WITHIN this worker: transfer+compute time that exceeded the
        # worker's own wall clock must have run concurrently (the prefetcher)
        wall = time.perf_counter() - t_worker - run.compile_s
        run.overlap_s = max(run.read_s + run.compute_s - max(wall, 0.0), 0.0)
        return run

    def _member_failed(self, dev_idx: int, zone_id: int,
                   e: Exception) -> None:
        """Raise the PR 2 clean degradation error when the array has no
        redundancy to absorb the member failure; otherwise return and let
        the caller reconstruct."""
        if self.array.redundancy == "raid0":
            raise ArrayOffloadError(
                f"offload degraded: member device {dev_idx} failed on zone "
                f"{zone_id}: {e}"
            ) from e

    def _run_chunk_degraded(self, zone_id: int, c: StripeChunk,
                            program: Program, tier: str,
                            run: "_DeviceRun", *,
                            fut=None) -> None:
        """Execute one chunk whose member cannot serve it: rebuild the bytes
        through the array's degraded read (raid1 mirror redirect / xor
        survivor reconstruction, riding the completion ring) and run the
        SAME execution tier over the host buffer — bit-identical results by
        construction. Pass a pre-submitted ``fut`` to overlap many chunks'
        reconstruction transfers (the planned-degraded fan-out does)."""
        try:
            if fut is None:
                fut = self.array.submit_read(zone_id, c.logical_off,
                                             c.n_blocks)
            flat = np.asarray(fut.result(self.io_timeout_s))
        except (ZNSError, TransientIOError) as e:
            raise ArrayOffloadError(
                f"offload failed: chunk {c.index} of zone {zone_id} is "
                f"unrecoverable under {self.array.redundancy}: {e}"
            ) from e
        src = _ExtentSource(self.array.block_bytes, c.local_off, flat)
        result = execute_extent(
            src, program, zone_id, c.local_off, c.n_blocks,
            tier=tier, pages_per_read=self.pages_per_read,
            cache=self.cache, prefetch_depth=0,
        )
        run.vals[c.index] = result.value
        run.compile_s += result.compile_seconds
        run.insns += result.insns_executed
        run.read_s += result.read_seconds + fut.service_seconds
        run.compute_s += result.exec_seconds
        run.hits += result.cache_hits
        run.misses += result.cache_misses
        run.degraded += 1

    def _run_batched(
        self, device, zone_id: int, full: list[StripeChunk], program: Program,
        tier: str,
    ) -> "_DeviceRun":
        """Execute all full-size chunks of one device through batched compiled
        calls — ONE vmapped XLA call (jit tier) or ONE grid-batched Pallas
        call (kernel tier) per chunk group. Full chunks of a device are
        contiguous in member-local space, so one read covers each group.

        Read/compute overlap rides the completion ring: EVERY group's device
        read is submitted up front (the zone's virtual-time queue serializes
        their emulated transfers in order), so group ``g+1``'s transfer
        elapses while group ``g`` executes — in-flight depth is the number of
        groups, with no prefetch pool and no thread parked per read.

        raid0/xor full chunks of one device are contiguous in member-local
        space, so ONE read covers each group; raid1's round-robin replica
        assignment interleaves the mirror pair by row, so a group may be
        member-locally discontiguous — those groups read per chunk (all
        still in flight up front) and stack for the one compiled call.
        """
        stripe = self.array.stripe_blocks
        dtype = np.dtype(program.input_dtype)
        page_elems, chunk_pages = extent_geometry(
            self.array.block_bytes, dtype, stripe, self.pages_per_read)
        m = len(full)
        # Split into overlap groups, then bucket the group size to a
        # power of two and zero-pad the tail group, so compiles stay
        # O(#programs x log(max chunks/device)) instead of one per distinct
        # per-device chunk count; pad-row outputs are discarded below. Floor
        # of 2: a batch-of-1 variant would duplicate the plain single-chunk
        # executable (the degenerate case _run_device_chunks already routes
        # around) at the cost of an extra XLA compile.
        n_groups = max(min(self.prefetch_depth, m), 1)
        m_b = max(1 << (-(-m // n_groups) - 1).bit_length(), 2)
        groups = [full[i:i + m_b] for i in range(0, m, m_b)]

        run = _DeviceRun({})

        def group_read(g: list[StripeChunk]):
            contiguous = all(g[i + 1].local_off == g[i].local_off + stripe
                             for i in range(len(g) - 1))
            if contiguous:
                return device.submit_read(zone_id, g[0].local_off,
                                          len(g) * stripe, dtype=dtype)
            return [device.submit_read(zone_id, c.local_off, stripe,
                                       dtype=dtype) for c in g]

        futs = [group_read(g) for g in groups]
        if tier == CsdTier.KERNEL:
            from repro.kernels.zone_filter import ops as zf_ops
            key = ("kernel_batched", program, m_b, chunk_pages, page_elems)
            builder = lambda: zf_ops.kernel_program_batched(
                program, m_b, chunk_pages, page_elems)
        else:
            key = ("jit_batched", program, m_b, chunk_pages, page_elems)
            builder = lambda: jit_program_batched(
                program, m_b, chunk_pages, page_elems)
        jp, compile_s, hit = self.cache.get_or_build(key, builder)
        run.compile_s += compile_s
        run.hits += int(hit)
        run.misses += int(not hit)

        reg = _registry()
        for group, fut in zip(groups, futs):
            # read_wait = wall time this worker BLOCKED on the group's ring
            # completion (zero when earlier groups' execution covered the
            # transfer) — the number that grows if fan-out serializes on I/O
            t_w = time.perf_counter()
            with _trace.span("worker.read_wait", group=len(group)):
                if isinstance(fut, list):
                    raws = [f.result(self.io_timeout_s) for f in fut]
                    run.read_s += sum(f.service_seconds for f in fut)
                else:
                    raw = fut.result(self.io_timeout_s)
                    # emulated transfer time of this group (the time the ring
                    # hid under earlier groups' execution; same meaning the
                    # thread-backed fetch wall-clock had)
                    run.read_s += fut.service_seconds
            reg.histogram("sched.worker.read_wait_seconds").observe(
                time.perf_counter() - t_w)
            t_s = time.perf_counter()
            with _trace.span("worker.stage"):
                if isinstance(fut, list):
                    pages = np.stack([r.reshape(chunk_pages, page_elems)
                                      for r in raws])
                else:
                    pages = raw.reshape(len(group), chunk_pages, page_elems)
                if len(group) != m_b:
                    pages = np.concatenate(
                        [pages, np.zeros((m_b - len(group), chunk_pages,
                                          page_elems), dtype)])
            reg.histogram("sched.worker.stage_seconds").observe(
                time.perf_counter() - t_s)
            t0 = time.perf_counter()
            with _trace.span("worker.compute", group=len(group)):
                out = jp(pages)
            if isinstance(out, tuple):
                bufs, ns = (np.asarray(v) for v in out)
                for i, c in enumerate(group):
                    run.vals[c.index] = (bufs[i], ns[i])
            else:
                out = np.asarray(out)
                for i, c in enumerate(group):
                    run.vals[c.index] = out[i]
            dt = time.perf_counter() - t0
            run.compute_s += dt
            reg.histogram("sched.worker.compute_seconds").observe(dt)
        return run

    # ----------------------------------------------------------- combiner
    def _combine(self, program: Program, ordered: list[object]) -> object:
        """Re-reduce per-chunk results in logical stripe order — the
        scatter-gather step. Semantics match :func:`repro.core.vm.run_oracle`
        over the concatenated logical stream."""
        term = program.terminal.op
        dtype = np.dtype(program.input_dtype)
        if term == OpCode.RED_COUNT:
            return np.int64(sum(int(v) for v in ordered))
        if term == OpCode.RED_SUM:
            widen = _SUM_WIDEN[dtype]
            if np.issubdtype(widen, np.floating):
                # Kahan compensated accumulation over the per-chunk partials,
                # in logical stripe order. The partials themselves depend only
                # on the chunk decomposition (stripe_blocks), not on how many
                # devices the chunks landed on — so with compensation the
                # re-reduction is bit-identical for every array width over
                # the same logical data.
                acc = widen(0)
                comp = widen(0)
                for v in ordered:
                    y = widen(np.asarray(v)[()]) - comp
                    t = widen(acc + y)
                    comp = widen((t - acc) - y)
                    acc = t
                return acc
            acc = widen(0)
            for v in ordered:
                acc = widen(acc + widen(np.asarray(v)[()]))
            return acc
        if term == OpCode.RED_MIN:
            return dtype.type(np.minimum.reduce(
                [np.asarray(v, dtype)[()] for v in ordered]))
        if term == OpCode.RED_MAX:
            return dtype.type(np.maximum.reduce(
                [np.asarray(v, dtype)[()] for v in ordered]))
        if term == OpCode.RED_HIST:
            acc = np.zeros(program.terminal.imm[2], np.int64)
            for v in ordered:
                acc += np.asarray(v, np.int64)
            return acc
        if term in (OpCode.SELECT, OpCode.SELECT_REC):
            cap = program.select_capacity
            parts: list[np.ndarray] = []
            filled = 0
            total = 0
            for v in ordered:
                buf, n = np.asarray(v[0]), int(v[1])
                total += n
                if filled < cap and n > 0:
                    take = min(n, cap, cap - filled)
                    parts.append(buf[:take])
                    filled += take
            if term == OpCode.SELECT_REC:
                stride = program.insns[0].imm[0]
                out = np.zeros((cap, stride), dtype)
            else:
                out = np.zeros((cap,), dtype)
            if parts:
                cat = np.concatenate(parts, axis=0)
                out[: cat.shape[0]] = cat
            return out, np.int64(total)
        raise AssertionError(term)
