"""Append-only benchmark trajectories + the latest-vs-best report.

``run.py --json`` used to overwrite each ``BENCH_*.json`` with the latest
run, so the perf history across PRs lived only in git archaeology. Each
file is now a trajectory document::

    {"trajectory": [ {..payload.., "timestamp": "..."}, ... ]}

Every ``--json`` run APPENDS a timestamped entry; a legacy single-object
file (the pre-trajectory format: a bare ``{"suites": ...}`` payload) is
migrated in place on first write by becoming the trajectory's first entry
(with ``timestamp: null`` — its run time was never recorded). Retention is
bounded (default ``MAX_ENTRIES``, overridable per call): the oldest entries
fall off first.

Run as a script it prints the latest-vs-best report per suite row (``make
bench-report``)::

    python benchmarks/trajectory.py [BENCH_foo.json ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

__all__ = ["append_entry", "report", "MAX_ENTRIES"]

# bound the file size: benchmarks run per-PR, so 50 entries is a year-scale
# window of history while keeping the checked-in JSON reviewable
MAX_ENTRIES = 50


def _load_trajectory(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            old = json.load(f)
    except (json.JSONDecodeError, OSError):
        return []   # corrupt file: start a fresh trajectory, don't crash CI
    if isinstance(old, dict) and isinstance(old.get("trajectory"), list):
        return old["trajectory"]
    if isinstance(old, dict):
        # legacy single-object payload -> first trajectory entry
        old.setdefault("timestamp", None)
        return [old]
    return []


def append_entry(path: str, payload: dict, *,
                 retention: int = MAX_ENTRIES) -> dict:
    """Append ``payload`` (timestamped now) to the trajectory at ``path``,
    migrating a legacy single-object file on first write and keeping only
    the newest ``retention`` entries. Returns the full document written."""
    if retention <= 0:
        raise ValueError("retention must be positive")
    entry = dict(payload)
    entry["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    trajectory = _load_trajectory(path)
    trajectory.append(entry)
    doc = {"trajectory": trajectory[-retention:]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return doc


# -------------------------------------------------------------- reporting
def _entry_rows(entry: dict) -> dict[str, float]:
    """Flatten one trajectory entry to ``{"suite/row": us_per_call}``."""
    out: dict[str, float] = {}
    for suite, rows in (entry.get("suites") or {}).items():
        for r in rows:
            us = r.get("us_per_call")
            if isinstance(us, (int, float)):
                out[f"{suite}/{r.get('name', '?')}"] = float(us)
    return out


def report(paths: list[str]) -> list[str]:
    """Latest-vs-best lines per suite row across each file's trajectory.

    'best' is the minimum us_per_call the row ever recorded; the ratio
    column makes drift visible without diffing JSON (>=1.25x is flagged —
    wide enough that CI-machine noise doesn't cry wolf)."""
    lines: list[str] = []
    for path in paths:
        traj = _load_trajectory(path)
        if not traj:
            lines.append(f"== {path}: no trajectory")
            continue
        best: dict[str, float] = {}
        for entry in traj:
            for name, us in _entry_rows(entry).items():
                if name not in best or us < best[name]:
                    best[name] = us
        latest = traj[-1]
        lines.append(f"== {path} ({len(traj)} entries, latest "
                     f"{latest.get('timestamp')})")
        rows = _entry_rows(latest)
        if not rows:
            lines.append("   (latest entry has no numeric rows)")
            continue
        lines.append(f"   {'row':<44}{'latest_us':>12}{'best_us':>12}"
                     f"{'vs_best':>9}")
        for name in sorted(rows):
            us, b = rows[name], best[name]
            if b > 0:
                ratio = us / b
                flag = "  <-- drift" if ratio >= 1.25 else ""
                ratio_s = f"{ratio:>8.2f}x"
            else:
                # zero-cost marker rows (pure-derived suites) have no ratio
                ratio_s, flag = f"{'n/a':>9}", ""
            lines.append(f"   {name:<44}{us:>12.1f}{b:>12.1f}{ratio_s}{flag}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="print latest-vs-best per benchmark trajectory")
    ap.add_argument("paths", nargs="*",
                    help="trajectory files (default: ./BENCH_*.json)")
    args = ap.parse_args(argv)
    paths = args.paths or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("no trajectory files found", file=sys.stderr)
        return 1
    for line in report(paths):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
