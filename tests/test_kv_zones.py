"""Zoned KV-cache pool: allocation, append, eviction-reset, paged attention
equivalence against a flat cache."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.kv_zones import KVZoneError, KVZonePool
from repro.kernels.paged_attn.ref import paged_attention_ref

KV, H, HD = 2, 4, 16


def pool(**kw):
    args = dict(num_zones=8, zone_len=4, kv_heads=KV, head_dim=HD,
                max_zones_per_seq=3, dtype=jnp.float32)
    args.update(kw)
    return KVZonePool(**args)


def tok(rng):
    return (jnp.asarray(rng.standard_normal((KV, HD)), jnp.float32),
            jnp.asarray(rng.standard_normal((KV, HD)), jnp.float32))


def test_zone_allocation_on_demand():
    p = pool()
    p.add_sequence(0)
    rng = np.random.default_rng(0)
    for i in range(9):                      # crosses two zone boundaries
        p.append(0, *tok(rng))
    tab, lengths = p.zone_table([0])
    assert int(lengths[0]) == 9
    assert (np.asarray(tab[0]) >= 0).sum() == 3   # ceil(9/4) zones


def test_attend_matches_flat_cache():
    p = pool()
    rng = np.random.default_rng(1)
    p.add_sequence(7)
    ks, vs = [], []
    for _ in range(6):
        k, v = tok(rng)
        ks.append(k); vs.append(v)
        p.append(7, k, v)
    q = jnp.asarray(rng.standard_normal((1, H, HD)), jnp.float32)
    out = p.attend([7], q)
    # flat reference
    kf = jnp.stack(ks)[None]                 # [1, 6, KV, HD]
    vf = jnp.stack(vs)[None]
    qh = q.reshape(1, KV, H // KV, HD).astype(jnp.float32) * HD ** -0.5
    logits = jnp.einsum("bkgh,bskh->bkgs", qh, kf)
    att = jnp.exp(logits - logits.max(-1, keepdims=True))
    att = att / att.sum(-1, keepdims=True)
    want = jnp.einsum("bkgs,bskh->bkgh", att, vf).reshape(1, H, HD)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_eviction_resets_and_reuses_zones():
    p = pool(num_zones=3, max_zones_per_seq=3)
    rng = np.random.default_rng(2)
    p.add_sequence(0)
    for _ in range(12):                      # all 3 zones
        p.append(0, *tok(rng))
    with pytest.raises(KVZoneError):         # pool exhausted
        p.add_sequence(1)
        p.append(1, *tok(rng))
    p.evict(0)
    assert p.stats["zones_reset"] == 3
    for _ in range(4):                       # reclaimed zones serve seq 1
        p.append(1, *tok(rng))
    assert p.utilization() == pytest.approx(1 / 3)


def test_max_zones_per_seq_enforced():
    p = pool(max_zones_per_seq=1)
    p.add_sequence(0)
    rng = np.random.default_rng(3)
    for _ in range(4):
        p.append(0, *tok(rng))
    with pytest.raises(KVZoneError):
        p.append(0, *tok(rng))


def test_multi_sequence_isolation():
    p = pool()
    rng = np.random.default_rng(4)
    p.add_sequence(0)
    p.add_sequence(1)
    for _ in range(5):
        p.append(0, *tok(rng))
    for _ in range(3):
        p.append(1, *tok(rng))
    tab, lengths = p.zone_table([0, 1])
    assert int(lengths[0]) == 5 and int(lengths[1]) == 3
    z0 = set(int(z) for z in np.asarray(tab[0]) if z >= 0)
    z1 = set(int(z) for z in np.asarray(tab[1]) if z >= 0)
    assert not z0 & z1                        # no zone shared
    q = jnp.asarray(rng.standard_normal((2, H, HD)), jnp.float32)
    out = p.attend([0, 1], q)
    ref = paged_attention_ref(q, p.k, p.v, tab, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
