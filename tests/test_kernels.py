"""Pallas kernel validation (interpret=True on CPU) against pure-jnp oracles,
with hypothesis sweeps over shapes/dtypes and the verified-Program bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CsdTier, NvmCsd, filter_count, run_oracle
from repro.core.programs import Instruction, OpCode, Program
from repro.kernels.zone_filter.kernel import filtered_reduce_pallas
from repro.kernels.zone_filter.ops import (
    kernelizable, run_program_kernel, zone_filter_count, zone_reduce,
)
from repro.kernels.zone_filter.ref import zone_filter_count_ref, zone_reduce_ref
from repro.kernels.paged_attn.ops import paged_attention
from repro.kernels.paged_attn.ref import paged_attention_ref
from repro.zns import ZonedDevice


# ------------------------------------------------------------- zone_filter

def _pages(n_pages, page_elems, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if np.dtype(dtype).kind == "f":
        return jnp.asarray(rng.standard_normal((n_pages, page_elems)) * 50,
                           dtype)
    info = np.iinfo(dtype)
    return jnp.asarray(rng.integers(info.min // 2, info.max // 2,
                                    (n_pages, page_elems)), dtype)


def test_zone_filter_count_matches_ref_paper_shape():
    """Paper geometry (scaled): 4 KiB pages of int32."""
    pages = _pages(2048, 1024, jnp.int32)
    got = zone_filter_count(pages, 2**30)
    want = zone_filter_count_ref(pages, 2**30)
    assert int(got) == int(want)


@settings(max_examples=25, deadline=None)
@given(
    n_pages=st.sampled_from([1, 2, 8, 64, 200, 513]),
    page_elems=st.sampled_from([128, 256, 1024]),
    dtype=st.sampled_from(["int32", "float32"]),
    kind=st.sampled_from(["count", "sum", "min", "max"]),
    seed=st.integers(0, 2**16),
)
def test_zone_reduce_sweep(n_pages, page_elems, dtype, kind, seed):
    pages = _pages(n_pages, page_elems, jnp.dtype(dtype), seed)
    if kind == "sum" and dtype == "int32":
        pages = (pages >> 21).astype(jnp.int32)   # keep exact in i32 partials
    thr = 0 if dtype == "int32" else 0.0
    got = zone_reduce(pages, kind, thr)
    want = zone_reduce_ref(pages, kind, thr)
    if kind == "sum" and dtype == "float32":
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)
    else:
        assert np.asarray(got) == np.asarray(want), (kind, dtype)


@settings(max_examples=20, deadline=None)
@given(block_pages=st.sampled_from([1, 3, 8, 64, 512, 4096]),
       seed=st.integers(0, 2**16))
def test_zone_filter_block_shape_invariance(block_pages, seed):
    """Any VMEM block tiling gives the identical result (tiling is a pure
    performance knob — the system invariant hypothesis checks)."""
    pages = _pages(96, 256, jnp.int32, seed)
    want = zone_filter_count_ref(pages, 12345)
    got = zone_filter_count(pages, 12345, block_pages=block_pages)
    assert int(got) == int(want)


PROGRAMS = [
    filter_count("int32", "gt", 2**30),
    filter_count("float32", "le", 0.0),
    Program("int32", (Instruction(OpCode.AND, 0xFF), Instruction(OpCode.CMP_EQ, 7),
                      Instruction(OpCode.RED_COUNT)), name="mask_eq"),
    Program("float32", (Instruction(OpCode.MUL, 2.0),
                        Instruction(OpCode.CMP_GE, 10.0),
                        Instruction(OpCode.RED_SUM)), name="scaled_sum"),
    Program("int32", (Instruction(OpCode.ABS), Instruction(OpCode.RED_MAX))),
    Program("int32", (Instruction(OpCode.SHR, 3), Instruction(OpCode.CMP_GT, 1000),
                      Instruction(OpCode.RED_MIN)), name="shift_min"),
]


@pytest.mark.parametrize("program", PROGRAMS, ids=lambda p: p.name)
def test_program_kernel_tier_matches_oracle(program):
    pages = np.asarray(_pages(64, 1024, jnp.dtype(program.input_dtype), 11))
    assert kernelizable(program)
    got = np.asarray(run_program_kernel(program, pages))
    want = run_oracle(program, pages)
    np.testing.assert_allclose(got, np.asarray(want, got.dtype), rtol=1e-6)


def test_csd_kernel_tier_end_to_end():
    """NvmCsd with tier=KERNEL: ZNS zone -> Pallas kernel -> scalar back."""
    dev = ZonedDevice(num_zones=1, zone_bytes=1024 * 1024, block_bytes=4096)
    rng = np.random.default_rng(5)
    data = rng.integers(0, 2**31, (256, 1024), dtype=np.int32)
    dev.zone_append(0, data)
    csd = NvmCsd(dev)
    program = filter_count("int32", "gt", 2**30)
    stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.KERNEL)
    assert int(csd.nvm_cmd_bpf_result()) == int(run_oracle(program, data))
    assert stats.bytes_returned <= 8
    assert stats.movement_saved_bytes > 1_000_000


def test_int_sum_not_kernelizable_falls_back():
    """RED_SUM over ints must preserve i64 semantics -> JIT tier fallback."""
    from repro.core import filter_sum
    prog = filter_sum("int32", "gt", 0)
    assert not kernelizable(prog)
    dev = ZonedDevice(num_zones=1, zone_bytes=256 * 1024, block_bytes=4096)
    data = np.random.default_rng(0).integers(-2**30, 2**30, (64, 1024),
                                             dtype=np.int32)
    dev.zone_append(0, data)
    csd = NvmCsd(dev)
    csd.nvm_cmd_bpf_run(prog, 0, tier=CsdTier.KERNEL)  # silently falls back
    assert int(csd.nvm_cmd_bpf_result()) == int(run_oracle(prog, data))


# -------------------------------------------------------------- paged_attn

def _paged_case(B, H, KV, hd, NZ, ZL, MZ, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((NZ, ZL, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((NZ, ZL, KV, hd)), dtype)
    # each sequence gets a random set of distinct zones and a valid length
    ztab = np.full((B, MZ), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    for b in range(B):
        nz = rng.integers(1, MZ + 1)
        ztab[b, :nz] = rng.choice(NZ, size=nz, replace=False)
        lengths[b] = rng.integers(1, nz * ZL + 1)
    return q, k, v, jnp.asarray(ztab), jnp.asarray(lengths)


@pytest.mark.parametrize("B,H,KV,hd,NZ,ZL,MZ", [
    (1, 4, 4, 32, 4, 16, 2),     # MHA
    (2, 8, 2, 64, 8, 32, 3),     # GQA
    (4, 8, 1, 128, 16, 128, 4),  # MQA, bigger zones
])
def test_paged_attention_matches_ref(B, H, KV, hd, NZ, ZL, MZ):
    q, k, v, ztab, lengths = _paged_case(B, H, KV, hd, NZ, ZL, MZ, seed=B)
    got = paged_attention(q, k, v, ztab, lengths)
    want = paged_attention_ref(q, k, v, ztab, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_paged_attention_random_tables(seed):
    q, k, v, ztab, lengths = _paged_case(3, 6, 2, 32, 8, 16, 4, seed=seed)
    got = paged_attention(q, k, v, ztab, lengths)
    want = paged_attention_ref(q, k, v, ztab, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_bf16():
    q, k, v, ztab, lengths = _paged_case(2, 8, 4, 64, 6, 32, 3, seed=9,
                                         dtype=jnp.bfloat16)
    got = paged_attention(q, k, v, ztab, lengths)
    want = paged_attention_ref(q, k, v, ztab, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
