"""ZNS device semantics: zone states, append-only writes, bounds, reset/GC."""
import numpy as np
import pytest

from repro.zns import (
    OutOfBoundsError,
    ZonedDevice,
    ZoneFullError,
    ZoneState,
    ZoneStateError,
)


@pytest.fixture
def dev():
    # small device: 4 zones x 64 KiB, 4 KiB blocks
    return ZonedDevice(num_zones=4, zone_bytes=64 * 1024, block_bytes=4096)


def test_initial_state(dev):
    assert all(z.state == ZoneState.EMPTY for z in dev.report_zones())
    assert all(z.write_pointer == 0 for z in dev.report_zones())
    assert dev.zone_blocks == 16
    assert dev.lba_size == 4096


def test_append_advances_write_pointer(dev):
    data = np.arange(1024, dtype=np.int32)  # exactly one block
    start = dev.zone_append(0, data)
    assert start == 0
    z = dev.zone(0)
    assert z.write_pointer == 1 and z.state == ZoneState.OPEN
    start2 = dev.zone_append(0, data)
    assert start2 == 1  # append-only: lands at the write pointer


def test_append_pads_partial_block(dev):
    dev.zone_append(0, b"xyz")
    out = dev.read_blocks(0, 0, 1)
    assert bytes(out[:3]) == b"xyz"
    assert not out[3:].any()


def test_read_roundtrip(dev):
    data = np.random.default_rng(0).integers(0, 2**31, 4096, dtype=np.int32)
    dev.zone_append(1, data)
    out = dev.read_blocks(1, 0, 4)
    assert np.array_equal(np.frombuffer(out.tobytes(), dtype=np.int32), data)


def test_read_beyond_write_pointer_rejected(dev):
    dev.zone_append(0, np.zeros(1024, np.int32))
    with pytest.raises(OutOfBoundsError):
        dev.read_blocks(0, 0, 2)  # only 1 block written
    with pytest.raises(OutOfBoundsError):
        dev.read_blocks(0, -1, 1)


def test_zone_full_and_overflow(dev):
    whole = np.zeros(16 * 1024, np.uint8 if False else np.int32)[: 16 * 1024]
    whole = np.zeros(16 * 1024, np.int32)  # 16 blocks = whole zone
    dev.zone_append(2, whole)
    assert dev.zone(2).state == ZoneState.FULL
    with pytest.raises(ZoneStateError):
        dev.zone_append(2, b"more")


def test_append_larger_than_remaining_rejected(dev):
    dev.zone_append(0, np.zeros(15 * 1024, np.int32))  # 15 of 16 blocks
    with pytest.raises(ZoneFullError):
        dev.zone_append(0, np.zeros(2 * 1024, np.int32))  # needs 2 blocks


def test_reset_is_host_managed_gc(dev):
    dev.zone_append(0, np.zeros(1024, np.int32))
    dev.reset_zone(0)
    z = dev.zone(0)
    assert z.state == ZoneState.EMPTY and z.write_pointer == 0
    assert z.reset_count == 1
    with pytest.raises(OutOfBoundsError):
        dev.read_blocks(0, 0, 1)  # data is gone from the host's view


def test_finish_seals_zone(dev):
    dev.zone_append(0, np.zeros(1024, np.int32))
    dev.finish_zone(0)
    assert dev.zone(0).state == ZoneState.FULL
    with pytest.raises(ZoneStateError):
        dev.zone_append(0, b"nope")


def test_offline_zone_faults(dev):
    dev.zone_append(0, np.zeros(1024, np.int32))
    dev.set_offline(0)
    with pytest.raises(ZoneStateError):
        dev.read_blocks(0, 0, 1)
    with pytest.raises(ZoneStateError):
        dev.reset_zone(0)


def test_max_open_zones():
    dev = ZonedDevice(num_zones=4, zone_bytes=64 * 1024, block_bytes=4096,
                      max_open_zones=2)
    dev.zone_append(0, b"a")
    dev.zone_append(1, b"b")
    with pytest.raises(ZoneStateError):
        dev.zone_append(2, b"c")


def test_file_backed_persistence(tmp_path):
    path = tmp_path / "zns.bin"
    dev = ZonedDevice(num_zones=2, zone_bytes=64 * 1024, block_bytes=4096,
                      backing_file=path)
    payload = np.arange(2048, dtype=np.int32)
    dev.zone_append(0, payload)
    dev.flush()
    # a new device over the same file sees the bytes (zone metadata is the
    # checkpoint manifest's job, which re-derives write pointers on recovery)
    dev2 = ZonedDevice(num_zones=2, zone_bytes=64 * 1024, block_bytes=4096,
                       backing_file=path)
    dev2.zone(0).write_pointer = 2  # recovery scan sets the pointer
    out = dev2.read_blocks(0, 0, 2)
    assert np.array_equal(np.frombuffer(out.tobytes(), np.int32), payload)


def test_stats_accounting(dev):
    dev.zone_append(0, np.zeros(2048, np.int32))
    dev.read_blocks(0, 0, 2)
    dev.reset_zone(0)
    assert dev.stats["blocks_appended"] == 2
    assert dev.stats["blocks_read"] == 2
    assert dev.stats["zone_resets"] == 1
