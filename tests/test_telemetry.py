"""Telemetry layer: span tracing + metrics registry.

These tests deliberately avoid ``registry().reset()``: the global registry
carries collectors wired at import time (the compile cache registers its
aggregate collector when ``repro.core.cache`` first loads), and resetting it
would silently unhook them for every later test in the process. Everything
here runs on private ``MetricsRegistry`` instances or on the trace module,
whose ``clear()`` is safe to call per test.
"""
from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.telemetry import trace
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, StatsView,
                                     default_latency_buckets, registry)


@pytest.fixture(autouse=True)
def _fresh_trace():
    trace.set_enabled(False)
    trace.clear()
    yield
    trace.set_enabled(False)
    trace.clear()


# ---------------------------------------------------------------- metrics
class TestCounterGauge:
    def test_counter_inc_and_set(self):
        c = Counter("x")
        c.inc()
        c.inc(41)
        assert c.value == 42
        c.set(0)
        assert c.value == 0

    def test_gauge_set_and_max(self):
        g = Gauge("depth")
        g.set(3.0)
        g.max(2.0)
        assert g.value == 3.0
        g.max(7.5)
        assert g.value == 7.5

    def test_counter_stress_exact_totals(self):
        """8 writer threads, every increment lands: the property the old
        unlocked ``stats[k] += n`` dicts did NOT have."""
        reg = MetricsRegistry("stress")
        c = reg.counter("hits")
        h = reg.histogram("lat")
        n_threads, per_thread = 8, 5000
        start = threading.Barrier(n_threads)

        def work():
            start.wait()
            for i in range(per_thread):
                c.inc()
                h.observe(1e-5 * (1 + i % 7))

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == n_threads * per_thread
        assert h.count == n_threads * per_thread


class TestHistogram:
    def test_quantiles_vs_numpy(self):
        """Interpolated p50/p95/p99 must land within one bucket width of
        numpy's exact percentiles for a log-uniform latency sample."""
        rng = np.random.default_rng(7)
        sample = 10 ** rng.uniform(-5.5, -1.5, 20_000)   # 3µs .. 30ms
        h = Histogram("lat")
        for v in sample:
            h.observe(float(v))
        assert h.count == sample.size
        assert h.sum == pytest.approx(sample.sum())
        for q in (50, 95, 99):
            exact = float(np.percentile(sample, q))
            est = h.percentile(q)
            # bucket geometry is ratio-2: the estimate may be off by at most
            # one bucket, i.e. within [exact/2, exact*2]
            assert exact / 2 <= est <= exact * 2, (q, exact, est)

    def test_exact_stats_and_bounds(self):
        h = Histogram("lat", buckets=[1.0, 2.0, 4.0])
        for v in (0.5, 1.5, 3.0, 100.0):
            h.observe(v)
        assert h.count == 4
        assert h.sum == pytest.approx(105.0)
        snap = h.snapshot()
        assert snap["min"] == 0.5
        assert snap["max"] == 100.0
        assert snap["count"] == 4
        # quantiles never exceed the observed max (overflow interpolates
        # toward max, not toward infinity)
        assert h.percentile(99) <= 100.0

    def test_empty(self):
        h = Histogram("lat")
        assert h.percentile(99) == 0.0      # explicit query stays defined
        snap = h.snapshot()
        assert snap["count"] == 0
        # no fabricated quantiles: an idle series must read as "no data",
        # not as p99=0.0 "perfect latency" (which would satisfy any SLO)
        assert not any(k.startswith("p") for k in snap)
        h.observe(1.0)
        snap = h.snapshot()
        assert snap["count"] == 1 and "p99" in snap

    def test_registry_snapshot_omits_empty_histogram_quantiles(self):
        reg = MetricsRegistry("t")
        reg.histogram("idle")
        reg.histogram("busy").observe(0.5)
        snap = reg.snapshot()
        assert "idle.count" in snap and "idle.p99" not in snap
        assert snap["busy.p99"] > 0.0

    def test_default_buckets_cover_emulated_io(self):
        b = default_latency_buckets()
        assert b[0] == pytest.approx(1e-6)
        assert b[-1] > 60.0
        assert list(b) == sorted(b)


class TestRegistry:
    def test_get_or_create_and_type_check(self):
        reg = MetricsRegistry("t")
        assert reg.counter("a") is reg.counter("a")
        with pytest.raises(TypeError):
            reg.gauge("a")

    def test_snapshot_delta(self):
        reg = MetricsRegistry("t")
        c = reg.counter("ops")
        h = reg.histogram("lat")
        g = reg.gauge("ratio")
        c.inc(10)
        h.observe(0.5)
        g.set(0.25)
        before = reg.snapshot()
        c.inc(5)
        h.observe(1.5)
        g.set(0.75)
        d = reg.delta(before)
        assert d["ops"] == 5
        assert d["lat.count"] == 1
        assert d["lat.sum"] == pytest.approx(1.5)
        assert d["ratio"] == 0.75          # gauges stay point-in-time

    def test_collectors_fold_in_and_failures_are_isolated(self):
        reg = MetricsRegistry("t")
        reg.counter("own").inc(1)
        reg.register_collector("ext", lambda: {"size": 3})
        reg.register_collector("dead", lambda: 1 / 0)
        snap = reg.snapshot()
        assert snap["ext.size"] == 3
        assert snap["own"] == 1
        assert not any(k.startswith("dead") for k in snap)

    def test_dump_is_textual(self):
        reg = MetricsRegistry("t")
        reg.counter("ops").inc(3)
        text = reg.dump()
        assert "ops" in text and "3" in text

    def test_global_registry_is_shared(self):
        assert registry() is registry()

    def test_stats_view_dict_semantics(self):
        reg = MetricsRegistry("t")
        a, b = reg.counter("a"), reg.counter("b")
        view = StatsView({"a": a, "b": b})
        a.inc(7)
        assert view["a"] == 7
        assert dict(view) == {"a": 7, "b": 0}
        assert len(view) == 2
        view["a"] = 0                       # the test-suite reset idiom
        assert a.value == 0
        with pytest.raises(TypeError):
            del view["a"]


# ------------------------------------------------------------------ trace
class TestTrace:
    def test_disabled_is_noop(self):
        assert not trace.enabled()
        with trace.span("nothing", tenant="t0"):
            trace.instant("marker")
            trace.event_complete("dev.read", 0.0, 1.0, track="dev0/z0")
        assert trace.drain() == []
        assert trace.dropped() == 0

    def test_span_nesting_inherits_tags(self):
        with trace.tracing(True):
            with trace.span("outer", tenant="t0", zone=3):
                with trace.span("inner", op="read"):
                    pass
        evs = {e["name"]: e for e in trace.drain()}
        assert evs["inner"]["tags"] == {"tenant": "t0", "zone": 3,
                                        "op": "read"}
        assert evs["outer"]["tags"] == {"tenant": "t0", "zone": 3}
        # inner closed first and nests inside outer's window
        assert evs["outer"]["ts"] <= evs["inner"]["ts"]
        assert (evs["inner"]["ts"] + evs["inner"]["dur"]
                <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-6)

    def test_spans_across_threads(self):
        """Each thread records into its own ring; nesting context does not
        leak between threads."""
        done = threading.Barrier(5)

        def work(i: int):
            with trace.span(f"thread{i}", idx=i):
                pass
            # hold every thread alive until all have recorded, so thread
            # idents (and therefore ring tids) cannot be reused
            done.wait()

        with trace.tracing(True):
            with trace.span("main", tenant="t0"):
                threads = [threading.Thread(target=work, args=(i,))
                           for i in range(4)]
                for t in threads:
                    t.start()
                done.wait()
                for t in threads:
                    t.join()
        evs = trace.drain()
        names = {e["name"] for e in evs}
        assert names == {"main"} | {f"thread{i}" for i in range(4)}
        by_name = {e["name"]: e for e in evs}
        tids = {e["tid"] for e in evs}
        assert len(tids) == 5               # five distinct rings
        # worker spans started from plain threads have no contextvar parent:
        # no tag leakage from "main"
        for i in range(4):
            assert by_name[f"thread{i}"]["tags"] == {"idx": i}

    def test_event_complete_lands_on_virtual_track(self):
        with trace.tracing(True):
            trace.event_complete("dev.read", 100.0, 0.002, track="dev0/z1",
                                 nblocks=8)
        (ev,) = trace.drain()
        assert ev["track"] == "dev0/z1"
        assert ev["ts"] == 100.0
        assert ev["dur"] == 0.002

    def test_ring_overflow_counts_drops(self):
        with trace.tracing(True):
            for _ in range(trace.RING_CAPACITY + 10):
                trace.instant("x")
        assert trace.dropped() == 10
        assert len(trace.drain()) == trace.RING_CAPACITY

    def test_chrome_export_round_trip(self, tmp_path):
        with trace.tracing(True):
            with trace.span("offload.execute", tenant="t0"):
                trace.instant("marker", note="hi")
            trace.event_complete("dev.read", 50.0, 0.001, track="dev0/z0")
        path = tmp_path / "trace.json"
        n = trace.export_chrome(str(path))
        doc = json.loads(path.read_text())
        evs = doc["traceEvents"]
        assert len(evs) == n
        assert doc["otherData"]["dropped_events"] == 0
        by_ph: dict[str, list] = {}
        for e in evs:
            by_ph.setdefault(e["ph"], []).append(e)
        assert set(by_ph) <= {"X", "M", "i"}
        # complete events carry µs ts/dur; the device event sits on pid 2
        dev = next(e for e in by_ph["X"] if e["name"] == "dev.read")
        assert dev["pid"] == 2
        assert dev["dur"] == pytest.approx(1000.0)   # 0.001 s -> 1000 µs
        host = next(e for e in by_ph["X"] if e["name"] == "offload.execute")
        assert host["pid"] == 1
        assert host["args"]["tenant"] == "t0"
        # metadata names both processes and every row
        meta_names = {m["args"]["name"] for m in by_ph["M"]
                      if m["name"] == "process_name"}
        assert meta_names == {"host threads", "device virtual time"}
        track_rows = {m["args"]["name"] for m in by_ph["M"]
                      if m["name"] == "thread_name" and m["pid"] == 2}
        assert track_rows == {"dev0/z0"}
        # timestamps are rebased: the earliest event starts near zero
        assert min(e["ts"] for e in by_ph["X"]) == pytest.approx(0.0, abs=1.0)

    def test_clear_forgets_everything(self):
        with trace.tracing(True):
            trace.instant("x")
        assert trace.drain()
        trace.clear()
        assert trace.drain() == []


# ------------------------------------------------------- instrumented code
class TestInstrumentation:
    def test_device_stats_view_and_histograms(self):
        from repro.zns import ZonedDevice
        dev = ZonedDevice(num_zones=1, zone_bytes=1 << 20, block_bytes=4096,
                          read_us_per_block=1.0, append_us_per_block=1.0)
        data = np.arange((1 << 18) // 4, dtype=np.int32)
        dev.zone_append(0, data)
        dev.read_blocks(0, 0, 4)
        assert dev.stats["blocks_appended"] > 0
        assert dev.stats["blocks_read"] == 4
        snap = dev.metrics.snapshot()
        assert snap["read.service_seconds.count"] >= 1
        assert snap["append.service_seconds.count"] >= 1
        dev.stats["blocks_read"] = 0        # legacy reset idiom still works
        assert dev.stats["blocks_read"] == 0

    def test_device_virtual_track_events(self):
        from repro.zns import ZonedDevice
        dev = ZonedDevice(num_zones=1, zone_bytes=1 << 20, block_bytes=4096,
                          read_us_per_block=1.0)
        dev.zone_append(0, np.arange(4096 // 4, dtype=np.int32))
        with trace.tracing(True):
            dev.read_blocks(0, 0, 1)
        evs = [e for e in trace.drain() if e["name"] == "dev.read"]
        assert len(evs) == 1
        assert evs[0]["track"] == f"dev{dev.dev_ordinal}/z0"
        assert evs[0]["dur"] == pytest.approx(1e-6, rel=0.5)

    def test_checkpoint_store_stats_migrated(self):
        from repro.train.checkpoint import ZonedCheckpointStore
        from repro.zns import ZonedDevice
        dev = ZonedDevice(num_zones=4, zone_bytes=1 << 20, block_bytes=4096)
        store = ZonedCheckpointStore(device=dev, keep=2)
        tree = {"w": np.arange(1024, dtype=np.int32)}
        store.save(0, tree)
        assert store.stats["bytes_copied"] >= tree["w"].nbytes
        got = store.restore(like=tree)
        assert np.array_equal(got["w"], tree["w"])
        snap = store.metrics.snapshot()
        assert snap["save_seconds.count"] == 1
        assert snap["restore_seconds.count"] == 1
        assert snap["bytes_viewed"] > 0

    def test_global_registry_sees_compile_cache(self):
        import repro.core.cache  # noqa: F401  (wires the collector)
        snap = registry().snapshot()
        assert "compile_cache.hits" in snap
        assert "compile_cache.live_caches" in snap
