"""Benchmark driver: one function per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines. Scaled-down sizes by default
(CI-friendly on 1 CPU core); pass --full for the paper's exact 256 MiB zone.
``--json`` additionally APPENDS a timestamped entry to the
``BENCH_hotpath.json`` trajectory (per-suite rows with parsed derived
metrics) — plus ``BENCH_async.json`` for the async completion-ring suite,
``BENCH_degraded.json`` for the redundancy / degraded-read suite,
``BENCH_profile.json`` for the traced fan-out profile,
``BENCH_rebuild.json`` for the self-healing recovery suite and
``BENCH_faults.json`` for the fault-injection suite when they ran — so
the perf trajectory is machine-readable across PRs (legacy single-object
files are migrated into trajectories on first write; see
``benchmarks/trajectory.py``); ``--budget SECONDS`` fails the run loudly
when it exceeds a wall-clock budget — the CI tripwire for hot-path
regressions.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

JSON_PATH = "BENCH_hotpath.json"
ASYNC_JSON_PATH = "BENCH_async.json"
DEGRADED_JSON_PATH = "BENCH_degraded.json"
PROFILE_JSON_PATH = "BENCH_profile.json"
HEALTH_JSON_PATH = "BENCH_health.json"
REBUILD_JSON_PATH = "BENCH_rebuild.json"
FAULTS_JSON_PATH = "BENCH_faults.json"


def _parse_derived(derived: str) -> dict:
    """'k1=v1;k2=v2' -> {k1: v1, ...} with numeric values parsed."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v.rstrip("x"))
            except ValueError:
                out[k] = v
    return out


def _row_record(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    try:
        us_per_call = float(us)
    except ValueError:
        us_per_call = None            # ERROR rows keep the raw text
    return {"name": name, "us_per_call": us_per_call,
            "derived": _parse_derived(derived) if us_per_call is not None
            else {"error": derived}}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact sizes (256 MiB zone, 5 runs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: filter,hotpath,toolchain,"
                         "pushdown,checkpoint,paged_attn,roofline,array,"
                         "async,degraded,profile,health,rebuild,faults")
    ap.add_argument("--list", action="store_true",
                    help="print the available suite names and exit")
    ap.add_argument("--json", action="store_true",
                    help=f"write per-suite results to {JSON_PATH}")
    ap.add_argument("--budget", type=float, default=None,
                    help="fail (exit 1) if the run exceeds this many seconds")
    args = ap.parse_args()

    from benchmarks import (bench_array, bench_async, bench_checkpoint,
                            bench_degraded, bench_faults, bench_filter,
                            bench_health, bench_hotpath, bench_paged_attn,
                            bench_profile, bench_pushdown, bench_rebuild,
                            bench_toolchain, roofline, trajectory)

    suites = {
        "filter": lambda: bench_filter.main(
            zone_mib=256 if args.full else 32, runs=5 if args.full else 3),
        "array": lambda: bench_array.main(
            data_mib=64 if args.full else 16, runs=5 if args.full else 3),
        "hotpath": lambda: bench_hotpath.main(
            data_mib=32 if args.full else 8, runs=5 if args.full else 3),
        "async": lambda: bench_async.main(
            data_mib=16 if args.full else 8, runs=3 if args.full else 2),
        "degraded": lambda: bench_degraded.main(
            data_mib=16 if args.full else 8, runs=5 if args.full else 3),
        "profile": lambda: bench_profile.main(
            data_mib=64 if args.full else 16, runs=5 if args.full else 3),
        "health": lambda: bench_health.main(
            data_mib=8 if args.full else 4, runs=5 if args.full else 3),
        "rebuild": lambda: bench_rebuild.main(
            data_mib=16 if args.full else 8, runs=5 if args.full else 3),
        "faults": lambda: bench_faults.main(
            data_mib=16 if args.full else 8, runs=5 if args.full else 3,
            stride=1 if args.full else 2),
        "toolchain": bench_toolchain.main,
        "pushdown": bench_pushdown.main,
        "checkpoint": bench_checkpoint.main,
        "paged_attn": bench_paged_attn.main,
        "roofline": roofline.main,
    }
    if args.list:
        for name in suites:
            print(name)
        return 0
    chosen = args.only.split(",") if args.only else list(suites)
    unknown = [n for n in chosen if n not in suites]
    if unknown:
        print(f"unknown suite(s): {', '.join(unknown)} "
              f"(try --list)", file=sys.stderr)
        return 2

    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    failures = 0
    results: dict[str, list[dict]] = {}
    for name in chosen:
        try:
            rows = suites[name]()
            for row in rows:
                print(row)
            results[name] = [_row_record(r) for r in rows]
        except Exception:
            failures += 1
            err = traceback.format_exc(limit=1)
            print(f"{name},ERROR,{err!r}")
            results[name] = [{"name": name, "us_per_call": None,
                              "derived": {"error": err}}]
    elapsed = time.perf_counter() - t0

    if args.json:
        payload = {
            "suites": results,
            "failures": failures,
            "elapsed_seconds": round(elapsed, 3),
            "full_sizes": bool(args.full),
        }
        trajectory.append_entry(JSON_PATH, payload)
        print(f"# appended to {JSON_PATH}", file=sys.stderr)
        for suite, path in (("async", ASYNC_JSON_PATH),
                            ("degraded", DEGRADED_JSON_PATH),
                            ("profile", PROFILE_JSON_PATH),
                            ("health", HEALTH_JSON_PATH),
                            ("rebuild", REBUILD_JSON_PATH),
                            ("faults", FAULTS_JSON_PATH)):
            if suite not in results:
                continue
            trajectory.append_entry(path, {"suites": {suite: results[suite]},
                                           "full_sizes": bool(args.full)})
            print(f"# appended to {path}", file=sys.stderr)

    if args.budget is not None and elapsed > args.budget:
        print(f"# BUDGET EXCEEDED: {elapsed:.1f}s > {args.budget:.1f}s "
              f"wall-clock budget — hot-path regression?", file=sys.stderr)
        return 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
