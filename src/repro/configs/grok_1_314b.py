"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072; MoE 8 experts top-2, attention logit softcap.
[hf:xai-org/grok-1; unverified]

8 experts don't divide a 16-way model axis, so EP shards the expert FFN dim
over "model" (TP-within-expert) instead of the expert dim — see
``repro.sharding.rules.rules_for``.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    moe_top_k=2,
    expert_d_ff=32768,
    attn_logit_softcap=30.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, expert_d_ff=128, num_experts=4, moe_top_k=2, vocab_size=512,
        moe_groups=2, attn_chunk=32,
    )
