"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]

O(1) decode state => ``long_500k`` runs natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv=4,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16,
    )
