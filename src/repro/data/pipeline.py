"""Zone-backed training-data pipeline with ZCSD pushdown.

Training data lives on a :class:`~repro.zns.ZonedDevice` as fixed-stride
records: ``[quality_score, token_0, ..., token_{T-1}]`` (int32). The pipeline
demonstrates the paper's thesis inside the training stack:

  * **pushdown filtering** — a verified offload Program
    (``FIELD(stride, 0); CMP_GE(min_quality); SELECT``) runs ON the device
    tier; only records that pass quality filtering cross to the host,
    and the per-epoch ``OffloadStats`` expose the data movement saved
    (the paper's headline statistic);
  * **pushdown statistics** — token histograms / quality quantiles computed
    device-side for curriculum decisions without moving the corpus;
  * **straggler mitigation** — N prefetch workers race batch reads; a backup
    fetch fires when a zone read exceeds the deadline (hedged requests), so
    one slow zone (device) cannot stall the step clock.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import CsdTier, NvmCsd, OffloadStats
from repro.core.programs import Instruction, OpCode, Program
from repro.telemetry.metrics import MetricsRegistry, StatsView
from repro.zns import ZonedDevice

_STORE_SEQ = itertools.count()

__all__ = ["ZoneDataStore", "ZoneDataPipeline", "PrefetchLoader"]


class ZoneDataStore:
    """Fixed-stride int32 records appended into zones.

    The record stride is padded so records never straddle the device read
    granularity (a verifier requirement for FIELD projections): either the
    stride divides the page's element count, or it is a whole multiple of it
    (then the pipeline reads multiple pages per offload access).
    """

    def __init__(self, device: ZonedDevice | StripedZoneArray, seq_len: int):
        self.device = device
        self.seq_len = seq_len
        per_page = device.block_bytes // 4
        raw = seq_len + 1                   # [quality | tokens...]
        if raw <= per_page:
            stride = 1
            while stride < raw:
                stride *= 2                 # next power of two divides per_page
            stride = min(stride, per_page)
        else:
            stride = -(-raw // per_page) * per_page   # round up to whole pages
        self.stride = stride
        self.pages_per_record_unit = max(stride // per_page, 1)
        self.records_written = 0
        # store-level host-copy accounting: the record staging buffer
        # (quality column + stride padding) is a host-side copy the device
        # counters never see — the data-path analogue of the checkpoint
        # store's serialization accounting. Counters live on a private
        # per-store registry; `stats` keeps its dict shape as a live view,
        # and concurrent appenders increment atomically.
        self.metrics = MetricsRegistry(f"data{next(_STORE_SEQ)}")
        self._c_bytes_copied = self.metrics.counter("bytes_copied")
        self._c_bytes_viewed = self.metrics.counter("bytes_viewed")
        self.stats = StatsView({"bytes_copied": self._c_bytes_copied,
                                "bytes_viewed": self._c_bytes_viewed})

    def append_records(self, zone_id: int, tokens: np.ndarray,
                       quality: Optional[np.ndarray] = None) -> int:
        """tokens: [N, seq_len] int32; quality: [N] int32 (default 100)."""
        n = tokens.shape[0]
        if quality is None:
            quality = np.full((n,), 100, np.int32)
        recs = np.zeros((n, self.stride), np.int32)
        recs[:, 0] = quality.astype(np.int32)
        recs[:, 1 : 1 + self.seq_len] = tokens.astype(np.int32)
        # pad the append to whole blocks with sentinel quality -1 records
        per_block = self.device.block_bytes // 4
        flat = recs.reshape(-1)
        pad_elems = (-flat.size) % per_block
        if pad_elems:
            n_pad = -(-pad_elems // self.stride)
            pad = np.zeros((n_pad, self.stride), np.int32)
            pad[:, 0] = -1                  # never passes quality >= 0
            flat = np.concatenate([flat, pad.reshape(-1)])
        self._c_bytes_copied.inc(flat.nbytes)   # staging copy to device
        self.device.zone_append(zone_id, flat)
        self.records_written += n
        return n

    def records_in_zone(self, zone_id: int) -> int:
        z = self.device.zone(zone_id)
        return (z.write_pointer * self.device.block_bytes // 4) // self.stride


@dataclass
class PipelineStats:
    bytes_read_device: int = 0
    bytes_to_host: int = 0
    records_seen: int = 0
    records_kept: int = 0
    offloads: int = 0

    @property
    def movement_saved(self) -> int:
        return max(self.bytes_read_device - self.bytes_to_host, 0)


class ZoneDataPipeline:
    """Batch iterator with device-side quality pushdown."""

    def __init__(self, store: ZoneDataStore, *, batch: int,
                 min_quality: int = 0, tier: str = CsdTier.JIT,
                 select_capacity: Optional[int] = None):
        self.store = store
        if isinstance(store.device, StripedZoneArray):
            # striped pushdown: the quality filter fans out across every
            # member device; only surviving records cross to the host
            self.csd = OffloadScheduler(
                store.device, default_tier=tier,
                pages_per_read=store.pages_per_record_unit)
        else:
            self.csd = NvmCsd(store.device, default_tier=tier,
                              pages_per_read=store.pages_per_record_unit)
        self.batch = batch
        self.min_quality = min_quality
        self.stats = PipelineStats()
        self.select_capacity = select_capacity

    def _zone_records(self, zone_id: int) -> np.ndarray:
        """Two-phase pushdown, fully device-side:

        1. ``FIELD(stride,0); CMP_GE(q); RED_COUNT``  -> survivor count
           (8 bytes back — sizes the SELECT_REC capacity exactly);
        2. ``FIELD(stride,0); CMP_GE(q); SELECT_REC`` -> only the surviving
           records cross to the host.
        """
        stride = self.store.stride
        nrec = self.store.records_in_zone(zone_id)
        if nrec == 0:
            return np.zeros((0, stride), np.int32)
        base = (Instruction(OpCode.FIELD, (stride, 0)),
                Instruction(OpCode.CMP_GE, int(self.min_quality)))
        n_blocks = self.store.device.zone(zone_id).write_pointer

        count_prog = Program("int32", (*base, Instruction(OpCode.RED_COUNT)),
                             name="quality_count")
        st = self.csd.nvm_cmd_bpf_run(count_prog, zone_id, n_blocks=n_blocks)
        kept = int(self.csd.nvm_cmd_bpf_result())
        self.stats.offloads += 1
        self.stats.bytes_read_device += st.bytes_read

        cap = self.select_capacity or max(kept, 1)
        sel_prog = Program("int32", (*base, Instruction(OpCode.SELECT_REC)),
                           select_capacity=cap, name="quality_select_rec")
        st2 = self.csd.nvm_cmd_bpf_run(sel_prog, zone_id, n_blocks=n_blocks)
        records, total = self.csd.nvm_cmd_bpf_result()
        records = np.asarray(records)[: min(kept, cap)]
        self.stats.offloads += 1
        self.stats.bytes_read_device += st2.bytes_read
        self.stats.bytes_to_host += records.nbytes + 8
        self.stats.records_seen += nrec
        self.stats.records_kept += records.shape[0]
        assert int(total) == kept, "device count != select_rec count"
        return records

    def batches(self, zone_ids: list[int], *, epochs: int = 1,
                seed: int = 0) -> Iterator[dict]:
        """Yield training batches {tokens, labels} from the surviving
        records of the given zones."""
        rng = np.random.default_rng(seed)
        for _ in range(epochs):
            pool = []
            for zid in zone_ids:
                recs = self._zone_records(zid)
                if recs.size:
                    pool.append(recs)
            if not pool:
                return
            recs = np.concatenate(pool, axis=0)
            order = rng.permutation(recs.shape[0])
            recs = recs[order]
            nb = recs.shape[0] // self.batch
            T = self.store.seq_len
            for i in range(nb):
                chunk = recs[i * self.batch : (i + 1) * self.batch, 1 : 1 + T]
                yield {
                    "tokens": chunk[:, :-1].copy(),
                    "labels": chunk[:, 1:].copy(),
                }

    def histogram(self, zone_id: int, bins: int = 64) -> np.ndarray:
        """Device-side token histogram (no corpus movement)."""
        from repro.core.programs import histogram as hist_prog
        prog = hist_prog("int32", 0, 2**31 - 1, bins)
        self.csd.nvm_cmd_bpf_run(prog, zone_id)
        return np.asarray(self.csd.nvm_cmd_bpf_result())


class PrefetchLoader:
    """Hedged prefetching around any batch iterator.

    ``workers`` threads pull from the source iterator into a bounded queue.
    A consumer-side deadline triggers a *backup* fetch path: if the queue
    stays empty past ``hedge_seconds`` (a straggling zone read), the loader
    synchronously fetches from the iterator itself rather than waiting —
    bounding the step-time tail (hedged-request straggler mitigation).
    """

    def __init__(self, it: Iterator[dict], *, depth: int = 4,
                 hedge_seconds: float = 1.0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._lock = threading.Lock()
        self._done = False
        self.hedge_seconds = hedge_seconds
        self.hedged_fetches = 0
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _next_upstream(self):
        with self._lock:
            return next(self._it, None)

    def _fill(self):
        while True:
            item = self._next_upstream()
            if item is None:
                self._done = True
                self._q.put(None)
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self._q.get(timeout=self.hedge_seconds)
        except queue.Empty:
            if self._done:
                raise StopIteration
            # straggler: hedge by fetching directly
            self.hedged_fetches += 1
            item = self._next_upstream()
        if item is None:
            raise StopIteration
        return item
