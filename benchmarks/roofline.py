"""Roofline analysis from the dry-run artifacts.

Combines ``results/dryrun.jsonl`` (production lowerings: memory analysis,
grad-accum policy, sharding proof) with ``results/probes.jsonl`` (unrolled
cost probes, extrapolated linearly in depth — see dryrun.py) into the
per-(arch x shape) roofline table:

  compute term    = HLO_FLOPs_per_device            / peak_FLOPs  (197 TF bf16)
  memory term     = HLO_bytes_accessed_per_device   / HBM_bw      (819 GB/s)
  collective term = collective_bytes_per_device     / ICI_link_bw (50 GB/s)

cost_analysis() and the HLO collective census are both per-device (SPMD
program), so dividing by per-chip peaks directly yields seconds; the spec's
"total / (chips x peak)" formulation is identical.

Also reported: MODEL_FLOPS (6*N*D train; 2*N*D forward-only, N_active for
MoE), the MODEL/HLO usefulness ratio, the dominant term, and a what-to-do
note. Output: markdown to stdout + results/roofline.csv.
"""
from __future__ import annotations

import csv
import json
import sys
from collections import defaultdict
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

RESULTS = Path(__file__).resolve().parent.parent / "results"


def load_jsonl(path):
    out = []
    if not Path(path).exists():
        return out
    for line in open(path):
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    return out


def model_flops(rec: dict, shape_kind: str, seq_len: int, batch: int) -> float:
    n = rec.get("active_params") or rec.get("params")
    if shape_kind == "train":
        return 6.0 * n * seq_len * batch
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * batch
    return 2.0 * n * batch          # decode: one token per sequence


SHAPE_META = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def bottleneck_note(dom: str, rec: dict, kind: str) -> str:
    fam = rec.get("family", "")
    if dom == "compute":
        if kind == "train":
            return ("compute-bound: reduce remat recompute (selective "
                    "checkpointing) or causal-skip the attention blocks")
        return "compute-bound: good — batch harder or quantize to push further"
    if dom == "memory":
        if kind == "decode":
            return ("HBM-bound (expected for decode: every step streams "
                    "weights+KV); grow batch, quantize KV, or fuse the "
                    "paged-attention kernel")
        return "HBM-bound: increase arithmetic intensity (fusion, bigger tiles)"
    return ("collective-bound: reshard to cut cross-device traffic "
            "(e.g. FSDP gather batching, expert-local dispatch, SP-KV)")


def assemble():
    prod = {(r["arch"], r["shape"], r["mesh"]): r
            for r in load_jsonl(RESULTS / "dryrun.jsonl")
            if r.get("kind") != "probe"}
    probes = defaultdict(list)
    for r in load_jsonl(RESULTS / "probes.jsonl"):
        if r.get("kind") == "probe" and r.get("status") == "ok":
            probes[(r["arch"], r["shape"])].append(r)

    rows = []
    for (arch, shape, mesh), rec in sorted(prod.items()):
        if mesh != "16x16":
            continue                       # roofline table is single-pod
        kind, seq, batch = SHAPE_META[shape]
        row = {"arch": arch, "shape": shape, "family": rec.get("family"),
               "status": rec.get("status")}
        if rec.get("status") == "skipped":
            row["note"] = rec.get("reason", "")[:80]
            rows.append(row)
            continue
        pr = probes.get((arch, shape), [])
        if not pr:
            row["note"] = "no probes"
            rows.append(row)
            continue
        flops = sum(p["weight"] * p["cost_analysis"].get("flops", 0.0)
                    for p in pr)
        byts = sum(p["weight"] * p["cost_analysis"].get("bytes accessed", 0.0)
                   for p in pr)
        coll = sum(p["weight"] * p["collectives"].get(
            "total_wire_bytes", p["collectives"]["total_bytes"]) for p in pr)
        t_c = flops / PEAK_FLOPS
        t_m = byts / HBM_BW
        t_x = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(rec, kind, seq, batch)
        hlo_total = flops * 256
        row.update({
            "grad_accum": rec.get("grad_accum", ""),
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_total": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "roofline_fraction": (mf / PEAK_FLOPS / 256) / max(t_c, t_m, t_x)
            if max(t_c, t_m, t_x) else 0.0,
            "temp_bytes_per_dev": rec.get("memory_analysis", {}).get(
                "temp_size_in_bytes", 0),
            "note": bottleneck_note(dom, rec, kind),
        })
        rows.append(row)
    return rows


def main() -> list[str]:
    rows = assemble()
    RESULTS.mkdir(exist_ok=True)
    fields = ["arch", "shape", "family", "status", "grad_accum",
              "t_compute_s", "t_memory_s", "t_collective_s", "dominant",
              "model_flops", "hlo_flops_total", "useful_ratio",
              "roofline_fraction", "temp_bytes_per_dev", "note"]
    with open(RESULTS / "roofline.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)

    out = []
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"roofline_{r['arch']}_{r['shape']},0,skipped")
            continue
        if "dominant" not in r:
            out.append(f"roofline_{r['arch']}_{r['shape']},0,{r.get('note')}")
            continue
        dom_t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        out.append(
            f"roofline_{r['arch']}_{r['shape']},{dom_t * 1e6:.0f},"
            f"dom={r['dominant']};tc={r['t_compute_s']:.3f};"
            f"tm={r['t_memory_s']:.3f};tx={r['t_collective_s']:.3f};"
            f"useful={r['useful_ratio']:.2f};"
            f"roofline_frac={r['roofline_fraction']:.2f}"
        )
    return out


def markdown_table() -> str:
    rows = assemble()
    lines = [
        "| arch | shape | accum | t_comp (s) | t_mem (s) | t_coll (s) | "
        "dominant | MODEL/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped" or "dominant" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skip | — | — | {r.get('note', '')} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('grad_accum', '')} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['note']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for line in main():
        print(line)
    print()
    print(markdown_table())
