"""Quickstart: the ZCSD workflow from the paper, end to end.

Creates an emulated ZNS device, fills a zone with random integers, then runs
the paper's Figure-2 filter offload on every execution tier — interpreter
(uBPF analogue), XLA JIT, and the Pallas TPU kernel (interpret mode on CPU)
— printing each tier's runtime, JIT time, and data movement saved.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CsdTier, NvmCsd, filter_count, histogram
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1


def main():
    # 1. an emulated ZNS SSD: 4 zones x 16 MiB, 4 KiB blocks
    dev = ZonedDevice(num_zones=4, zone_bytes=16 * 1024 * 1024,
                      block_bytes=4096)

    # 2. fill zone 0 with random integers (append-only writes)
    rng = np.random.default_rng(0)
    data = rng.integers(0, RAND_MAX, 4 * 1024 * 1024, dtype=np.int32)
    dev.zone_append(0, data)
    print(f"zone 0: wp={dev.zone(0).write_pointer} blocks, "
          f"state={dev.zone(0).state.value}")

    # 3. the offloaded program: count ints above RAND_MAX/2 (paper Fig. 2)
    program = filter_count("int32", "gt", RAND_MAX // 2)
    csd = NvmCsd(dev)

    expected = int((data > RAND_MAX // 2).sum())
    print(f"\nhost oracle: {expected} of {data.size} ints pass "
          f"({expected / data.size:.1%})\n")

    for tier in (CsdTier.INTERP, CsdTier.JIT, CsdTier.KERNEL):
        stats = csd.nvm_cmd_bpf_run(program, 0, tier=tier)
        result = int(csd.nvm_cmd_bpf_result())
        assert result == expected, (tier, result, expected)
        print(f"tier={tier:7s} exec={stats.exec_seconds * 1e3:8.1f} ms  "
              f"jit={stats.jit_seconds * 1e3:6.1f} ms  "
              f"verified_insns={stats.insns_verified}  "
              f"saved={stats.movement_saved_bytes / 1e6:.1f} MB "
              f"({stats.reduction_factor:.0f}x reduction)")

    # 4. richer offloads: histogram without moving the zone
    hist = histogram("int32", 0, RAND_MAX, 16)
    csd.nvm_cmd_bpf_run(hist, 0, tier=CsdTier.JIT)
    print("\ndevice-side histogram (16 bins):",
          np.asarray(csd.nvm_cmd_bpf_result()))

    # 5. host-managed GC
    dev.reset_zone(0)
    print(f"\nafter reset: zone 0 state={dev.zone(0).state.value}, "
          f"resets={dev.stats['zone_resets']}")


if __name__ == "__main__":
    main()
