"""Zoned checkpoint store: atomic commit, crash recovery, GC, elastic restore,
and preemption-exact training resume."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.params import abstract_params, init_params
from repro.train.checkpoint import CheckpointError, ZonedCheckpointStore
from repro.train.step import TrainHyper, make_train_step, train_state_specs
from repro.train.trainer import Trainer, TrainerConfig
from repro.zns import ZonedDevice


def small_store(path=None, keep=2):
    return ZonedCheckpointStore(path, num_zones=8, zone_bytes=4 * 1024 * 1024,
                                keep=keep)


def tiny_state(seed=0):
    cfg = get_reduced("h2o-danube-1.8b")
    specs = train_state_specs(cfg)
    return cfg, specs, init_params(specs, jax.random.PRNGKey(seed))


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_save_restore_roundtrip():
    cfg, specs, state = tiny_state()
    store = small_store()
    store.save(3, state)
    got = store.restore(like=abstract_params(specs))
    assert_tree_equal(state, got)
    assert store.latest_step() == 3


def test_multiple_checkpoints_and_gc():
    cfg, specs, state = tiny_state()
    store = small_store(keep=2)
    for s in (1, 2, 3, 4):
        state = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x,
                             state)
        store.save(s, state)
    assert store.latest_step() == 4
    assert len(store.steps()) == 2          # GC keeps 2
    assert store.device.stats["zone_resets"] > 0  # host-managed reclamation
    got = store.restore(like=abstract_params(specs))
    assert_tree_equal(state, got)


def test_crash_recovery_from_file(tmp_path):
    """Kill after save; a fresh process (new store over the same file)
    recovers the committed checkpoint from the manifest log."""
    path = tmp_path / "ckpt.zns"
    cfg, specs, state = tiny_state()
    store = ZonedCheckpointStore(path, num_zones=8,
                                 zone_bytes=4 * 1024 * 1024)
    store.save(7, state)
    store.flush()
    del store
    store2 = ZonedCheckpointStore(path, num_zones=8,
                                  zone_bytes=4 * 1024 * 1024)
    assert store2.latest_step() == 7
    got = store2.restore(like=abstract_params(specs))
    assert_tree_equal(state, got)


def test_torn_checkpoint_never_referenced(tmp_path):
    """A crash mid-payload (no manifest committed) leaves the previous
    checkpoint as the recovery target."""
    path = tmp_path / "ckpt.zns"
    cfg, specs, state = tiny_state()
    store = ZonedCheckpointStore(path, num_zones=8,
                                 zone_bytes=4 * 1024 * 1024)
    store.save(1, state)
    # simulate crash mid-save: payload appended, manifest NOT written
    leaves = jax.tree.leaves(state)
    store.device.zone_append(2, np.asarray(jnp.ravel(
        leaves[0].astype(jnp.float32))).view(np.uint8))
    store.flush()
    store2 = ZonedCheckpointStore(path, num_zones=8,
                                  zone_bytes=4 * 1024 * 1024)
    assert store2.latest_step() == 1
    got = store2.restore(like=abstract_params(specs))
    assert_tree_equal(state, got)


def test_elastic_restore_across_meshes():
    """Save sharded over 4x2, restore onto 2x4 and onto 1 device."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import param_shardings, rules_for
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    cfg, specs, state = tiny_state()
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh_a = param_shardings(specs, mesh_a, rules_for("train", cfg, mesh_a))
    sh_b = param_shardings(specs, mesh_b, rules_for("train", cfg, mesh_b))
    state_a = jax.device_put(state, sh_a)
    store = small_store()
    store.save(5, state_a)
    got_b = store.restore(like=abstract_params(specs), shardings=sh_b)
    assert_tree_equal(state, got_b)
    leaf = jax.tree.leaves(got_b)[0]
    assert leaf.sharding.mesh.devices.shape == (2, 4)


@pytest.mark.slow
def test_preemption_exact_resume():
    """train 6 steps straight == train 3, 'crash', resume, train 3 more."""
    cfg = get_reduced("h2o-danube-1.8b")
    rng = np.random.default_rng(0)
    batches = [
        {"tokens": rng.integers(0, cfg.vocab_size, (2, 32), dtype=np.int32),
         "labels": rng.integers(0, cfg.vocab_size, (2, 32), dtype=np.int32)}
        for _ in range(6)
    ]
    tcfg = TrainerConfig(total_steps=6, checkpoint_every=3, log_every=100,
                         hyper=TrainHyper())
    # uninterrupted
    t1 = Trainer(cfg, tcfg)
    t1.run(iter(list(batches)))
    # interrupted at step 3
    store = small_store()
    t2 = Trainer(cfg, TrainerConfig(total_steps=3, checkpoint_every=3,
                                    log_every=100), store=store)
    t2.run(iter(list(batches)))
    assert store.latest_step() == 3
    t3 = Trainer(cfg, tcfg, store=store)   # resumes at 3, replays pipeline
    t3.run(iter(list(batches)))
    assert int(np.asarray(jax.device_get(t3.state["step"]))) == 6
    assert_tree_equal(t1.state["params"], t3.state["params"])
    assert_tree_equal(t1.state["m"], t3.state["m"])


def test_restore_missing_raises():
    store = small_store()
    with pytest.raises(CheckpointError):
        store.restore(like={})


@pytest.mark.parametrize("redundancy,n", [("raid1", 2), ("xor", 3)])
def test_striped_restore_survives_member_loss_mid_restore(tmp_path, redundancy, n):
    """Acceptance: a checkpoint saved healthy restores bit-identically after
    a member zone goes OFFLINE — including a loss injected while restore
    reads are already in flight — and the redundancy mode survives reopen."""
    rng = np.random.default_rng(7)
    tree = {"w": rng.standard_normal((64, 64)).astype(np.float32),
            "b": rng.integers(-5, 5, 4096, dtype=np.int64)}
    like = {"w": np.zeros((64, 64), np.float32),
            "b": np.zeros(4096, np.int64)}
    store = ZonedCheckpointStore.striped(
        tmp_path, num_devices=n, num_zones=6,
        member_zone_bytes=64 * 4096, stripe_blocks=4, redundancy=redundancy)
    store.save(3, tree)
    store.flush()
    # mid-restore member loss: reads in flight when the member dies
    ticket = store.restore_async(like=like)
    for z in range(store.device.num_zones):
        store.device.devices[1].set_offline(z)
    got = ticket.result(timeout=30)
    assert np.array_equal(got["w"], tree["w"])
    assert np.array_equal(got["b"], tree["b"])
    # fully-degraded restore: every read planned AFTER the loss reconstructs
    got2 = store.restore(like=like)
    assert np.array_equal(got2["w"], tree["w"])
    assert np.array_equal(got2["b"], tree["b"])
    assert store.device.stats["degraded_reads"] > 0
    # reopen adopts the redundancy mode from the array.json sidecar
    reopened = ZonedCheckpointStore.striped(tmp_path)
    assert reopened.device.redundancy == redundancy
    got3 = reopened.restore(like=like)
    assert np.array_equal(got3["w"], tree["w"])
