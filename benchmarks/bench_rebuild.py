"""Self-healing array: unattended recovery end to end, loud in CI.

The self-healing loop is only trustworthy if a member death recovers with
NOBODY at the keyboard. This benchmark injects one and asserts the whole
chain (every stage a hard tripwire, same posture as ``bench_health``):

  * **alert-path promotion** — killing a raid1 member fires the
    :class:`HealthPromotionRule` through the :class:`AlertEngine`; the
    :class:`ArrayManager`'s callback pops a hot spare and starts the
    rebuild with no manual call;
  * **online rebuild** — the copy runs on the metered ``"rebuild"`` tenant
    (WRR-arbitrated against live traffic; the spare is paced with an
    emulated per-block append latency so the overlap is guaranteed, not
    lucky) while offloads keep streaming — every offload issued DURING the
    rebuild must return the healthy answer bit-identically, and the
    offload p99 under concurrent rebuild must stay within a bounded factor
    of the healthy baseline;
  * **full recovery** — after the rebuild: every zone writable again
    (post-rebuild appends succeed), reads bit-identical, a full scrub
    reports zero mismatches, and the rebuild tenant's SQ accounting shows
    the copy traffic actually rode the arbiter;
  * **scrub interference** — a scrub pass racing the offload stream stays
    on the ``"scrub"`` tenant and leaves the offload p99 bounded;
  * **xor double-fault** — a survivor dies mid-rebuild: the affected zone
    goes OFFLINE with a clean refusal (never half-rebuilt garbage), the
    other zones complete, and the whole episode terminates in bounded
    wall time — no hangs, no corruption.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import ArrayManager, OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.telemetry import (
    AlertEngine,
    ArrayHealthMonitor,
    HealthPromotionRule,
    event_log,
)
from repro.zns import ZNSError, ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096
# generous CI bound: a WRR slice behind a paced rebuild batch, not a hang
MAX_P99_FACTOR = 50.0
MAX_P99_FLOOR_S = 0.25
DOUBLE_FAULT_BUDGET_S = 30.0


def _mk_dev(num_zones: int, zone_bytes: int, **kw) -> ZonedDevice:
    return ZonedDevice(num_zones=num_zones, zone_bytes=zone_bytes,
                       block_bytes=BLOCK, **kw)


def run_recovery(*, data_mib: int = 8, runs: int = 3,
                 read_us_per_block: float = 0.5,
                 spare_append_us_per_block: float = 40.0) -> dict:
    """Kill a raid1 member mid-stream; assert unattended recovery."""
    zone_bytes = data_mib * 1024 * 1024 // 2
    zone_blocks = zone_bytes // BLOCK
    rng = np.random.default_rng(0)
    program = filter_count("int32", "gt", RAND_MAX // 2)

    devices = [_mk_dev(3, zone_bytes, read_us_per_block=read_us_per_block)
               for _ in range(2)]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy="raid1")
    fills, expected = [], []
    for z in range(3):
        fill = zone_blocks // 2 + 64 * z        # distinct, half-ish fills
        data = rng.integers(0, RAND_MAX, fill * BLOCK // 4, dtype=np.int32)
        array.zone_append(z, data)
        fills.append(fill)
        expected.append(int((data > RAND_MAX // 2).sum()))
    baseline = [array.read_zone(z).copy() for z in range(3)]

    log = event_log()
    seq0 = log.last_seq()
    monitor = ArrayHealthMonitor(array)
    engine = AlertEngine(rules=[HealthPromotionRule(monitor)])

    t_start = time.perf_counter()
    with OffloadScheduler(array) as sched:
        sched.register_tenant("alice")
        # the spare is paced: ~zone_blocks/2 * 25us per zone of copy, so
        # the offload loop below is guaranteed to overlap the rebuild
        spare = _mk_dev(3, zone_bytes,
                        append_us_per_block=spare_append_us_per_block)
        mgr = ArrayManager(array, scheduler=sched, spares=[spare],
                           monitor=monitor, rows_per_io=4)
        unsub = mgr.attach(engine)

        # -------- healthy baseline
        monitor.sample()
        healthy_s = []
        for _ in range(runs):
            for z in range(3):
                t0 = time.perf_counter()
                sched.nvm_cmd_bpf_run(program, z, tenant="alice")
                healthy_s.append(time.perf_counter() - t0)
                assert int(sched.nvm_cmd_bpf_result()) == expected[z]
        assert engine.evaluate() == [], "healthy array fired an alert"

        # -------- fault: the member dies; NOBODY calls promote_spare
        for z in range(3):
            array.set_offline(z, device=1)
        fired = engine.evaluate()
        assert any(a.rule == "member_degraded" for a in fired), fired
        assert log.snapshot(name="spare.promoted", since_seq=seq0), \
            "alert did not auto-promote the spare"

        # -------- offloads DURING the rebuild: bit-identical, bounded p99
        during_s, during_n = [], 0
        deadline = time.monotonic() + 60.0
        while mgr.rebuild_active() and time.monotonic() < deadline:
            z = during_n % 3
            t0 = time.perf_counter()
            sched.nvm_cmd_bpf_run(program, z, tenant="alice")
            during_s.append(time.perf_counter() - t0)
            assert int(sched.nvm_cmd_bpf_result()) == expected[z], \
                "offload during rebuild differs from healthy answer"
            during_n += 1
        assert during_n >= 1, "rebuild finished before any offload ran " \
                              "(pacing broken — overlap not exercised)"
        assert mgr.wait(timeout=60.0), "rebuild did not finish"
        st = mgr.status()[1]
        assert st["state"] == "complete", st
        recovery_s = time.perf_counter() - t_start

        # -------- full recovery: writable, bit-identical, scrub-clean
        for z in range(3):
            assert array.zone(z).is_writable, f"zone {z} not writable"
            assert np.array_equal(array.read_zone(z), baseline[z]), \
                f"zone {z} not bit-identical after rebuild"
            array.zone_append(z, np.zeros(BLOCK, np.uint8))
        scrub = mgr.scrub()
        assert scrub["mismatches"] == 0, scrub
        ts = sched.tenant_stats()
        assert ts["rebuild"]["ops"] > 0 and ts["rebuild"]["bytes"] > 0, \
            "rebuild traffic was not metered on the rebuild tenant"

        # -------- scrub-vs-offload interference on the WRR arbiter
        with_scrub_s = []
        for _ in range(runs):
            res = mgr.scrub()        # rides the "scrub" tenant's SQ
            for z in range(3):
                t0 = time.perf_counter()
                sched.nvm_cmd_bpf_run(program, z, tenant="alice")
                with_scrub_s.append(time.perf_counter() - t0)
                assert int(sched.nvm_cmd_bpf_result()) == expected[z]
        assert res["mismatches"] == 0
        assert sched.tenant_stats()["scrub"]["ops"] > 0, \
            "scrub traffic was not metered on the scrub tenant"
        unsub()
        alice = sched.tenant_stats()["alice"]

    healthy_p99 = float(np.percentile(healthy_s, 99))
    during_p99 = float(np.percentile(during_s, 99))
    scrub_p99 = float(np.percentile(with_scrub_s, 99))
    bound = max(MAX_P99_FACTOR * healthy_p99, MAX_P99_FLOOR_S)
    assert during_p99 <= bound, (
        f"offload p99 under rebuild {during_p99 * 1e3:.1f}ms exceeds "
        f"{MAX_P99_FACTOR:g}x healthy baseline {healthy_p99 * 1e3:.1f}ms")
    assert scrub_p99 <= bound, (
        f"offload p99 under scrub {scrub_p99 * 1e3:.1f}ms exceeds "
        f"{MAX_P99_FACTOR:g}x healthy baseline {healthy_p99 * 1e3:.1f}ms")
    return {
        "recovery_seconds": recovery_s,
        "healthy_p99_s": healthy_p99,
        "during_p99_s": during_p99,
        "scrub_p99_s": scrub_p99,
        "offloads_during_rebuild": during_n,
        "zones_done": st["zones_done"],
        "rows_verified": scrub["rows_verified"],
        "rebuild_ops": ts["rebuild"]["ops"],
        "rebuild_mib": ts["rebuild"]["bytes"] / 2**20,
        "alice_ops": alice["ops"],
    }


def run_double_fault(*, data_mib: int = 8,
                     spare_append_us_per_block: float = 25.0) -> dict:
    """xor survivor dies mid-rebuild: OFFLINE zone, zero corruption,
    bounded wall time."""
    # 3 data columns, 2 zones; member zones stripe-aligned (64 blocks)
    zone_blocks = max(64, data_mib * 1024 * 1024 // 6 // BLOCK // 64 * 64)
    zone_bytes = zone_blocks * BLOCK
    rng = np.random.default_rng(1)

    devices = [_mk_dev(2, zone_bytes) for _ in range(4)]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy="xor")
    baseline = []
    for z in range(2):
        data = rng.integers(0, RAND_MAX, 3 * zone_blocks * BLOCK // 8,
                            dtype=np.int32)     # ~half of each logical zone
        array.zone_append(z, data)
        baseline.append(array.read_zone(z).copy())

    victim, survivor = 1, 3
    for z in range(2):
        array.set_offline(z, device=victim)
    spare = _mk_dev(2, zone_bytes,
                    append_us_per_block=spare_append_us_per_block)
    mgr = ArrayManager(array, spares=[spare], rows_per_io=4)

    tripped = []

    def on_event(e):
        # the instant zone 0 cuts over, a SECOND member dies for zone 1:
        # the xor rebuild of zone 1 has lost its reconstruction source
        if e.name == "array.zone_rebuilt" and not tripped:
            tripped.append(True)
            nxt = sorted(array.rebuilding_zones())
            if nxt:
                array.devices[survivor].set_offline(nxt[0])

    unsub = event_log().subscribe(on_event)
    t0 = time.perf_counter()
    try:
        assert mgr.promote_spare(victim, reason="bench")
        assert mgr.wait(timeout=DOUBLE_FAULT_BUDGET_S), \
            "double-fault rebuild hung"
    finally:
        unsub()
    elapsed = time.perf_counter() - t0
    assert elapsed < DOUBLE_FAULT_BUDGET_S
    st = mgr.status()[victim]
    assert tripped, "injection point never reached"
    assert st["state"] == "degraded", st
    assert len(st["zones_failed"]) == 1, st
    dead = st["zones_failed"][0]
    assert array.zone(dead).state.value == "offline"
    try:
        array.read_zone(dead)
        raise AssertionError("double-faulted zone served a read")
    except ZNSError:
        pass                                    # clean refusal, not garbage
    live = 1 - dead
    assert array.zone(live).is_writable
    assert np.array_equal(array.read_zone(live), baseline[live]), \
        "surviving zone corrupted by the aborted rebuild"
    return {"elapsed_seconds": elapsed, "dead_zone": dead,
            "zones_done": st["zones_done"]}


def main(data_mib: int = 8, runs: int = 3) -> list[str]:
    rows = []
    r = run_recovery(data_mib=data_mib, runs=runs)
    rows.append(
        f"rebuild_unattended_recovery,{r['recovery_seconds'] * 1e6:.0f},"
        f"offloads_during_rebuild={r['offloads_during_rebuild']};"
        f"zones_done={r['zones_done']};"
        f"rebuild_ops={r['rebuild_ops']};"
        f"rebuild_mib={r['rebuild_mib']:.1f};"
        f"scrub_rows={r['rows_verified']};"
        f"alice_ops={r['alice_ops']}"
    )
    rows.append(
        f"rebuild_p99_interference,{r['during_p99_s'] * 1e6:.0f},"
        f"healthy_p99_us={r['healthy_p99_s'] * 1e6:.0f};"
        f"during_p99_us={r['during_p99_s'] * 1e6:.0f};"
        f"scrub_p99_us={r['scrub_p99_s'] * 1e6:.0f};"
        f"factor_vs_healthy="
        f"{r['during_p99_s'] / max(r['healthy_p99_s'], 1e-9):.1f}x"
    )
    d = run_double_fault(data_mib=data_mib)
    rows.append(
        f"rebuild_xor_double_fault,{d['elapsed_seconds'] * 1e6:.0f},"
        f"dead_zone={d['dead_zone']};zones_done={d['zones_done']};"
        f"outcome=offline_clean"
    )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
