"""Self-healing array manager: rebuild-to-spare, scrub, spare promotion.

PR 5 made a degraded raid1/xor zone *survive* — reads reconstruct, appends
fence — and PR 7 made the decay *visible* (SMART-style health monitors, an
edge-triggered alert engine). This module closes the loop the ROADMAP's
"Self-managing array" item asks for: the array **recovers unattended**.

:class:`ArrayManager` owns a pool of hot-spare devices and runs two
background loops over a :class:`~repro.array.striping.StripedZoneArray`:

  * **online rebuild** — after :meth:`promote_spare` swaps a spare into a
    dead member's seat (:meth:`StripedZoneArray.replace_member`), a worker
    reconstructs the member zone by zone: read the logical extent (raid1
    mirror copy / xor survivor reconstruction, riding the existing
    completion-ring degraded-read machinery), derive the member's shard
    (:meth:`StripedZoneArray.member_shard` — data chunks plus rotated
    parity under xor), and append it to the spare. When a scheduler is
    attached the copy traffic is raw I/O on a dedicated ``"rebuild"``
    tenant, so WRR arbitration meters it against live offload traffic.
    Cutover is **per zone** under the array lock
    (:meth:`StripedZoneArray.commit_member_rebuild`): rebuilt zones leave
    READ_ONLY and accept appends again while later zones are still copying.
  * **background scrub** — :meth:`scrub` reads every stripe row at low
    priority (the ``"scrub"`` tenant), verifies mirror equality (raid1) or
    parity consistency (xor, including the incomplete tail row against the
    host parity accumulator), publishes ``scrub.mismatch`` events and
    charges ``scrub_mismatches`` to the implicated devices' metric
    registries — which the :class:`DeviceHealthMonitor` counts as media
    errors, so silent corruption pages like any other fault.

**Automatic spare promotion** plugs into the seat PR 7 reserved:
:meth:`attach` registers an ``AlertEngine.on_alert`` callback that maps a
``member_degraded`` incident key (``member<i>/dev<ordinal>``) to
:meth:`promote_spare`. Promotion is idempotent per incident — an alert
re-fire or a concurrent manual promotion never double-promotes — and the
member's health monitor is rebound to the spare, so the incident resolves
(``alert.resolved``) on the next evaluation instead of paging forever.

Fault posture, by injection point:

  * member death **during** rebuild (the spare dies) — the rebuild restarts
    onto the next spare (``rebuild.restarted``), or degrades cleanly when
    the pool is empty (``rebuild.failed``; partial copies are parked
    OFFLINE, never served);
  * **double fault** on xor (a survivor dies mid-copy) — the zone's rebuild
    is abandoned (``rebuild.zone_failed``), the zone goes OFFLINE through
    the ordinary redundancy math; no corruption, no hang;
  * everything is restartable — :meth:`StripedZoneArray.begin_member_rebuild`
    re-parks partial copies, so a crashed manager resumes from block 0 of
    whatever zones remain marked.
"""
from __future__ import annotations

import re
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.telemetry.events import Severity as _Sev, publish as _publish_event
from repro.telemetry.health import ArrayHealthMonitor, DeviceHealthMonitor
from repro.telemetry.metrics import registry as _registry
from repro.zns.device import ZNSError, ZonedDevice
from repro.array.striping import StripedZoneArray

__all__ = ["ArrayManager", "RebuildError"]


class RebuildError(Exception):
    """A rebuild could not complete (no spares left / unrecoverable source)."""


_MEMBER_KEY = re.compile(r"member(\d+)\b")


class ArrayManager:
    """Owns hot spares and the rebuild/scrub loops for one striped array.

    ``scheduler`` (an :class:`~repro.array.scheduler.OffloadScheduler`) is
    optional: with one, rebuild/scrub I/O rides the per-tenant SQs and WRR
    arbitration (the production shape); without one, the manager issues
    direct array/device I/O (the unit-test shape). ``monitor`` (an
    :class:`ArrayHealthMonitor`) is rebound per seat on promotion so
    incidents resolve once the spare is in place.
    """

    def __init__(
        self,
        array: StripedZoneArray,
        *,
        scheduler=None,
        spares: Sequence[ZonedDevice] = (),
        monitor: Optional[ArrayHealthMonitor] = None,
        rebuild_tenant: str = "rebuild",
        scrub_tenant: str = "scrub",
        rebuild_weight: int = 1,
        scrub_weight: int = 1,
        rows_per_io: int = 8,
    ):
        self.array = array
        self.scheduler = scheduler
        self.monitor = monitor
        self.rebuild_tenant = rebuild_tenant
        self.scrub_tenant = scrub_tenant
        self.rows_per_io = int(rows_per_io)
        self._spares: list[ZonedDevice] = list(spares)
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._threads: dict[int, threading.Thread] = {}
        self._member_status: dict[int, dict] = {}
        self._handled: set[str] = set()        # promotion incident keys seen
        self._scrub_thread: Optional[threading.Thread] = None
        self._unsubscribe = None
        if scheduler is not None:
            for tenant, weight in ((rebuild_tenant, rebuild_weight),
                                   (scrub_tenant, scrub_weight)):
                if tenant not in scheduler._pairs:
                    scheduler.register_tenant(tenant, weight=weight)
        reg = _registry()
        self._g_total = reg.gauge("rebuild.zones_total")
        self._g_done = reg.gauge("rebuild.zones_done")
        self._g_progress = reg.gauge("rebuild.progress")
        self._g_active = reg.gauge("rebuild.active")
        self._c_restarts = reg.counter("rebuild.restarts")
        self._c_rows = reg.counter("scrub.rows_verified")
        self._c_mismatch = reg.counter("scrub.mismatches")
        self._c_passes = reg.counter("scrub.passes")

    # -------------------------------------------------------------- spares
    def add_spare(self, device: ZonedDevice) -> None:
        with self._lock:
            self._spares.append(device)

    @property
    def spare_count(self) -> int:
        with self._lock:
            return len(self._spares)

    def _pop_spare(self) -> Optional[ZonedDevice]:
        with self._lock:
            return self._spares.pop(0) if self._spares else None

    def _rebind_monitor(self, member: int, spare: ZonedDevice) -> None:
        """Point the seat's health monitor at the spare: the dead device's
        incident key disappears from the promotion rule's view, so the
        engine publishes ``alert.resolved`` on its next evaluation."""
        if self.monitor is None or member >= len(self.monitor.members):
            return
        self.monitor.members[member] = DeviceHealthMonitor(
            spare, events=self.monitor.events,
            name=f"member{member}/dev{getattr(spare, 'dev_ordinal', member)}")

    # ----------------------------------------------------------- promotion
    def attach(self, engine, *, rule: str = "member_degraded"):
        """Wire automatic promotion into ``engine`` (an AlertEngine): a
        ``member_degraded`` alert whose incident key names ``member<i>``
        promotes a spare into seat ``i``. Idempotent per incident key — a
        re-fired or duplicated alert never double-promotes. Returns the
        unsubscribe callable."""

        def on_alert(alert) -> None:
            if alert.rule != rule:
                return
            m = _MEMBER_KEY.match(alert.key)
            if m is None:
                return
            with self._lock:
                if alert.key in self._handled:
                    return
                self._handled.add(alert.key)
            self.promote_spare(int(m.group(1)),
                               reason=f"alert {alert.rule}/{alert.key}")

        self._unsubscribe = engine.on_alert(on_alert)
        return self._unsubscribe

    def promote_spare(self, member: int, *, reason: str = "manual") -> bool:
        """Swap the next hot spare into seat ``member`` and start its
        rebuild worker. Returns False (without consuming a spare) when the
        seat already has a live rebuild or the pool is empty — the
        idempotence the alert path relies on."""
        with self._lock:
            t = self._threads.get(member)
            if t is not None and t.is_alive():
                return False
            if not self._spares:
                _publish_event(
                    "spare.exhausted", severity=_Sev.ERROR,
                    message=f"no hot spare available for member {member} "
                            f"({reason})",
                    member=member, reason=reason)
                return False
            spare = self._spares.pop(0)
            try:
                pending = self.array.replace_member(member, spare)
            except Exception:
                self._spares.insert(0, spare)   # seat refused: keep the spare
                raise
            self._rebind_monitor(member, spare)
            self._member_status[member] = {
                "state": "running", "zones_total": len(pending),
                "zones_done": 0, "zones_failed": [], "restarts": 0,
                "spare": getattr(spare, "dev_ordinal", None),
            }
            self._publish_progress()
            worker = threading.Thread(
                target=self._rebuild_member, args=(member,),
                name=f"array-rebuild-m{member}", daemon=True)
            self._threads[member] = worker
        _publish_event(
            "spare.promoted", severity=_Sev.WARNING,
            message=f"spare dev{getattr(spare, 'dev_ordinal', '?')} promoted "
                    f"into member seat {member} ({reason}): "
                    f"{len(pending)} zone(s) to rebuild",
            member=member, spare=getattr(spare, "dev_ordinal", None),
            pending=len(pending), reason=reason)
        worker.start()
        return True

    # -------------------------------------------------------------- status
    def status(self) -> dict[int, dict]:
        """Per-seat rebuild status snapshot (state / zone counts)."""
        with self._lock:
            return {m: dict(st) for m, st in self._member_status.items()}

    def rebuild_active(self) -> bool:
        with self._lock:
            return any(t.is_alive() for t in self._threads.values())

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Join every rebuild worker; True when all finished in time."""
        with self._lock:
            threads = list(self._threads.values())
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in threads:
            if deadline is None:
                t.join()
            else:
                t.join(timeout=max(0.0, deadline - time.monotonic()))
                if t.is_alive():
                    return False
        return True

    def stop(self) -> None:
        """Stop the loops (rebuild state stays restartable: marked zones
        keep their ``_rebuilding`` entries)."""
        self._stop.set()
        self.stop_scrub()
        self.wait(timeout=10.0)
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None
        self._stop.clear()

    def _publish_progress(self) -> None:
        with self._lock:
            total = sum(st.get("zones_total", 0)
                        for st in self._member_status.values())
            done = sum(st.get("zones_done", 0)
                       for st in self._member_status.values())
            active = sum(1 for t in self._threads.values() if t.is_alive())
        self._g_total.set(total)
        self._g_done.set(done)
        self._g_progress.set(done / total if total else 1.0)
        self._g_active.set(active)

    # -------------------------------------------------------------- I/O
    def _sched_io(self, io_op: str, zone_id: int, *, tenant: str,
                  block_off: int = 0, n_blocks: Optional[int] = None,
                  data=None, member: Optional[int] = None):
        """One raw I/O through the scheduler's queues, synchronously: the
        command pays its way through WRR like any tenant's traffic."""
        sched = self.scheduler
        cmd_id = sched.submit_io(
            io_op, zone_id, block_off=block_off, n_blocks=n_blocks,
            data=data, tenant=tenant, member=member, block=True,
            _watch=True)
        if sched._thread is None:
            sched.drain()
        comp = sched.wait(cmd_id)
        if comp.error is not None:
            raise comp.error
        return comp.value

    def _read_logical(self, zone_id: int, base: int, n: int) -> np.ndarray:
        """Logical-extent read (degraded reconstruction included) on the
        rebuild tenant; ``(n, block_bytes)`` uint8."""
        if self.scheduler is not None:
            flat = self._sched_io("read", zone_id, tenant=self.rebuild_tenant,
                                  block_off=base, n_blocks=n)
        else:
            flat = self.array.read_blocks(zone_id, base, n)
        return np.asarray(flat).reshape(-1, self.array.block_bytes)

    def _append_member(self, member: int, zone_id: int,
                       payload: np.ndarray) -> None:
        if self.scheduler is not None:
            self._sched_io("append", zone_id, tenant=self.rebuild_tenant,
                           data=payload, member=member)
        else:
            self.array.devices[member].submit_append(
                zone_id, payload).result()

    def _read_member(self, member: int, zone_id: int, off: int,
                     n: int) -> np.ndarray:
        if self.scheduler is not None:
            flat = self._sched_io("read", zone_id, tenant=self.scrub_tenant,
                                  block_off=off, n_blocks=n, member=member)
        else:
            flat = self.array.devices[member].read_blocks(zone_id, off, n)
        return np.asarray(flat).reshape(-1, self.array.block_bytes)

    # ------------------------------------------------------------- rebuild
    def _rebuild_member(self, member: int) -> None:
        """Worker loop for one seat: reconstruct every marked zone, commit
        each at cutover, classify failures (source double fault vs spare
        death), restart onto the next spare if this one dies."""
        arr = self.array
        st = self._member_status[member]
        while not self._stop.is_set():
            zones = sorted(z for z, m in arr.rebuilding_zones().items()
                           if m == member)
            if not zones:
                break
            zone_id = zones[0]
            try:
                self._rebuild_zone(member, zone_id)
            except _SourceStopped:
                # stop(): the zone keeps its _rebuilding mark (partial copy
                # re-parked at the next begin_member_rebuild) — restartable
                with self._lock:
                    st["state"] = "stopped"
                self._publish_progress()
                return
            except _SpareWriteError as e:
                if self._restart_onto_next_spare(member, e):
                    st["restarts"] += 1
                    self._c_restarts.inc()
                    continue
                # pool empty: park every remaining marked zone and stop
                for z in zones:
                    arr.abandon_member_rebuild(z)
                st["state"] = "failed"
                st["zones_failed"].extend(zones)
                _publish_event(
                    "rebuild.failed", severity=_Sev.ERROR,
                    message=f"rebuild of member {member} failed (spare died, "
                            f"pool empty): {len(zones)} zone(s) abandoned",
                    member=member, zones=zones, error=str(e.__cause__ or e))
                self._publish_progress()
                return
            except Exception as e:
                # source-side failure: the survivors can no longer produce
                # this zone's bytes (xor double fault). Abandon THIS zone —
                # it goes OFFLINE through the redundancy math — and keep
                # rebuilding the rest.
                arr.abandon_member_rebuild(zone_id)
                st["zones_failed"].append(zone_id)
                _publish_event(
                    "rebuild.zone_failed", severity=_Sev.ERROR,
                    message=f"zone {zone_id} rebuild onto member {member} "
                            f"abandoned (source unrecoverable): {e}",
                    zone=zone_id, member=member, error=type(e).__name__)
            else:
                st["zones_done"] += 1
            self._publish_progress()
        with self._lock:
            if st["state"] == "running":
                left = [z for z, m in arr.rebuilding_zones().items()
                        if m == member]
                st["state"] = "stopped" if left else (
                    "degraded" if st["zones_failed"] else "complete")
        self._publish_progress()
        _publish_event(
            "rebuild.finished",
            severity=_Sev.INFO if not st["zones_failed"] else _Sev.WARNING,
            message=f"member {member} rebuild {st['state']}: "
                    f"{st['zones_done']} zone(s) rebuilt, "
                    f"{len(st['zones_failed'])} abandoned",
            member=member, state=st["state"], zones_done=st["zones_done"],
            zones_failed=list(st["zones_failed"]))

    def _rebuild_zone(self, member: int, zone_id: int) -> None:
        arr = self.array
        member_idx, wp = arr.begin_member_rebuild(zone_id)
        assert member_idx == member
        batch = self.rows_per_io * arr.stripe_blocks * arr.data_columns
        base = 0
        while base < wp:
            if self._stop.is_set():
                raise _SourceStopped(f"rebuild stopped at zone {zone_id}")
            n = min(batch, wp - base)
            logical = self._read_logical(zone_id, base, n)
            shard = arr.member_shard(member, logical, base_block=base)
            if len(shard):
                try:
                    self._append_member(member, zone_id, shard)
                except (ZNSError, OSError) as e:
                    raise _SpareWriteError(
                        f"spare write failed on zone {zone_id}") from e
            base += n
        arr.commit_member_rebuild(zone_id)

    def _restart_onto_next_spare(self, member: int, cause: Exception) -> bool:
        """The spare itself died mid-rebuild: swap in the next one (the
        marked zones carry over; committed-then-lost zones re-enter the
        pending set via replace_member) and keep the same worker going."""
        with self._lock:
            spare = self._pop_spare()
            if spare is None:
                return False
            try:
                pending = self.array.replace_member(member, spare)
            except Exception:
                self._spares.insert(0, spare)
                return False
            self._rebind_monitor(member, spare)
            st = self._member_status[member]
            st["zones_total"] = st["zones_done"] + len(pending)
            st["spare"] = getattr(spare, "dev_ordinal", None)
        _publish_event(
            "rebuild.restarted", severity=_Sev.WARNING,
            message=f"member {member} rebuild restarted onto spare "
                    f"dev{getattr(spare, 'dev_ordinal', '?')} after the "
                    f"previous spare failed: {cause.__cause__ or cause}",
            member=member, spare=getattr(spare, "dev_ordinal", None),
            pending=len(pending))
        return True

    # --------------------------------------------------------------- scrub
    def scrub(self, zones: Optional[Sequence[int]] = None) -> dict:
        """One full verification pass: every complete stripe row of every
        healthy zone is read back per member (low-priority ``scrub``
        tenant) and checked — raid1 partners byte-equal, xor rows XOR to
        zero, the tail row consistent with the host parity accumulator.
        Mismatches publish ``scrub.mismatch`` and charge
        ``scrub_mismatches`` on the implicated devices (the health monitor
        counts them as media errors). Degraded / rebuilding / raid0 zones
        are skipped — there is nothing redundant to cross-check. Returns
        ``{rows_verified, mismatches, zones_scrubbed, zones_skipped}``."""
        arr = self.array
        result = {"rows_verified": 0, "mismatches": 0,
                  "zones_scrubbed": 0, "zones_skipped": 0}
        if arr.redundancy == "raid0":
            result["zones_skipped"] = arr.num_zones
            return result
        s, C = arr.stripe_blocks, arr.data_columns
        for z in (range(arr.num_zones) if zones is None else zones):
            if self._stop.is_set():
                break
            with arr._lock:
                wp = arr._wp[z]
                skip = (z in arr._rebuilding
                        or bool(arr._offline_members(z)))
                tp = arr.tail_parity(z) if not skip else None
            if wp == 0:
                continue
            if skip:
                result["zones_skipped"] += 1
                continue
            mm = self._scrub_zone(z, wp, tp, s, C, result)
            result["mismatches"] += mm
            result["zones_scrubbed"] += 1
        self._c_passes.inc()
        return result

    def _scrub_zone(self, z: int, wp: int, tail_parity, s: int, C: int,
                    result: dict) -> int:
        """Verify one zone against snapshot ``wp``/``tail_parity`` (taken
        under the array lock — data below ``wp`` is immutable, so the reads
        need no lock). Returns the mismatch count."""
        arr = self.array
        mismatches = 0
        full_rows, rem = divmod(wp, s * C)
        batch_rows = max(self.rows_per_io, 1)
        for row0 in range(0, full_rows, batch_rows):
            k = min(batch_rows, full_rows - row0)
            spans = [self._read_member(i, z, row0 * s, k * s)
                     for i in range(arr.n_devices)]
            if arr.redundancy == "raid1":
                for c in range(C):
                    a, b = spans[2 * c], spans[2 * c + 1]
                    if not np.array_equal(a, b):
                        for r in range(k):
                            if not np.array_equal(a[r * s:(r + 1) * s],
                                                  b[r * s:(r + 1) * s]):
                                mismatches += 1
                                self._report_mismatch(
                                    z, row0 + r, [2 * c, 2 * c + 1],
                                    "mirror halves differ")
            else:
                acc = spans[0].copy()
                for sp in spans[1:]:
                    acc ^= sp
                if acc.any():
                    bad = acc.reshape(k, s, -1).any(axis=(1, 2))
                    for r in np.flatnonzero(bad):
                        row = row0 + int(r)
                        mismatches += 1
                        self._report_mismatch(
                            z, row, list(range(arr.n_devices)),
                            "row XOR is nonzero (parity inconsistent)")
            self._c_rows.inc(k)
            result["rows_verified"] += k
        if rem:
            mismatches += self._scrub_tail(z, full_rows, rem, tail_parity,
                                           s, C, result)
        return mismatches

    def _scrub_tail(self, z: int, row: int, rem: int, tail_parity,
                    s: int, C: int, result: dict) -> int:
        """Verify the incomplete tail row: raid1 compares the partners'
        landed spans; xor XORs the landed data spans against the host
        parity-accumulator snapshot (the value the row's parity chunk will
        have). Returns the mismatch count."""
        arr = self.array
        rem_chunks, partial = divmod(rem, s)

        def tail(col: int) -> int:
            if col < rem_chunks:
                return s
            return partial if col == rem_chunks else 0

        mismatches = 0
        if arr.redundancy == "raid1":
            for c in range(C):
                t = tail(c)
                if not t:
                    continue
                a = self._read_member(2 * c, z, row * s, t)
                b = self._read_member(2 * c + 1, z, row * s, t)
                if not np.array_equal(a, b):
                    mismatches += 1
                    self._report_mismatch(
                        z, row, [2 * c, 2 * c + 1],
                        "tail-row mirror halves differ")
        else:
            if tail_parity is None:
                return 0            # accumulator lost at recovery: unverifiable
            data_devs, _parity = arr._row_devices(row)
            acc = np.zeros((s, arr.block_bytes), np.uint8)
            for c in range(C):
                t = tail(c)
                if not t:
                    continue
                acc[:t] ^= self._read_member(data_devs[c], z, row * s, t)
            if not np.array_equal(acc, tail_parity):
                mismatches += 1
                self._report_mismatch(
                    z, row, [data_devs[c] for c in range(C) if tail(c)],
                    "tail-row data disagrees with the parity accumulator")
        self._c_rows.inc(1)
        result["rows_verified"] += 1
        return mismatches

    def _report_mismatch(self, zone_id: int, row: int, members: list[int],
                         detail: str) -> None:
        self._c_mismatch.inc()
        for m in members:
            dev = self.array.devices[m]
            try:
                dev.metrics.counter("scrub_mismatches").inc()
            except Exception:
                pass
        _publish_event(
            "scrub.mismatch", severity=_Sev.ERROR,
            message=f"scrub: zone {zone_id} stripe row {row} inconsistent "
                    f"({detail}; members {members})",
            zone=zone_id, row=row, members=members,
            redundancy=self.array.redundancy)

    def start_scrub(self, interval: float = 5.0) -> None:
        """Run :meth:`scrub` every ``interval`` seconds on a daemon thread
        (the cadence knob the README documents)."""
        if self._scrub_thread is not None and self._scrub_thread.is_alive():
            return
        self._scrub_stop = threading.Event()

        def loop() -> None:
            while not self._scrub_stop.wait(interval):
                self.scrub()

        self._scrub_thread = threading.Thread(
            target=loop, name="array-scrub", daemon=True)
        self._scrub_thread.start()

    def stop_scrub(self) -> None:
        t = self._scrub_thread
        if t is not None:
            self._scrub_stop.set()
            t.join(timeout=5.0)
            self._scrub_thread = None


class _SpareWriteError(Exception):
    """Internal: the spare (copy target) failed — restart onto the next."""


class _SourceStopped(Exception):
    """Internal: stop() interrupted a zone copy (zone stays restartable)."""
