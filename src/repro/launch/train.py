"""Cluster training driver.

Wires every substrate together for a real run: mesh + sharding rules ->
sharded train state -> zone-backed data pipeline (with pushdown) -> hedged
prefetch -> jit train_step -> zoned checkpoints with resume.

On real hardware, run one process per host (jax.distributed initializes from
the cluster env) with the same flags; on this CPU container it runs reduced
configs end-to-end:

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 50 --batch 8 --seq 128 --data 4 --model 2 \
      --host-devices 8 --ckpt /tmp/ckpt.zns
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--min-quality", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fake host device count (testing; must be first)")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import numpy as np

    from repro.configs import get_config, get_reduced
    from repro.data import PrefetchLoader, ZoneDataPipeline, ZoneDataStore
    from repro.launch.mesh import make_local_mesh
    from repro.sharding import param_shardings, rules_for, use_rules
    from repro.train.checkpoint import ZonedCheckpointStore
    from repro.train.optimizer import AdamWHyper
    from repro.train.step import TrainHyper, train_state_specs
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.zns import ZonedDevice

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"[launch] {args.arch}: {cfg.param_count() / 1e6:.1f}M params, "
          f"mesh data={args.data} model={args.model}")

    mesh = None
    state_sh = None
    rules = None
    if args.data * args.model > 1:
        mesh = make_local_mesh(args.data, args.model)
        rules = rules_for("train", cfg, mesh)
        state_sh = param_shardings(train_state_specs(cfg), mesh, rules)

    # ---- corpus in zones
    dev = ZonedDevice(num_zones=4, zone_bytes=64 * 1024 * 1024,
                      block_bytes=4096)
    store = ZoneDataStore(dev, seq_len=args.seq + 1)
    rng = np.random.default_rng(0)
    n = max(args.steps * args.batch * 2, 512)
    store.append_records(
        0, rng.integers(0, cfg.vocab_size, (n, args.seq + 1), dtype=np.int32),
        rng.integers(0, 100, n, dtype=np.int32))
    pipe = ZoneDataPipeline(store, batch=args.batch,
                            min_quality=args.min_quality)
    batches = PrefetchLoader(pipe.batches([0], epochs=8, seed=1), depth=4)

    ckpt = ZonedCheckpointStore(args.ckpt, num_zones=8,
                                zone_bytes=64 * 1024 * 1024) \
        if args.ckpt else None

    tcfg = TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        log_every=10,
        hyper=TrainHyper(grad_accum=args.grad_accum,
                         adamw=AdamWHyper(lr=args.lr, total_steps=args.steps)))
    trainer = Trainer(cfg, tcfg, store=ckpt, mesh=mesh,
                      state_shardings=state_sh)

    import contextlib
    ctx = contextlib.ExitStack()
    if mesh is not None:
        ctx.enter_context(use_rules(rules))
        ctx.enter_context(mesh)
    with ctx:
        last = trainer.run(batches)
    st = pipe.stats
    print(f"[launch] done: loss={last.get('loss', float('nan')):.4f}; "
          f"pushdown saved {st.movement_saved / 1e6:.1f} MB; "
          f"checkpoints at {ckpt.steps() if ckpt else '—'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
