"""Offload hot-path accounting: copies, compile-cache reuse, read/compute
overlap, checkpoint-path copies.

The paper's argument is that moving bytes is the bottleneck, so the emulation
must account for ITS OWN data movement honestly. Four measurements:

  1. **host bytes copied per offload** — the device counts ``bytes_copied``
     (host-side duplications) separately from ``bytes_viewed`` (zero-copy
     aliases of the backing buffer). The JIT/kernel tiers must reach XLA with
     AT MOST one host-side copy; on a single device the typed view makes that
     zero numpy-side copies (XLA's own device_put is the one unavoidable
     move) — asserted here, not just reported.
  2. **compile-cache hit rate** — distinct ``NvmCsd`` instances sharing one
     :class:`~repro.core.cache.CompiledProgramCache` must reuse executables:
     the second instance's offload reports ``jit_seconds == 0``.
  3. **read/compute overlap + array scaling** — with member bandwidth
     emulated (16 us per 4 KiB block, a QEMU-emulated-ZNS-class member),
     the staged read -> batched-compute -> combine pipeline must hide
     device transfer time under execution; reported as ``overlap_ratio``
     (1.0 = reads fully hidden) for 1..8 devices. The ISSUE-10 acceptance
     bar is ASSERTED on best-of-N walls: 8-device offload throughput must
     be >= the 4-device figure and >= 2x the single device's, or the
     array-scaling cliff is back.
  4. **checkpoint-path copies** — the checkpoint store counts its own host
     copies: restore must materialize each leaf with EXACTLY one host-side
     copy (the device bytes are read as zero-copy views) — asserted, so the
     ``tobytes()`` double-move can never silently come back.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import CsdTier, NvmCsd, filter_count
from repro.core.cache import CompiledProgramCache
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096


def _fill(device, data_bytes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    device.zone_append(0, data)
    return data


def measure_copies(data_mib: int = 8, runs: int = 3) -> dict:
    """Host-side bytes copied per single-device JIT-tier offload."""
    data_bytes = data_mib * 1024 * 1024
    dev = ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=BLOCK)
    data = _fill(dev, data_bytes)
    csd = NvmCsd(dev)
    program = filter_count("int32", "gt", RAND_MAX // 2)
    csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)   # warm-up pays compile
    copied0 = dev.stats["bytes_copied"]
    viewed0 = dev.stats["bytes_viewed"]
    times = []
    for _ in range(runs):
        t = time.perf_counter()
        csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        times.append(time.perf_counter() - t)
    assert int(csd.nvm_cmd_bpf_result()) == int((data > RAND_MAX // 2).sum())
    copied = (dev.stats["bytes_copied"] - copied0) / runs
    viewed = (dev.stats["bytes_viewed"] - viewed0) / runs
    # the acceptance bar is "at most ONE host-side copy per offload"; the
    # zero-copy read path actually delivers ZERO numpy-side copies (XLA
    # device_put is the single remaining move, inside the executable call),
    # so assert the stronger invariant
    assert copied == 0, (
        f"zero-copy read path regressed: {copied} host bytes copied/offload "
        f"for a {data_bytes}-byte extent")
    return {"seconds": float(np.mean(times)), "bytes_copied": copied,
            "bytes_viewed": viewed, "extent_bytes": data_bytes}


def measure_cache(data_mib: int = 8) -> dict:
    """Compile reuse across NvmCsd instances sharing one cache."""
    data_bytes = data_mib * 1024 * 1024
    shared = CompiledProgramCache()
    program = filter_count("int32", "gt", RAND_MAX // 2)
    jit_seconds = []
    results = []
    for seed in range(3):
        dev = ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=BLOCK)
        _fill(dev, data_bytes)       # same seed -> same data on every device
        csd = NvmCsd(dev, cache=shared)
        stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        jit_seconds.append(stats.jit_seconds)
        results.append(int(csd.nvm_cmd_bpf_result()))
    assert len(set(results)) == 1, "shared-cache executions disagree"
    assert all(s == 0.0 for s in jit_seconds[1:]), \
        f"cache hit still compiled: {jit_seconds}"
    cs = shared.stats()
    return {"first_jit_seconds": jit_seconds[0], "hit_rate": cs.hit_rate,
            "hits": cs.hits, "misses": cs.misses, "evictions": cs.evictions}


def measure_overlap(
    *,
    widths: tuple[int, ...] = (1, 2, 4, 8),
    data_mib: int = 8,
    stripe_blocks: int = 64,
    read_us_per_block: float = 16.0,
    runs: int = 5,
) -> list[dict]:
    """Read/compute overlap + scaling of striped offloads, 1..8 devices."""
    data_bytes = data_mib * 1024 * 1024
    rng = np.random.default_rng(0)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)
    out = []
    for n in widths:
        devices = [ZonedDevice(num_zones=1, zone_bytes=data_bytes,
                               block_bytes=BLOCK,
                               read_us_per_block=read_us_per_block)
                   for _ in range(n)]
        with StripedZoneArray(devices, stripe_blocks=stripe_blocks) as array:
            array.zone_append(0, data)
            copied0 = array.stats["bytes_copied"]
            with OffloadScheduler(array) as sched:
                sched.nvm_cmd_bpf_run(program, 0)          # warm-up
                overlap, times = [], []
                for _ in range(runs):
                    t = time.perf_counter()
                    stats = sched.nvm_cmd_bpf_run(program, 0)
                    times.append(time.perf_counter() - t)
                    overlap.append(stats.overlap_ratio)
                assert int(sched.nvm_cmd_bpf_result()) == expected
        copied = (array.stats["bytes_copied"] - copied0) / (runs + 1)
        # best-of-N: the pipeline's steady state, immune to host load
        # spikes that can double any individual run
        seconds = float(min(times))
        out.append({
            "devices": n,
            "seconds": seconds,
            "mib_per_s": data_mib / seconds,
            "overlap_ratio": float(np.mean(overlap)),
            "read_seconds": stats.read_seconds,
            "compute_seconds": stats.compute_seconds,
            "bytes_copied_per_offload": copied,
        })

    # ISSUE-10 acceptance bar, asserted where the numbers are recorded:
    # widening the array must keep paying off through 8 members (the old
    # thread-per-member fan-out peaked at 2 and FELL through 8).
    thr = {r["devices"]: r["mib_per_s"] for r in out}
    if 4 in thr and 8 in thr:
        assert thr[8] >= 0.97 * thr[4], (
            f"array-scaling cliff is back: 8-device offload throughput "
            f"{thr[8]:.0f} MiB/s < 4-device {thr[4]:.0f} MiB/s")
    if 1 in thr and 8 in thr:
        assert thr[8] >= 2.0 * thr[1], (
            f"8-device offload throughput {thr[8]:.0f} MiB/s is not >= 2x "
            f"the single device's {thr[1]:.0f} MiB/s")
        # and the reads must actually hide under compute at full width
        widest = out[-1]
        assert widest["overlap_ratio"] >= 0.5, (
            f"reads are not overlapping at {widest['devices']} devices: "
            f"overlap_ratio={widest['overlap_ratio']:.2f}")
    return out


def measure_checkpoint_copies(data_mib: int = 8) -> dict:
    """Host copies on the checkpoint save/restore path, asserted.

    Save stages each leaf once (serialization); restore reads leaf extents as
    device VIEWS and pays exactly ONE copy per leaf — the materialization
    that detaches it from the device buffer. A second copy per byte (the old
    ``tobytes()`` round-trip) trips the assert.
    """
    from repro.train.checkpoint import ZonedCheckpointStore
    from repro.zns import ZonedDevice
    leaf_bytes = data_mib * 1024 * 1024 // 4
    tree = {f"w{i}": np.arange(leaf_bytes // 4, dtype=np.int32)
            for i in range(4)}
    payload = sum(v.nbytes for v in tree.values())
    dev = ZonedDevice(num_zones=6, zone_bytes=data_mib * 1024 * 1024,
                      block_bytes=BLOCK)
    store = ZonedCheckpointStore(device=dev, keep=2)
    copied0 = store.stats["bytes_copied"]
    t0 = time.perf_counter()
    store.save(0, tree)
    save_seconds = time.perf_counter() - t0
    save_copied = store.stats["bytes_copied"] - copied0
    assert save_copied == payload, (
        f"save staged {save_copied} bytes for a {payload}-byte checkpoint "
        f"(expected exactly one serialization copy per leaf)")

    copied0 = store.stats["bytes_copied"]
    viewed0 = store.stats["bytes_viewed"]
    t0 = time.perf_counter()
    got = store.restore(like=tree)
    restore_seconds = time.perf_counter() - t0
    restore_copied = store.stats["bytes_copied"] - copied0
    restore_viewed = store.stats["bytes_viewed"] - viewed0
    assert restore_copied == payload, (
        f"restore copied {restore_copied} host bytes for a {payload}-byte "
        f"checkpoint — the one-copy-per-leaf contract regressed")
    assert restore_viewed >= payload   # leaf extents arrive as views
    assert all(np.array_equal(got[k], tree[k]) for k in tree)
    return {"save_seconds": save_seconds, "restore_seconds": restore_seconds,
            "payload_bytes": payload, "save_bytes_copied": save_copied,
            "restore_bytes_copied": restore_copied,
            "restore_bytes_viewed": restore_viewed}


def main(data_mib: int = 8, runs: int = 3) -> list[str]:
    rows = []
    c = measure_copies(data_mib=data_mib, runs=runs)
    rows.append(
        f"hotpath_copies_jit,{c['seconds'] * 1e6:.0f},"
        f"bytes_copied_per_offload={c['bytes_copied']:.0f};"
        f"bytes_viewed_per_offload={c['bytes_viewed']:.0f};"
        f"extent_bytes={c['extent_bytes']}"
    )
    k = measure_cache(data_mib=data_mib)
    rows.append(
        f"hotpath_compile_cache,{k['first_jit_seconds'] * 1e6:.0f},"
        f"hit_rate={k['hit_rate']:.2f};hits={k['hits']};misses={k['misses']};"
        f"evictions={k['evictions']}"
    )
    # scaling asserts want best-of-N stability even on the quick suite
    for r in measure_overlap(data_mib=data_mib, runs=max(runs, 5)):
        rows.append(
            f"hotpath_overlap_{r['devices']}dev,{r['seconds'] * 1e6:.0f},"
            f"overlap_ratio={r['overlap_ratio']:.2f};"
            f"mib_per_s={r['mib_per_s']:.1f};"
            f"bytes_copied_per_offload={r['bytes_copied_per_offload']:.0f}"
        )
    ck = measure_checkpoint_copies(data_mib=data_mib)
    rows.append(
        f"hotpath_ckpt_copies,{ck['restore_seconds'] * 1e6:.0f},"
        f"save_us={ck['save_seconds'] * 1e6:.0f};"
        f"payload_bytes={ck['payload_bytes']};"
        f"restore_bytes_copied={ck['restore_bytes_copied']};"
        f"restore_bytes_viewed={ck['restore_bytes_viewed']}"
    )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
