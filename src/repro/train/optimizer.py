"""AdamW with ZeRO-3 sharded state.

Moment tensors are declared as ParamSpecs with the *same logical axes* as
their parameters, so the FSDP rule ("embed" -> data axes) shards optimizer
state exactly like ZeRO-3 — each data shard owns 1/N of m/v and of the
parameters it updates; XLA's SPMD partitioner inserts the all-gathers on use
and keeps the update fully sharded.

Also here: int8 gradient compression with error feedback (an opt-in
distributed-optimization trick for DCN-crossing gradient reduction), and a
cosine LR schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

__all__ = [
    "AdamWHyper", "adamw_state_specs", "adamw_update", "cosine_lr",
    "compress_int8", "decompress_int8",
]


@dataclass(frozen=True)
class AdamWHyper:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def cosine_lr(h: AdamWHyper, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(h.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - h.warmup_steps)
                    / jnp.maximum(h.total_steps - h.warmup_steps, 1), 0.0, 1.0)
    return h.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_state_specs(param_specs: Any) -> dict:
    """m/v ParamSpec trees mirroring the parameter tree (f32, same axes)."""
    def f32(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, init="zeros", dtype=jnp.float32)
    is_spec = lambda x: isinstance(x, ParamSpec)
    return {
        "m": jax.tree.map(f32, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(f32, param_specs, is_leaf=is_spec),
    }


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, m, v, step, h: AdamWHyper):
    """One AdamW step in f32 math over (possibly bf16) params."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, h.grad_clip / (gnorm + 1e-9))
    lr = cosine_lr(h, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - h.b1 ** t
    bc2 = 1.0 - h.b2 ** t

    def upd(p, g, m_, v_):
        g = g.astype(jnp.float32) * scale
        m2 = h.b1 * m_ + (1.0 - h.b1) * g
        v2 = h.b2 * v_ + (1.0 - h.b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + h.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + h.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(m)
    flat_v = jax.tree.leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v, {"grad_norm": gnorm, "lr": lr}


# ----------------------------------------------------- gradient compression

def compress_int8(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback int8 quantization: returns (q, scale, new_err).
    Used before DCN-crossing (pod-axis) gradient reduction — 4x fewer bytes
    on the slowest link; the quantization error re-enters the next step."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
