"""Deterministic fault injection, retry/timeout policy, and crash harness.

Everything the repo needs to emulate an *unreliable* ZNS fleet lives here:

  * :mod:`repro.faults.errors` — the transient taxonomy
    (:class:`TransientIOError` and friends), distinct from the permanent
    ``ZNSError`` family.
  * :mod:`repro.faults.injector` — the seeded :class:`FaultInjector`
    consulted by device submit paths; every schedule replays from a seed.
  * :mod:`repro.faults.retry` — :class:`RetryPolicy` and the
    completion-callback retry controller (backoff in ring virtual time).
  * :mod:`repro.faults.crash` — the power-loss crash harness for striped
    checkpoint saves. Imported lazily (it pulls in the checkpoint store,
    which pulls in the device layer): use
    ``from repro.faults.crash import PowerLossHarness``.
"""
from repro.faults.errors import (IoTimeoutError, TornAppendError,
                                 TransientIOError)
from repro.faults.injector import FaultDecision, FaultInjector, FaultSpec
from repro.faults.retry import RetryPolicy, drive_retries, schedule_timer

__all__ = [
    "TransientIOError", "TornAppendError", "IoTimeoutError",
    "FaultInjector", "FaultSpec", "FaultDecision",
    "RetryPolicy", "drive_retries", "schedule_timer",
]
