"""Emulated NVMe Zoned Namespace (ZNS) device.

Semantics mirror the NVMe ZNS command set the paper targets (TP 4053, ratified
June 2020):

  * the LBA space is divided into fixed-size zones;
  * writes within a zone are append-only at the zone's write pointer
    ("Zone Append" command);
  * no in-place updates -- rewriting requires a host-managed ``reset_zone``;
  * zones move through an explicit state machine
    EMPTY -> (IMPLICITLY) OPEN -> FULL, with FINISH and RESET transitions
    driven by the host;
  * reads are block (LBA) granular and bounds-checked against the write
    pointer.

The device is backed either by host memory (default; fast, used by tests and
the data/KV substrates) or by a memory-mapped file (persistence for the
checkpoint store). Emulation knobs (``read_us_per_block``/``append_us_per_block``)
let benchmarks model device bandwidth, as QEMU does for the paper.
"""
from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import numpy as np

__all__ = [
    "ZoneState",
    "Zone",
    "ZonedDevice",
    "ZNSError",
    "ZoneFullError",
    "ZoneStateError",
    "OutOfBoundsError",
]


class ZNSError(Exception):
    """Base error for ZNS protocol violations."""


class ZoneFullError(ZNSError):
    """Append past the end of a zone."""


class ZoneStateError(ZNSError):
    """Operation illegal in the zone's current state."""


class OutOfBoundsError(ZNSError):
    """Read beyond the write pointer / zone capacity."""


class ZoneState(enum.Enum):
    EMPTY = "empty"
    OPEN = "open"           # implicitly opened by a first append
    FULL = "full"           # write pointer reached capacity or host FINISHed
    READ_ONLY = "read_only" # host transitioned (e.g. sealed checkpoint zone)
    OFFLINE = "offline"     # dead zone (injected for fault-tolerance tests)


@dataclass
class Zone:
    """Descriptor for one zone (mirrors the ZNS Zone Descriptor)."""

    zone_id: int
    start_lba: int            # first block of the zone in device LBA space
    capacity_blocks: int      # writable blocks in the zone
    write_pointer: int = 0    # next writable block, relative to start_lba
    state: ZoneState = ZoneState.EMPTY
    # Number of times this zone has been reset (wear proxy; the paper's GC
    # statistics build on host-visible reset counts).
    reset_count: int = 0
    cond: threading.Condition = field(
        default_factory=threading.Condition, repr=False, compare=False
    )
    # Serializes bandwidth-emulation sleeps at ZONE granularity: transfers
    # against one zone queue behind each other (one flash die), transfers
    # against different zones of the same device overlap — the intra-device
    # parallelism real ZNS hardware exposes (arXiv:2310.19094).
    io_gate: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    @property
    def remaining_blocks(self) -> int:
        return self.capacity_blocks - self.write_pointer

    @property
    def is_writable(self) -> bool:
        return self.state in (ZoneState.EMPTY, ZoneState.OPEN)


class ZonedDevice:
    """An emulated ZNS SSD: ``num_zones`` zones of ``zone_blocks`` blocks of
    ``block_bytes`` bytes.

    Defaults follow the paper's evaluation: 4 KiB blocks and 256 MiB zones
    (65536 blocks/zone).
    """

    def __init__(
        self,
        num_zones: int = 8,
        zone_bytes: int = 256 * 1024 * 1024,
        block_bytes: int = 4096,
        backing_file: Optional[Path | str] = None,
        read_us_per_block: float = 0.0,
        append_us_per_block: float = 0.0,
        max_open_zones: int = 0,  # 0 = unlimited (QEMU default)
    ):
        if zone_bytes % block_bytes != 0:
            raise ValueError("zone_bytes must be a multiple of block_bytes")
        self.num_zones = int(num_zones)
        self.block_bytes = int(block_bytes)
        self.zone_blocks = int(zone_bytes // block_bytes)
        self.zone_bytes = int(zone_bytes)
        self.read_us_per_block = float(read_us_per_block)
        self.append_us_per_block = float(append_us_per_block)
        self.max_open_zones = int(max_open_zones)
        self._lock = threading.RLock()

        total_bytes = self.num_zones * self.zone_bytes
        if backing_file is not None:
            path = Path(backing_file)
            mode = "r+" if path.exists() and path.stat().st_size == total_bytes else "w+"
            self._buf = np.memmap(path, dtype=np.uint8, mode=mode, shape=(total_bytes,))
            self._backing_file = path
        else:
            self._buf = np.zeros(total_bytes, dtype=np.uint8)
            self._backing_file = None

        self.zones = [
            Zone(zone_id=z, start_lba=z * self.zone_blocks,
                 capacity_blocks=self.zone_blocks)
            for z in range(self.num_zones)
        ]
        # device-level statistics (host-visible, like NVMe log pages);
        # bytes_copied/bytes_viewed account host-side data movement: the copy
        # path duplicates the extent into host memory, the view path hands out
        # an alias of the backing buffer (zero host copies).
        self.stats = {
            "blocks_read": 0,
            "blocks_appended": 0,
            "zone_resets": 0,
            "zone_finishes": 0,
            "bytes_copied": 0,
            "bytes_viewed": 0,
        }

    # ------------------------------------------------------------------ zones
    def zone(self, zone_id: int) -> Zone:
        if not 0 <= zone_id < self.num_zones:
            raise OutOfBoundsError(f"zone {zone_id} out of range [0,{self.num_zones})")
        return self.zones[zone_id]

    def report_zones(self) -> list[Zone]:
        """ZNS 'Zone Management Receive / Report Zones'."""
        return list(self.zones)

    def open_zones(self) -> list[Zone]:
        return [z for z in self.zones if z.state == ZoneState.OPEN]

    # ----------------------------------------------------------------- append
    def zone_append(self, zone_id: int, data: np.ndarray | bytes) -> int:
        """ZNS 'Zone Append': write ``data`` at the zone's write pointer.

        ``data`` must be a whole number of blocks (the device pads the final
        block with zeros, as a ZNS host library would). Returns the starting
        block index *relative to the zone* at which data landed.
        """
        raw = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray)) \
            else np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        nblocks = -(-raw.size // self.block_bytes)  # ceil
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.EMPTY:
                if self.max_open_zones and len(self.open_zones()) >= self.max_open_zones:
                    raise ZoneStateError("max open zones exceeded")
                z.state = ZoneState.OPEN
            if not z.is_writable:
                raise ZoneStateError(f"zone {zone_id} not writable (state={z.state})")
            if nblocks > z.remaining_blocks:
                raise ZoneFullError(
                    f"append of {nblocks} blocks exceeds zone {zone_id} "
                    f"remaining {z.remaining_blocks}"
                )
            start_rel = z.write_pointer
            off = (z.start_lba + start_rel) * self.block_bytes
            self._buf[off : off + raw.size] = raw
            pad = nblocks * self.block_bytes - raw.size
            if pad:
                self._buf[off + raw.size : off + raw.size + pad] = 0
            z.write_pointer += nblocks
            if z.write_pointer == z.capacity_blocks:
                z.state = ZoneState.FULL
            self.stats["blocks_appended"] += nblocks
        self._emulate_transfer(z, nblocks, self.append_us_per_block)
        return start_rel

    # ------------------------------------------------------------------- read
    def _emulate_transfer(self, z: Zone, nblocks: int, us_per_block: float) -> None:
        """Model the device transfer time OUTSIDE the device-wide lock.

        The lock only guards metadata and the buffer slice computation; the
        emulated busy time queues at per-zone granularity (``z.io_gate``), so
        concurrent transfers against different zones of one device overlap —
        without this, the array scheduler's fan-out parallelism is partly
        fake because every member read serializes the whole device.
        """
        if us_per_block and nblocks:
            with z.io_gate:
                time.sleep(nblocks * us_per_block * 1e-6)

    def _read_span(self, zone_id: int, block_off: int, nblocks: int,
                   *, copy: bool) -> tuple[Zone, np.ndarray]:
        """Bounds-check a read and return (zone, buffer) under ONE lock
        acquisition: an owned copy (``copy=True``, atomic w.r.t. writers) or
        a read-only view of the backing buffer. Byte accounting happens here
        too, so the hot path never re-takes the lock."""
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.OFFLINE:
                raise ZoneStateError(f"zone {zone_id} is offline")
            if block_off < 0 or nblocks < 0 or block_off + nblocks > z.write_pointer:
                raise OutOfBoundsError(
                    f"read [{block_off},{block_off + nblocks}) beyond write pointer "
                    f"{z.write_pointer} of zone {zone_id}"
                )
            off = (z.start_lba + block_off) * self.block_bytes
            span = self._buf[off : off + nblocks * self.block_bytes]
            self.stats["blocks_read"] += nblocks
            if copy:
                span = np.array(span)
                self.stats["bytes_copied"] += span.nbytes
            else:
                span = span.view()
                span.flags.writeable = False
                self.stats["bytes_viewed"] += span.nbytes
            return z, span

    def read_blocks(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Read ``nblocks`` blocks starting at ``block_off`` (zone-relative).

        Bounds-checked against the write pointer: reading unwritten blocks is
        a protocol error (this is the check the offloaded program's
        ``bpf_read`` hook relies on). Returns an owned COPY taken under the
        device lock (atomic even against a host that resets and rewrites the
        zone mid-read); the offload hot path uses :meth:`read_blocks_view` /
        :meth:`read_extent` instead.
        """
        z, out = self._read_span(zone_id, block_off, nblocks, copy=True)
        self._emulate_transfer(z, nblocks, self.read_us_per_block)
        return out

    def read_blocks_view(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Zero-copy variant of :meth:`read_blocks`: returns a read-only uint8
        VIEW of the device's backing buffer.

        The view stays valid as long as the extent is not rewritten (zones are
        append-only, so written blocks only change across a host-driven
        ``reset_zone`` — rewriting an extent while a reader holds it is a
        host protocol bug, exactly as it would be on real hardware).
        Consumers that feed XLA hand this view straight to the executable —
        the device-internal DMA the paper models, with at most the one copy
        XLA itself makes on device_put.
        """
        z, view = self._read_span(zone_id, block_off, nblocks, copy=False)
        self._emulate_transfer(z, nblocks, self.read_us_per_block)
        return view

    def read_extent(self, zone_id: int, block_off: int, nblocks: int,
                    dtype: np.dtype | str) -> np.ndarray:
        """Dtype-typed zero-copy read: :meth:`read_blocks_view` reinterpreted
        as ``dtype`` elements. Block offsets are always block-aligned in the
        backing buffer, which is stricter than any supported element
        alignment, so the reinterpretation never copies."""
        dtype = np.dtype(dtype)
        if self.block_bytes % dtype.itemsize:
            raise ValueError(
                f"block size {self.block_bytes} not a multiple of "
                f"{dtype} itemsize {dtype.itemsize}")
        return self.read_blocks_view(zone_id, block_off, nblocks).view(dtype)

    def read_zone(self, zone_id: int) -> np.ndarray:
        """Read every written block of a zone."""
        z = self.zone(zone_id)
        return self.read_blocks(zone_id, 0, z.write_pointer)

    # -------------------------------------------------------- zone management
    def finish_zone(self, zone_id: int) -> None:
        """ZNS 'Zone Management Send / Finish': host seals the zone."""
        with self._lock:
            z = self.zone(zone_id)
            if z.state not in (ZoneState.EMPTY, ZoneState.OPEN, ZoneState.FULL):
                raise ZoneStateError(f"cannot finish zone in state {z.state}")
            z.state = ZoneState.FULL
            self.stats["zone_finishes"] += 1

    def set_read_only(self, zone_id: int) -> None:
        with self._lock:
            self.zone(zone_id).state = ZoneState.READ_ONLY

    def reset_zone(self, zone_id: int) -> None:
        """ZNS 'Zone Management Send / Reset': host-managed GC.

        All data in the zone is discarded and the write pointer rewinds to 0.
        This is the paper's host-visible garbage-collection primitive.
        """
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.OFFLINE:
                raise ZoneStateError(f"zone {zone_id} is offline")
            z.write_pointer = 0
            z.state = ZoneState.EMPTY
            z.reset_count += 1
            self.stats["zone_resets"] += 1

    def set_offline(self, zone_id: int) -> None:
        """Fault injection: mark a zone dead (used by fault-tolerance tests)."""
        with self._lock:
            self.zone(zone_id).state = ZoneState.OFFLINE

    # ------------------------------------------------------------------ misc
    def flush(self) -> None:
        if self._backing_file is not None:
            self._buf.flush()

    @property
    def lba_size(self) -> int:
        """Block size in bytes (the ``bpf_get_lba_size`` hook's answer)."""
        return self.block_bytes

    def utilization(self) -> float:
        written = sum(z.write_pointer for z in self.zones)
        return written / float(self.num_zones * self.zone_blocks)
