"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400; fine-grained MoE: 2 shared + 64 routed experts, top-6;
first layer is a dense MLP. [arXiv:2401.06066; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    expert_d_ff=1408,
    first_layer_dense=True,
    dense_layer_d_ff=10944,
    # fine-grained experts are small (17 MB bf16): dispatch groups shard over
    # EVERY mesh axis and expert weights are gathered (FSDP-style) instead of
    # routing tokens across shards — see sharding.rules (§Perf iteration 2)
    moe_groups=512,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=64, expert_d_ff=64, num_experts=8, moe_top_k=2,
        num_shared_experts=1, vocab_size=512, dense_layer_d_ff=128,
        moe_groups=2, attn_chunk=32,
    )
