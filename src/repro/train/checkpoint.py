"""Zoned checkpoint store: fault-tolerant training state on ZNS semantics.

The checkpoint substrate is built directly on the paper's storage model:

  * **append-only**: a checkpoint is a sequence of zone appends (one record
    stream per pytree leaf) into data zones — never an in-place update;
  * **atomic commit**: the manifest (leaf index: zone/offset/shape/dtype +
    step + a payload checksum) is appended to a dedicated manifest zone
    LAST. Recovery scans the manifest zone and takes the newest manifest
    whose payload verifies — a torn/partial checkpoint (crash mid-write) is
    simply never referenced, mirroring log-structured FS commit records;
  * **host-managed GC**: freeing an old checkpoint = ``reset_zone`` on its
    data zones (the ZNS reset primitive; the device never garbage-collects
    behind the host's back);
  * **elastic restore**: leaves are stored as full logical arrays, so a
    checkpoint written on one mesh restores onto ANY mesh/sharding — the
    elastic-scaling path (grow/shrink the pod count between runs);
  * **asynchronous I/O**: ``save_async``/``restore_async`` put every leaf
    transfer in flight on the device's completion ring at once (different
    payload zones overlap on their virtual clocks) and return a
    :class:`CheckpointTicket` immediately — training steps run while
    checkpoint bytes move. Payload block offsets are taken from the append
    COMPLETIONS, exactly as real ZNS Zone Append reports the landing LBA in
    the CQ entry, and the manifest append is only submitted once every
    payload completion has retired (the commit-point ordering). Attach an
    :class:`~repro.array.OffloadScheduler` and the same transfers instead
    ride a tenant's submission queue, arbitrated (WRR) against live offload
    traffic.

Host-copy accounting: ``stats["bytes_copied"]``/``stats["bytes_viewed"]``
count the store's own data movement — leaf serialization staging on save, the
single materialization copy per leaf on restore, and the manifest-scan
buffer — the checkpoint-path extension of the device-level counters.
"""
from __future__ import annotations

import bisect
import hashlib
import itertools
import json
import threading
import time
import weakref
import zlib
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.telemetry import trace as _trace
from repro.telemetry.events import Severity as _Sev, publish as _publish_event
from repro.telemetry.metrics import MetricsRegistry, StatsView
from repro.zns import CompletionBarrier, IoFuture, ZonedDevice, ZoneState

__all__ = ["ZonedCheckpointStore", "CheckpointError", "CheckpointTicket"]

MANIFEST_MAGIC = "zcsd-ckpt-v1"

_STORE_SEQ = itertools.count()


class CheckpointError(Exception):
    pass


class CheckpointTicket:
    """Handle for an in-flight asynchronous checkpoint save/restore.

    ``result()`` blocks until every underlying transfer completion has
    retired, then runs the finalize step (manifest return for saves; checksum
    verify + pytree assembly + optional ``device_put`` for restores) in the
    CALLER's thread — reactor callbacks never touch JAX.
    """

    def __init__(self, fut: IoFuture,
                 finalize: Optional[Callable[[Any], Any]] = None):
        self._fut = fut
        self._finalize = finalize
        self._final: Any = None
        self._finalized = False
        self._lock = threading.Lock()

    def done(self) -> bool:
        """True once every underlying transfer has retired (the finalize step
        still runs at the first ``result()``)."""
        return self._fut.done()

    def result(self, timeout: Optional[float] = None):
        raw = self._fut.result(timeout)
        if self._finalize is None:
            return raw
        with self._lock:
            if not self._finalized:
                self._final = self._finalize(raw)
                self._finalized = True
            return self._final


def _leaf_to_bytes(x) -> tuple[bytes, str, tuple]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jax.numpy.bfloat16:
        return arr.view(np.uint16).tobytes(), "bfloat16", arr.shape
    return arr.tobytes(), str(arr.dtype), arr.shape


def _leaf_from_bytes(raw, dtype: str, shape: tuple) -> np.ndarray:
    """Materialize one leaf from a bytes-like buffer (device view or bytes)
    with exactly ONE host copy — the ``.copy()`` that detaches the leaf from
    the device's backing buffer."""
    if dtype == "bfloat16":
        import ml_dtypes
        return np.frombuffer(raw, np.uint16).view(
            ml_dtypes.bfloat16).reshape(shape).copy()
    return np.frombuffer(raw, np.dtype(dtype)).reshape(shape).copy()


class ZonedCheckpointStore:
    """Checkpoints on a (file-backed) ZonedDevice.

    Zone 0 is the manifest zone; zones 1..N-1 hold payload. Payload zones are
    used round-robin per checkpoint generation so GC (zone reset) can reclaim
    whole generations.

    ``scheduler`` (optional) routes save/restore I/O through that scheduler's
    submission queues under ``tenant`` — checkpoint transfers then share WRR
    arbitration and SQ admission control with offload traffic instead of
    bypassing it straight to the device ring.
    """

    def __init__(self, path: Optional[Path | str] = None, *,
                 device: Optional[ZonedDevice | StripedZoneArray] = None,
                 num_zones: int = 16,
                 zone_bytes: int = 256 * 1024 * 1024,
                 keep: int = 2,
                 scheduler: Optional[OffloadScheduler] = None,
                 tenant: str = "checkpoint"):
        if device is None:
            device = ZonedDevice(num_zones=num_zones, zone_bytes=zone_bytes,
                                 block_bytes=4096,
                                 backing_file=path)
        self.device = device
        self.keep = keep
        # store-level host-copy accounting (the device counters only see
        # device-side moves; serialization/materialization happen here).
        # Stores are unbounded, so the series live on a private per-store
        # registry; `stats` keeps its dict shape as a live view.
        self.metrics = MetricsRegistry(f"ckpt{next(_STORE_SEQ)}")
        self._c_bytes_copied = self.metrics.counter("bytes_copied")
        self._c_bytes_viewed = self.metrics.counter("bytes_viewed")
        self._h_save = self.metrics.histogram("save_seconds")
        self._h_restore = self.metrics.histogram("restore_seconds")
        self.stats = StatsView({"bytes_copied": self._c_bytes_copied,
                                "bytes_viewed": self._c_bytes_viewed})
        self._mlock = threading.Lock()   # manifests list + placement state
        # blocks placed but whose append completion has not yet retired, per
        # zone: overlapping save_asyncs place against remaining_blocks MINUS
        # these, so queued appends can never over-commit a zone. (Released at
        # completion, so the check is conservative while transfers are in
        # flight — a spurious "no room" beats a torn zone.)
        self._reserved: dict[int, int] = {}
        # zones with in-flight checkpoint I/O (count of such operations per
        # zone): an UNCOMMITTED save's targets — its manifest does not exist
        # yet, so the live-set alone cannot protect them — and an in-flight
        # restore's sources, whose manifest a concurrent gc may evict. gc()
        # must never reset these. Held from placement/read-submission until
        # the operation's ticket settles.
        self._pinned_zones: dict[int, int] = {}
        self._scheduler: Optional[OffloadScheduler] = None
        self._tenant = tenant
        if scheduler is not None:
            self.attach_scheduler(scheduler, tenant=tenant)
        self._recover()

    def attach_scheduler(self, scheduler: OffloadScheduler, *,
                         tenant: str = "checkpoint", weight: int = 1) -> None:
        """Route subsequent save/restore I/O through ``scheduler``'s queues
        (registering ``tenant`` if needed). The scheduler must drive the same
        array this store was built over."""
        if scheduler.array is not self.device:
            raise CheckpointError(
                "scheduler drives a different device than this store")
        if tenant not in scheduler._pairs:
            scheduler.register_tenant(tenant, weight=weight)
        self._scheduler = scheduler
        self._tenant = tenant

    @classmethod
    def striped(cls, directory: Path | str, *, num_devices: int = 4,
                num_zones: int = 16,
                member_zone_bytes: int = 64 * 1024 * 1024,
                stripe_blocks: int = 256, keep: int = 2,
                redundancy: str = "raid0",
                fault_injector=None, retry_policy=None,
                ) -> "ZonedCheckpointStore":
        """Checkpoint store over a striped array of file-backed ZNS devices.

        Leaf payloads stripe across ``num_devices`` member files
        (``directory/member{i}.zns``) in ``stripe_blocks``-block chunks —
        save/restore bandwidth aggregates over every member, and a reopened
        store recovers the striped manifests exactly like the single-device
        path (the logical zone's write pointer distributes to the members).
        With ``redundancy`` ``"raid1"`` or ``"xor"`` a checkpoint written
        healthy restores bit-identically even after a member zone goes
        OFFLINE mid-restore — the array reconstructs the dead member's
        chunks from the mirror partner / the surviving row members on the
        same completion ring the restore reads ride.

        The array geometry (redundancy mode included) is persisted to
        ``directory/array.json`` on first use and ADOPTED on reopen — a
        stale geometry would de-interleave member blocks in the wrong order
        and render every checkpoint unreadable, so the sidecar, not the
        arguments, is the truth for an existing store.

        ``fault_injector``/``retry_policy`` arm every member device with the
        fault-injection machinery (keyed by member index, the stable
        identity fault schedules replay under) — checkpoint saves then ride
        the same retry/timeout datapath as any other array traffic.
        """
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        sidecar = directory / "array.json"
        geometry = {
            "num_devices": num_devices, "num_zones": num_zones,
            "member_zone_bytes": member_zone_bytes,
            "stripe_blocks": stripe_blocks,
            "redundancy": redundancy,
        }
        if sidecar.exists():
            geometry = json.loads(sidecar.read_text())
        else:
            sidecar.write_text(json.dumps(geometry))
        devices = [
            ZonedDevice(num_zones=geometry["num_zones"],
                        zone_bytes=geometry["member_zone_bytes"],
                        block_bytes=4096,
                        backing_file=directory / f"member{i}.zns",
                        fault_injector=fault_injector, fault_key=i,
                        retry_policy=retry_policy)
            for i in range(geometry["num_devices"])
        ]
        array = StripedZoneArray(devices,
                                 stripe_blocks=geometry["stripe_blocks"],
                                 redundancy=geometry.get("redundancy",
                                                         "raid0"))
        return cls(device=array, keep=keep)

    # ----------------------------------------------------------- I/O routing
    def _io_append(self, zone_id: int, raw: bytes,
                   cb: Callable[[Optional[BaseException], Any], None]) -> None:
        """Submit one payload append on the configured path — scheduler SQ
        (overlapping with offload traffic under WRR) or the device ring
        directly. ``cb(error, landed_block)`` fires when the completion
        retires. Queue submission BLOCKS on a full SQ rather than raising:
        called from the saver's thread while the dispatcher keeps draining,
        so a checkpoint with more leaves than the queue depth is admitted in
        waves instead of failing. (The SQ bounds queued commands — dispatch
        forwards to the ring without blocking, so in-flight transfer count is
        bounded by the device's zone clocks, not the queue depth.)"""
        if self._scheduler is not None:
            self._scheduler.start()   # idempotent; queued I/O needs a pump
            self._scheduler.submit_io(
                "append", zone_id, data=np.frombuffer(raw, np.uint8),
                tenant=self._tenant, block=True,
                on_complete=lambda comp: cb(comp.error, comp.value))
        else:
            self.device.submit_append(zone_id, raw).add_done_callback(
                lambda f: cb(f.error, f._value))

    def _io_read(self, zone_id: int, block_off: int, nblocks: int,
                 cb: Callable[[Optional[BaseException], Any], None]) -> None:
        if self._scheduler is not None:
            self._scheduler.start()
            self._scheduler.submit_io(
                "read", zone_id, block_off=block_off, n_blocks=nblocks,
                tenant=self._tenant, block=True,
                on_complete=lambda comp: cb(comp.error, comp.value))
        else:
            self.device.submit_read(zone_id, block_off, nblocks) \
                .add_done_callback(lambda f: cb(f.error, f._value))

    # --------------------------------------------------------------- write
    def save(self, step: int, tree: Any) -> dict:
        """Append a checkpoint synchronously; returns its manifest. The
        payload transfers still move through the completion ring in parallel
        (distinct payload zones overlap) — this just blocks at the commit
        point, then garbage-collects."""
        manifest = self.save_async(step, tree).result()
        self.gc()
        return manifest

    def save_async(self, step: int, tree: Any) -> CheckpointTicket:
        """Put a whole checkpoint's appends in flight and return immediately.

        Per-leaf landing blocks are read from the append COMPLETIONS (the
        ZNS Zone Append contract: the LBA arrives in the CQ entry), the
        manifest append is submitted only after every payload completion has
        retired, and the ticket resolves with the manifest once the commit
        record is durable. GC is deliberately NOT run here — call
        :meth:`gc` (or use :meth:`save`) from the training thread.
        """
        t0 = time.monotonic()
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        payloads: list[tuple[str, bytes, str, tuple]] = []
        crc = 0
        for path_, leaf in leaves:
            raw, dtype, shape = _leaf_to_bytes(leaf)
            crc = zlib.crc32(raw, crc)
            self._c_bytes_copied.inc(len(raw))   # serialization staging
            payloads.append((jax.tree_util.keystr(path_), raw, dtype, shape))

        ticket_fut = IoFuture(op="ckpt-save")
        n = len(payloads)
        # barrier lifetime (serialization -> commit-record durable) as a span
        # on the shared monotonic clock, so checkpoint saves line up against
        # device/offload tracks in the exported trace
        ticket_fut.add_done_callback(
            lambda f: self._observe_ticket("save", t0, f, step=step,
                                           leaves=n))
        entries: list[Optional[dict]] = [None] * n
        save_zones: list[int] = []   # uncommitted-zone guard, released at settle

        def on_payload(i: int, err: Optional[BaseException], landed) -> None:
            e = entries[i]
            nblocks = -(-e["bytes"] // self.device.block_bytes)
            with self._mlock:
                self._reserved[e["zone"]] -= nblocks   # transfer settled
            if err is None:
                e["block"] = int(landed)
            barrier.settle(i, err)

        # placement: chosen against live zone metadata MINUS the in-flight
        # reservations under the store lock; with direct ring routing member
        # metadata advances at submission, so consecutive leaves stack
        # correctly. (Queue routing defers the append to dispatch; the
        # landing block is still exact — it comes from the completion — and
        # the FIFO SQ preserves this save's append order.)
        with self._mlock:
            zone_ids = self._pick_payload_zones()
            placed_blocks: list[tuple[int, int]] = []   # rollback on failure
            zi = 0
            try:
                for i, (path_str, raw, dtype, shape) in enumerate(payloads):
                    nblocks = -(-len(raw) // self.device.block_bytes)
                    placed = False
                    for attempt in range(len(zone_ids)):
                        zid = zone_ids[(zi + attempt) % len(zone_ids)]
                        z = self.device.zone(zid)
                        if z.is_writable and nblocks + \
                                self._reserved.get(zid, 0) <= z.remaining_blocks:
                            zi = (zi + attempt) % len(zone_ids)
                            self._reserved[zid] = \
                                self._reserved.get(zid, 0) + nblocks
                            placed_blocks.append((zid, nblocks))
                            entries[i] = {
                                "path": path_str, "zone": zid, "block": -1,
                                "bytes": len(raw), "dtype": dtype,
                                "shape": list(shape),
                            }
                            placed = True
                            break
                    if not placed:
                        raise CheckpointError(
                            "no payload zone has room; raise num_zones")
            except BaseException:
                for zid, nblocks in placed_blocks:
                    self._reserved[zid] -= nblocks
                raise
            save_zones.extend({zid for zid, _ in placed_blocks})
            for zid in save_zones:
                self._pinned_zones[zid] = self._pinned_zones.get(zid, 0) + 1

        barrier = CompletionBarrier(
            n, lambda _vals, err: self._commit(step, entries, crc, treedef,
                                               err, save_zones, ticket_fut))
        for i, (path_str, raw, dtype, shape) in enumerate(payloads):
            try:
                self._io_append(entries[i]["zone"], raw,
                                lambda err, landed, i=i:
                                on_payload(i, err, landed))
            except BaseException as e:
                # a failed submission settles this leaf with an error: the
                # barrier still fires and the ticket fails loudly instead of
                # hanging (earlier leaves' completions drain normally)
                on_payload(i, e, None)
        return CheckpointTicket(ticket_fut)

    def _observe_ticket(self, op: str, t0: float,
                        fut: Optional[IoFuture] = None, **tags) -> None:
        """Record one async ticket's barrier lifetime (submission entry to
        last completion retired) — runs on whichever thread settles the
        final transfer, so it must stay allocation-light."""
        dt = time.monotonic() - t0
        (self._h_save if op == "save" else self._h_restore).observe(dt)
        if _trace.enabled():
            _trace.event_complete(f"ckpt.{op}", t0, dt, track="checkpoint",
                                  **tags)
        if fut is not None and fut.error is not None:
            # failed tickets surface in the operator event stream too, not
            # only to the caller holding the ticket
            _publish_event(
                "ckpt.ticket_failed", severity=_Sev.ERROR,
                message=f"checkpoint {op} ticket failed after {dt:.3f}s: "
                        f"{fut.error}",
                op=op, error=type(fut.error).__name__, **tags)

    def _release_pins(self, zones: list[int]) -> None:
        with self._mlock:
            for zid in zones:
                self._pinned_zones[zid] -= 1

    def _commit(self, step: int, entries, crc: int, treedef,
                error: Optional[BaseException], save_zones: list[int],
                ticket_fut: IoFuture) -> None:
        """The commit point: every payload completion has retired. Submit the
        manifest append; the checkpoint exists once ITS completion retires.

        The manifest goes STRAIGHT to the device ring, never through the
        scheduler queues: this may run on the dispatcher's own thread (an
        inline payload completion), where blocking on a full SQ would
        deadlock the dispatcher against itself — and the commit record is
        metadata-sized, so there is nothing for the arbiter to meter. The
        payload barrier already guarantees commit ordering on either path.
        Any failure here (e.g. a full manifest zone) fails the ticket — a
        callback context must surface errors through the ticket, not raise.
        Every terminal branch releases the save's zone pins.
        """
        if error is not None:
            self._release_pins(save_zones)
            ticket_fut.fail(error)
            return
        try:
            manifest = {
                "magic": MANIFEST_MAGIC, "step": int(step),
                "entries": entries, "crc32": crc,
                "treedef": str(treedef),
            }
            raw = json.dumps(manifest).encode()
            header = len(raw).to_bytes(8, "little") \
                + hashlib.sha256(raw).digest()

            def on_manifest(f: IoFuture) -> None:
                self._release_pins(save_zones)
                if f.error is not None:
                    ticket_fut.fail(f.error)
                    return
                with self._mlock:
                    # overlapping save_asyncs may commit out of step order
                    # (a small step-2 can retire before a fat step-1): keep
                    # the list sorted by step so latest_step()/restore(None)/
                    # gc(keep=...) mean "newest STEP", not "last to land"
                    bisect.insort(self._manifests, manifest,
                                  key=lambda m: m["step"])
                ticket_fut.complete(manifest)

            self.device.submit_append(0, header + raw) \
                .add_done_callback(on_manifest)
        except BaseException as e:
            self._release_pins(save_zones)
            ticket_fut.fail(e)

    def _pick_payload_zones(self) -> list[int]:
        ids = [z.zone_id for z in self.device.zones[1:]
               if z.state in (ZoneState.EMPTY, ZoneState.OPEN)]
        if not ids:
            raise CheckpointError("no writable payload zones (GC needed)")
        # prefer empty zones so each generation owns whole zones
        ids.sort(key=lambda i: (self.device.zone(i).write_pointer, i))
        return ids

    # ---------------------------------------------------------------- read
    def _recover(self) -> None:
        """Scan the manifest zone for valid commit records (crash recovery).
        Covers both the live-device case and a file-backed reopen, where the
        zone metadata is volatile and the log is the truth."""
        self._manifests: list[dict] = []
        self._scan_raw_manifest_zone()
        # the manifest log is in commit order; overlapping async saves may
        # have committed out of step order — normalize (stable, so same-step
        # rewrites keep the later commit last, as _find_manifest expects)
        self._manifests.sort(key=lambda m: m["step"])

    def _scan_raw_manifest_zone(self) -> None:
        bb = self.device.block_bytes
        z = self.device.zone(0)
        # read every block that may contain manifests
        max_blocks = z.write_pointer if z.write_pointer else z.capacity_blocks
        if z.write_pointer == 0:
            z.write_pointer = z.capacity_blocks  # allow raw scan
            raw = self.device.read_blocks_view(0, 0, max_blocks or z.capacity_blocks)
            z.write_pointer = 0
        else:
            raw = self.device.read_blocks_view(0, 0, z.write_pointer)
        self._c_bytes_viewed.inc(raw.nbytes)
        buf = raw.tobytes()    # the one copy: bytes for the header parser
        self._c_bytes_copied.inc(len(buf))
        off = 0
        found_blocks = 0
        while off + 40 <= len(buf):
            ln = int.from_bytes(buf[off : off + 8], "little")
            if ln == 0 or ln > 64 * 1024 * 1024 or off + 40 + ln > len(buf):
                # skip to next block boundary
                off = ((off // bb) + 1) * bb
                if off >= len(buf):
                    break
                continue
            digest = buf[off + 8 : off + 40]
            body = buf[off + 40 : off + 40 + ln]
            if hashlib.sha256(body).digest() == digest:
                try:
                    m = json.loads(body)
                    if m.get("magic") == MANIFEST_MAGIC:
                        self._manifests.append(m)
                        found_blocks = -(-(off + 40 + ln) // bb)
                except json.JSONDecodeError:
                    pass
                off = ((off + 40 + ln + bb - 1) // bb) * bb
            else:
                off = ((off // bb) + 1) * bb
        if z.write_pointer == 0 and found_blocks:
            # restore the manifest zone's write pointer after a reopen
            z.write_pointer = found_blocks
            z.state = ZoneState.OPEN
        # restore payload zone write pointers from the surviving manifests —
        # ONE assignment per zone (max over its entries), not one per entry:
        # on a striped array the setter redistributes every member write
        # pointer (and under xor re-reads the tail row into the parity
        # accumulator), so per-entry assignment would repeat that work
        # O(entries) times
        ends: dict[int, int] = {}
        for m in self._manifests:
            for e in m["entries"]:
                end = e["block"] + -(-e["bytes"] // bb)
                if end > ends.get(e["zone"], 0):
                    ends[e["zone"]] = end
        for zid, end in ends.items():
            zz = self.device.zone(zid)
            if end > zz.write_pointer:
                zz.write_pointer = end
                if zz.state == ZoneState.EMPTY:
                    zz.state = ZoneState.OPEN

    def latest_step(self) -> Optional[int]:
        return self._manifests[-1]["step"] if self._manifests else None

    def steps(self) -> list[int]:
        return [m["step"] for m in self._manifests]

    def _find_manifest_locked(self, step: Optional[int]) -> dict:
        """Manifest lookup; caller holds ``_mlock``."""
        if not self._manifests:
            raise CheckpointError("no checkpoints found")
        manifest = self._manifests[-1] if step is None else next(
            (m for m in reversed(self._manifests) if m["step"] == step),
            None)
        if manifest is None:
            raise CheckpointError(
                f"step {step} not found; have "
                f"{[m['step'] for m in self._manifests]}")
        return manifest

    def _find_manifest(self, step: Optional[int]) -> dict:
        with self._mlock:
            return self._find_manifest_locked(step)

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shardings: Any = None) -> Any:
        """Restore a checkpoint as a pytree (synchronous shim over
        :meth:`restore_async`: every leaf read is in flight at once — payload
        zones overlap on their virtual clocks — and this blocks at the join).

        ``like`` supplies the treedef (e.g. abstract state); ``shardings``
        (optional NamedSharding tree) device_puts each leaf — restoring onto
        a *different* mesh than the one that wrote it (elastic scaling).
        """
        return self.restore_async(step, like=like, shardings=shardings).result()

    def restore_async(self, step: Optional[int] = None, *, like: Any = None,
                      shardings: Any = None) -> CheckpointTicket:
        """Put every leaf's read in flight and return a ticket; the checksum
        verify, pytree assembly, and (optional) ``device_put`` run in the
        caller's thread at ``result()`` time."""
        if like is None:
            raise CheckpointError("restore requires `like` for the treedef")
        t0 = time.monotonic()
        ticket_fut = IoFuture(op="ckpt-restore")
        # Manifest lookup and source-zone pinning happen under ONE _mlock
        # critical section: gc() also sweeps under it, so there is no window
        # where the manifest is found but its zones can still be reset. The
        # pin holds for the restore's lifetime — a concurrent save() may
        # evict this manifest, at which point only the pin stops the sweep
        # from resetting the zones under our in-flight reads and zero-copy
        # views. Released once: at failure, after finalize has detached
        # every leaf from the device buffer, or when an unfinalized ticket
        # is garbage-collected (abandoned after a result() timeout).
        with self._mlock:
            manifest = self._find_manifest_locked(step)
            entries = manifest["entries"]
            restore_zones = sorted({e["zone"] for e in entries})
            for zid in restore_zones:
                self._pinned_zones[zid] = self._pinned_zones.get(zid, 0) + 1
        released = [False]

        def release_once() -> None:
            with self._mlock:
                if released[0]:
                    return
                released[0] = True
                for zid in restore_zones:
                    self._pinned_zones[zid] -= 1

        def on_done(parts, err: Optional[BaseException]) -> None:
            if err is not None:
                release_once()
                ticket_fut.fail(err)
            else:
                ticket_fut.complete(parts)

        barrier = CompletionBarrier(len(entries), on_done)

        def finalize(raw_parts: list[np.ndarray]) -> Any:
            arrays = []
            crc = 0
            try:
                for e, raw in zip(entries, raw_parts):
                    raw = np.asarray(raw).reshape(-1)[: e["bytes"]]
                    self._c_bytes_viewed.inc(raw.nbytes)
                    crc = zlib.crc32(raw, crc)
                    arrays.append(
                        _leaf_from_bytes(raw, e["dtype"], tuple(e["shape"])))
                    self._c_bytes_copied.inc(arrays[-1].nbytes)
            finally:
                # every leaf is now an owned copy (or we are failing): the
                # device zones may be recycled
                release_once()
            if crc != manifest["crc32"]:
                raise CheckpointError(
                    "payload checksum mismatch (torn checkpoint?)")
            flat_like, treedef = jax.tree_util.tree_flatten(like)
            if len(flat_like) != len(arrays):
                raise CheckpointError(
                    f"leaf count mismatch: ckpt {len(arrays)} vs like "
                    f"{len(flat_like)}")
            tree = jax.tree_util.tree_unflatten(treedef, arrays)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), tree, shardings)
            return tree

        for i, e in enumerate(entries):
            nblocks = -(-e["bytes"] // self.device.block_bytes)
            try:
                self._io_read(e["zone"], e["block"], nblocks,
                              lambda err, value, i=i:
                              barrier.settle(i, err, value))
            except BaseException as err:
                barrier.settle(i, err)   # settle the leaf; ticket fails loudly
        ticket_fut.add_done_callback(
            lambda f: self._observe_ticket(
                "restore", t0, f, step=manifest["step"],
                leaves=len(entries)))
        ticket = CheckpointTicket(ticket_fut, finalize)
        # abandoned ticket (e.g. result() timed out and the caller moved on):
        # the pins must not outlive it, or gc could never reclaim the zones
        weakref.finalize(ticket, release_once)
        return ticket

    # ------------------------------------------------------------------ GC
    def gc(self) -> int:
        """Host-managed GC: drop all but the newest ``keep`` checkpoints and
        reset any payload zone no longer referenced (the ZNS reset story)."""
        resets = 0
        # the reset loop runs UNDER the store lock: placement also runs under
        # it, so no save_async can claim a zone between the live-set snapshot
        # and its reset (the lock orders strictly before the device lock
        # reset_zone takes; nothing takes them in the other order)
        with self._mlock:
            if len(self._manifests) <= self.keep:
                return 0
            self._manifests = self._manifests[-self.keep:]
            live = {(e["zone"]) for m in self._manifests for e in m["entries"]}
            # zones with in-flight checkpoint I/O — an uncommitted save's
            # targets or an active restore's sources — must survive the sweep
            live |= {zid for zid, n in self._pinned_zones.items() if n > 0}
            for z in self.device.zones[1:]:
                if z.zone_id not in live and z.write_pointer > 0:
                    self.device.reset_zone(z.zone_id)
                    resets += 1
        return resets

    def flush(self) -> None:
        self.device.flush()
