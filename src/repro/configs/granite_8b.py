"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152; llama-architecture code model. [arXiv:2405.04324; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, attn_chunk=32,
    )
