"""Power-loss crash-consistency harness for the zoned checkpoint store.

The atomic-commit claim of :class:`repro.train.ZonedCheckpointStore` is that
a checkpoint exists exactly when its manifest append is durable — payload
appends land first, the manifest lands last, and recovery takes the newest
manifest whose payload verifies. :class:`PowerLossHarness` tests that claim
*exhaustively* instead of at a few hand-picked points:

  1. run a scripted sequence of checkpoint saves against a live striped
     store while journaling every **member-device append completion** (the
     emulator's unit of durability — one journal entry per member chunk, in
     retirement order);
  2. for every prefix ``journal[:k]`` — i.e. *power loss between any two
     append completions*, including ``k=0`` (loss before anything landed)
     and mid-stripe cuts where one mirror of a pair has the manifest and the
     other does not — rebuild a fresh set of member files containing exactly
     those ``k`` completed appends and nothing else;
  3. reopen the truncated store through the normal recovery scan and demand
     one of exactly two outcomes: a **bit-exact restore** of some checkpoint
     between ``lo(k)`` (the newest save *fully* durable at the cut) and
     ``hi(k)`` (the newest save whose manifest had *started* landing — a
     half-mirrored commit record may legitimately be readable), or a **clean
     refusal** (``CheckpointError``) only while no save is fully durable.
     A torn restore — wrong step, wrong bytes, or an unhandled crash in
     recovery — fails the whole sweep.

The harness is deterministic: member completions retire in virtual-time
order, so the journal (and therefore the boundary set) is identical across
runs with the same inputs.
"""
from __future__ import annotations

import json
import shutil
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional, Sequence

import numpy as np

__all__ = ["PowerLossHarness", "CrashOutcome", "CrashConsistencyError"]


class CrashConsistencyError(AssertionError):
    """A crash boundary recovered to a torn/impossible state."""


@dataclass(frozen=True)
class CrashOutcome:
    """Result of recovery at one power-loss boundary.

    ``boundary`` is the number of member append completions that were
    durable at the cut; ``recovered_step`` is what recovery restored
    (``None`` on refusal); ``lo``/``hi`` bound the steps recovery was
    allowed to yield; ``refused`` marks a clean ``CheckpointError``.
    """
    boundary: int
    recovered_step: Optional[int]
    lo: Optional[int]
    hi: Optional[int]
    refused: bool
    ok: bool
    detail: str = ""


@dataclass(frozen=True)
class _JournalEntry:
    member: int      # index into array.devices
    zone_id: int     # member-local zone (0 = manifest zone)
    start_rel: int   # landing block within the member zone
    nblocks: int
    step: int        # checkpoint save in flight when the append completed


def _tree_leaves(tree: Any) -> list[np.ndarray]:
    import jax
    return [np.asarray(jax.device_get(x))
            for x in jax.tree_util.tree_leaves(tree)]


def _trees_equal(a: Any, b: Any) -> bool:
    la, lb = _tree_leaves(a), _tree_leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if not np.array_equal(
                x.view(np.uint8) if x.dtype.kind == "V" else x,
                y.view(np.uint8) if y.dtype.kind == "V" else y):
            return False
    return True


class PowerLossHarness:
    """Simulate power loss at every append-completion boundary of a striped
    checkpoint workload (see module docstring for the contract checked).

    Parameters mirror :meth:`ZonedCheckpointStore.striped`; ``stride``
    subsamples the boundary sweep for fast CI runs (boundary 0, every
    ``stride``-th cut, and the final boundary are always included).
    """

    def __init__(self, directory: Path | str, *, num_devices: int = 4,
                 num_zones: int = 8,
                 member_zone_bytes: int = 1 * 1024 * 1024,
                 stripe_blocks: int = 8, redundancy: str = "raid1",
                 stride: int = 1):
        if stride < 1:
            raise ValueError("stride must be >= 1")
        self.directory = Path(directory)
        self.num_devices = num_devices
        self.num_zones = num_zones
        self.member_zone_bytes = member_zone_bytes
        self.stripe_blocks = stripe_blocks
        self.redundancy = redundancy
        self.stride = stride
        self.journal: list[_JournalEntry] = []
        self._step_end: list[tuple[int, int]] = []  # (step, journal len after)
        self.outcomes: list[CrashOutcome] = []

    # ------------------------------------------------------------- recording
    def _record_saves(self, steps: Sequence[tuple[int, Any]]) -> None:
        from repro.train.checkpoint import ZonedCheckpointStore

        live_dir = self.directory / "live"
        store = ZonedCheckpointStore.striped(
            live_dir, num_devices=self.num_devices,
            num_zones=self.num_zones,
            member_zone_bytes=self.member_zone_bytes,
            stripe_blocks=self.stripe_blocks,
            redundancy=self.redundancy,
            keep=len(steps) + 1,   # the sweep replays history; never GC it
        )
        self._live = store
        member_of = {id(d): i for i, d in enumerate(store.device.devices)}
        cur_step = [-1]

        def listener(device, zone_id, start_rel, nblocks, fut):
            entry = _JournalEntry(member_of[id(device)], zone_id,
                                  start_rel, nblocks, cur_step[0])

            def on_done(f):
                if f.error is None:
                    self.journal.append(entry)

            fut.add_done_callback(on_done)

        for d in store.device.devices:
            d.add_append_listener(listener)

        for step, tree in steps:
            cur_step[0] = step
            # save_async().result(), NOT save(): gc() would reset zones the
            # boundary replay still reads committed history from
            store.save_async(step, tree).result()
            self._step_end.append((step, len(self.journal)))
        store.flush()

    # ---------------------------------------------------------------- bounds
    def _bounds(self, k: int) -> tuple[Optional[int], Optional[int]]:
        """(lo, hi) recovery bounds for a cut after ``k`` completions: lo is
        the newest step fully durable (every member append, manifest
        included, in ``journal[:k]``), hi the newest step with at least one
        manifest-zone member append in ``journal[:k]`` (a partially mirrored
        commit record may still scan as valid on the surviving replica)."""
        lo = None
        for step, end in self._step_end:
            if end <= k:
                lo = step
        hi = None
        for e in self.journal[:k]:
            if e.zone_id == 0:
                hi = e.step if hi is None else max(hi, e.step)
        return lo, hi

    # ---------------------------------------------------------------- replay
    def _replay(self, k: int) -> Path:
        """Materialize member files holding exactly ``journal[:k]``."""
        from repro.zns.device import ZonedDevice

        crash_dir = self.directory / f"crash{k:05d}"
        if crash_dir.exists():
            shutil.rmtree(crash_dir)
        crash_dir.mkdir(parents=True)
        shutil.copy(self.directory / "live" / "array.json",
                    crash_dir / "array.json")
        devs = [ZonedDevice(num_zones=self.num_zones,
                            zone_bytes=self.member_zone_bytes,
                            block_bytes=4096,
                            backing_file=crash_dir / f"member{i}.zns")
                for i in range(self.num_devices)]
        live_devs = self._live.device.devices
        for e in self.journal[:k]:
            z = devs[e.member].zone(e.zone_id)
            if z.write_pointer != e.start_rel:
                raise CrashConsistencyError(
                    f"journal out of order: member {e.member} zone "
                    f"{e.zone_id} wp={z.write_pointer} but entry lands at "
                    f"{e.start_rel}")
            data = live_devs[e.member].read_blocks(
                e.zone_id, e.start_rel, e.nblocks)
            landed = devs[e.member].zone_append(e.zone_id, data)
            assert landed == e.start_rel
        for d in devs:
            d.flush()
        return crash_dir

    # ------------------------------------------------------------------- run
    def _check_boundary(self, k: int, trees: dict[int, Any],
                        like: Any) -> CrashOutcome:
        from repro.train.checkpoint import CheckpointError, \
            ZonedCheckpointStore

        lo, hi = self._bounds(k)
        crash_dir = self._replay(k)
        try:
            store = ZonedCheckpointStore.striped(crash_dir,
                                                 keep=len(trees) + 1)
            recovered = store.latest_step()
            if recovered is None:
                try:
                    store.restore(like=like)
                    return CrashOutcome(
                        k, None, lo, hi, refused=False, ok=False,
                        detail="restore succeeded with no manifest found")
                except CheckpointError:
                    pass  # the clean refusal path
                ok = lo is None
                return CrashOutcome(
                    k, None, lo, hi, refused=True, ok=ok,
                    detail="" if ok else
                    f"refused although step {lo} was fully durable")
            try:
                tree = store.restore(step=recovered, like=like)
            except CheckpointError as e:
                # a scan-visible manifest must restore: its payload landed
                # before it (commit ordering), so a failure here is torn
                return CrashOutcome(
                    k, recovered, lo, hi, refused=True, ok=False,
                    detail=f"manifest for step {recovered} visible but "
                           f"restore refused: {e}")
            # recovery may land anywhere in [lo, hi]: above lo when a
            # half-mirrored commit record scans as valid on the surviving
            # replica (its payload is durable by commit ordering), never
            # above hi (no manifest bytes for a newer step exist on disk)
            if hi is None or recovered > hi or \
                    (lo is not None and recovered < lo):
                return CrashOutcome(
                    k, recovered, lo, hi, refused=False, ok=False,
                    detail=f"recovered step {recovered} outside durable "
                           f"bounds [{lo}, {hi}]")
            if not _trees_equal(tree, trees[recovered]):
                return CrashOutcome(
                    k, recovered, lo, hi, refused=False, ok=False,
                    detail=f"step {recovered} restored with torn bytes")
            return CrashOutcome(k, recovered, lo, hi, refused=False,
                                ok=True)
        finally:
            shutil.rmtree(crash_dir, ignore_errors=True)

    def _boundaries(self) -> list[int]:
        n = len(self.journal)
        ks = sorted(set(range(0, n + 1, self.stride)) | {0, n})
        return ks

    def run(self, steps: Sequence[tuple[int, Any]]) -> list[CrashOutcome]:
        """Save ``steps`` (``[(step, tree), ...]``, ascending) on a live
        striped store, then sweep every power-loss boundary. Returns the
        per-boundary outcomes; raises :class:`CrashConsistencyError` on the
        first contract violation (its message names the boundary)."""
        if not steps:
            raise ValueError("need at least one (step, tree) to save")
        self._record_saves(steps)
        trees = {s: t for s, t in steps}
        like = steps[0][1]
        self.outcomes = []
        for k in self._boundaries():
            out = self._check_boundary(k, trees, like)
            self.outcomes.append(out)
            if not out.ok:
                raise CrashConsistencyError(
                    f"boundary {out.boundary}/{len(self.journal)}: "
                    f"{out.detail}")
        return self.outcomes

    def summary(self) -> dict:
        """Machine-readable sweep summary (for benchmarks / CI)."""
        return {
            "journal_len": len(self.journal),
            "boundaries": len(self.outcomes),
            "refusals": sum(1 for o in self.outcomes if o.refused),
            "restores": sum(1 for o in self.outcomes
                            if o.recovered_step is not None),
            "all_ok": all(o.ok for o in self.outcomes),
        }
