"""Shared building blocks: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import shard_act, use_param

__all__ = [
    "norm_specs", "apply_norm", "mlp_specs", "apply_mlp",
    "embed_specs", "rope", "softcap", "cdtype",
]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ------------------------------------------------------------------- norms

def norm_specs(cfg: ModelConfig, dim: Optional[int] = None) -> dict:
    d = dim or cfg.d_model
    specs = {"scale": ParamSpec((d,), ("embed",), init="ones")}
    if cfg.norm == "layer" and cfg.use_bias:
        specs["bias"] = ParamSpec((d,), ("embed",), init="zeros")
    return specs


def apply_norm(cfg: ModelConfig, p: dict, x: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:  # rmsnorm
        y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -------------------------------------------------------------------- MLPs

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation in ("silu", "gelu_glu"):  # gated (llama / gemma family)
        specs = {
            "gate": ParamSpec((d, f), ("embed", "mlp"), init="fan_in"),
            "up": ParamSpec((d, f), ("embed", "mlp"), init="fan_in"),
            "down": ParamSpec((f, d), ("mlp", "embed"), init="fan_in"),
        }
    else:  # classic 2-matrix MLP (starcoder2, seamless)
        specs = {
            "up": ParamSpec((d, f), ("embed", "mlp"), init="fan_in"),
            "down": ParamSpec((f, d), ("mlp", "embed"), init="fan_in"),
        }
        if cfg.use_bias:
            specs["up_b"] = ParamSpec((f,), ("mlp",), init="zeros")
            specs["down_b"] = ParamSpec((d,), ("embed",), init="zeros")
    return specs


def apply_mlp(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = cdtype(cfg)
    if cfg.activation in ("silu", "gelu_glu"):
        act = jax.nn.silu if cfg.activation == "silu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        gate = use_param(p["gate"], ("embed", "mlp"))
        up = use_param(p["up"], ("embed", "mlp"))
        h = act(x @ gate.astype(dt)) * (x @ up.astype(dt))
    else:
        h = x @ use_param(p["up"], ("embed", "mlp")).astype(dt)
        if "up_b" in p:
            h = h + p["up_b"].astype(dt)
        h = jax.nn.gelu(h, approximate=True)
    h = shard_act(h, ("act_batch", "act_seq", "act_mlp"))
    y = h @ use_param(p["down"], ("mlp", "embed")).astype(dt)
    if "down_b" in p:
        y = y + p["down_b"].astype(dt)
    # keep batch@data on the output (see apply_attention's out-proj note)
    return shard_act(y, ("act_batch", "act_seq", "act_embed"))


# -------------------------------------------------------------- embeddings

def embed_specs(cfg: ModelConfig) -> dict:
    specs = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              init="normal")}
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                                  init="fan_in")
    return specs


# -------------------------------------------------------------------- RoPE

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [..., L, hd]; positions: broadcastable to [..., L]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq          # [..., L, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: Optional[float]):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
