"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention in a 2:1 pattern (Griffin).
[arXiv:2402.19427; unverified]

Hybrid recurrence + windowed attention => bounded decode state =>
``long_500k`` runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="gelu_glu",
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    scale_embeddings=True,
    tie_embeddings=True,
    ssm_conv=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, local_window=32, attn_chunk=32,
    )
