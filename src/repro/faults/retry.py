"""Retry/timeout/backoff engine for ring-delivered transient errors.

The device submit paths stay asynchronous under faults: a retry is NOT a
blocking loop around ``result()`` but a chain of completion callbacks.
:func:`drive_retries` owns one caller-visible aggregate future and, behind
it, launches up to ``max_attempts`` device-level attempt futures; each
attempt's error completion either resolves the aggregate or schedules the
next attempt after an exponential-backoff delay. The backoff timer is
itself an :class:`~repro.zns.ring.IoFuture` parked on the reactor heap —
backoff elapses in the same emulated clock as every other completion, and
jitter comes from the seeded injector hash, never from wall-clock entropy,
so retry schedules replay exactly.

Per-attempt timeouts use the same timer primitive: a completion callback
and a timeout timer race for a once-only latch; whichever settles the
attempt first wins, and the loser's late firing is ignored. That latch is
what rescues *hung* commands (attempt futures that will never retire).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.faults.errors import IoTimeoutError, TransientIOError

if False:  # typing only — a module-level import would close a cycle:
    # repro.faults -> retry -> repro.zns (package) -> device -> repro.faults
    from repro.zns.ring import IoFuture, IoReactor

__all__ = ["RetryPolicy", "schedule_timer", "drive_retries"]


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded-attempt exponential backoff with seeded jitter.

    ``timeout_s`` is the per-attempt patience: an attempt whose completion
    has not retired within it is abandoned (counted as a timeout) and the
    budget permitting, retried. ``None`` disables timeouts — a hung command
    then surfaces only through the caller's own ``result(timeout=)``.
    """

    max_attempts: int = 4
    backoff_base_s: float = 200e-6
    backoff_factor: float = 2.0
    jitter_frac: float = 0.25
    timeout_s: Optional[float] = None

    def backoff_s(self, attempt: int, u01: float) -> float:
        """Delay before attempt ``attempt + 1`` given a uniform jitter draw:
        ``base * factor**(attempt-1)``, spread +/- ``jitter_frac``."""
        base = self.backoff_base_s * (self.backoff_factor ** (attempt - 1))
        return base * (1.0 + self.jitter_frac * (2.0 * u01 - 1.0))


def schedule_timer(reactor: IoReactor, delay_s: float,
                   fn: Callable[[], None]) -> IoFuture:
    """Run ``fn()`` after ``delay_s`` on the reactor clock. The timer is a
    plain value-bearing IoFuture (op ``retry-timer``), so it rides the same
    deadline heap as data completions — zero or negative delays fire inline
    on the calling thread, like any already-due completion."""
    from repro.zns.ring import IoFuture
    t = IoFuture(op="retry-timer")
    t._value = None
    t.add_done_callback(lambda _f: fn())
    return reactor.schedule(t, time.monotonic() + max(0.0, delay_s))


def drive_retries(agg: IoFuture, *, policy: RetryPolicy, reactor: IoReactor,
                  submit: Callable[[int], Optional[IoFuture]],
                  jitter01: Callable[[], float],
                  on_retry: Optional[Callable[[int, BaseException], None]] = None,
                  on_timeout: Optional[Callable[[int, BaseException], None]] = None,
                  on_exhausted: Optional[Callable[[int, BaseException], None]] = None,
                  timeout_error: Optional[Callable[[int], BaseException]] = None,
                  first: Optional[tuple] = None) -> IoFuture:
    """Resolve ``agg`` by driving up to ``policy.max_attempts`` submissions.

    ``submit(attempt)`` issues one device-level attempt and returns its
    future — or ``None`` for a hung command whose completion will never
    arrive (only the attempt timeout can rescue it). ``first=(fut,)`` hands
    in a pre-submitted attempt 1 (appends land their data effect under the
    device lock before the controller takes over); the one-element tuple
    keeps a ``None`` hung first attempt distinguishable from "not given".

    Success completes ``agg`` with the attempt's value. A retryable error
    (``TransientIOError.retryable``) with budget left schedules the next
    attempt after :meth:`RetryPolicy.backoff_s`; anything else — permanent
    error, torn append, exhausted budget — fails ``agg`` with the final
    error. The ``on_*`` hooks fire before the follow-up action, in attempt
    order, on whichever thread settled the attempt.
    """

    def launch(attempt: int, pre: Optional[tuple] = None) -> None:
        if pre is not None:
            fut = pre[0]
        else:
            try:
                fut = submit(attempt)
            except BaseException as e:   # submit-time (protocol) failure
                agg.fail(e)
                return

        settled = [False]
        latch = threading.Lock()

        def claim() -> bool:
            with latch:
                if settled[0]:
                    return False
                settled[0] = True
                return True

        def settle_error(err: BaseException, *, timed_out: bool) -> None:
            retryable = isinstance(err, TransientIOError) and err.retryable
            more = retryable and attempt < policy.max_attempts
            if timed_out and on_timeout is not None:
                on_timeout(attempt, err)
            elif not timed_out and more and on_retry is not None:
                on_retry(attempt, err)
            if more:
                delay = policy.backoff_s(attempt, jitter01())
                if delay > 0:
                    schedule_timer(reactor, delay,
                                   lambda: launch(attempt + 1))
                else:
                    launch(attempt + 1)
                return
            if on_exhausted is not None:
                on_exhausted(attempt, err)
            agg.fail(err)

        def on_complete(f: IoFuture) -> None:
            if not claim():
                return            # the timeout timer already abandoned us
            if f._error is None:
                agg.complete(f._value)
            else:
                settle_error(f._error, timed_out=False)

        def fire_timeout() -> None:
            if not claim():
                return            # completion won the race
            if timeout_error is not None:
                err = timeout_error(attempt)
            else:
                err = IoTimeoutError(
                    f"attempt {attempt} exceeded "
                    f"timeout_s={policy.timeout_s}", attempt=attempt)
            settle_error(err, timed_out=True)

        if fut is None:
            # hung command: no completion will ever arrive, so without a
            # timeout budget the aggregate (deliberately) hangs too
            if policy.timeout_s is not None:
                schedule_timer(reactor, policy.timeout_s, fire_timeout)
            return
        if policy.timeout_s is not None and not fut.done():
            schedule_timer(reactor, policy.timeout_s, fire_timeout)
        fut.add_done_callback(on_complete)

    launch(1, first)
    return agg
