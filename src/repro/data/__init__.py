from repro.data.pipeline import (
    PrefetchLoader,
    ZoneDataPipeline,
    ZoneDataStore,
)

__all__ = ["ZoneDataStore", "ZoneDataPipeline", "PrefetchLoader"]
