"""Redundancy modes: degraded-read floor and mirror read-bandwidth multiplier.

A fixed logical dataset is laid out on arrays with emulated member read
bandwidth (``read_us_per_block``, QEMU-style) under each redundancy mode,
then a member zone is killed and the SAME reads/offloads run degraded:

  * raw striped reads — raid1 redirects every chunk to the surviving mirror
    partner (so degraded throughput ~= the single-device floor), xor
    reconstructs the dead member's chunks from the surviving row members in
    parallel (so degraded throughput can exceed the floor);
  * verified offloads through the :class:`~repro.array.OffloadScheduler` —
    degraded fan-out redirects/reconstructs per chunk and the result must be
    BIT-IDENTICAL to the healthy array's (asserted, the acceptance
    criterion), with the served-degraded chunk count in
    ``ArrayOffloadStats.degraded_reads``.

Asserted tripwires (loud in CI):
  * healthy raid1 reads beat the raid0 single-device floor at equal data
    size (mirror round-robin is a read-bandwidth multiplier);
  * degraded reads stay >= the single-device floor (with a small emulation
    tolerance for raid1, whose survivor IS a single device);
  * every offload, healthy or degraded, returns the exact expected count.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.core.cache import CompiledProgramCache
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1


def _build(mode: str, n_devices: int, data: np.ndarray, data_bytes: int,
           read_us_per_block: float) -> StripedZoneArray:
    devices = [
        ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=4096,
                    read_us_per_block=read_us_per_block)
        for _ in range(n_devices)
    ]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy=mode)
    array.zone_append(0, data)
    return array


def run_degraded(
    *,
    data_mib: int = 8,
    read_us_per_block: float = 20.0,
    runs: int = 3,
    seed: int = 0,
) -> list[dict]:
    # 20 us/block means the emulated transfer time (~41 ms for an 8 MiB
    # single-device scan) dominates host-side scheduling noise, so the
    # ratio asserts below stay stable even on a loaded 2-core CI box
    data_bytes = data_mib * 1024 * 1024
    n_blocks = data_bytes // 4096
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)
    cache = CompiledProgramCache()   # share compiles across every config

    # (row name, redundancy, member count, member device to kill or None)
    configs = [
        ("raid0_1dev", "raid0", 1, None),
        ("raid0_2dev", "raid0", 2, None),
        ("raid1_2dev_healthy", "raid1", 2, None),
        ("raid1_2dev_degraded", "raid1", 2, 1),
        ("xor_3dev_healthy", "xor", 3, None),
        ("xor_3dev_degraded", "xor", 3, 1),
    ]
    out: list[dict] = []
    for name, mode, n, kill in configs:
        array = _build(mode, n, data, data_bytes, read_us_per_block)
        with OffloadScheduler(array, cache=cache) as sched:
            sched.nvm_cmd_bpf_run(program, 0)        # healthy warm-up: pays JIT
            if kill is not None:
                array.set_offline(0, device=kill)
            # raw striped read (reconstruction path for degraded configs)
            read_times = []
            for _ in range(runs):
                t = time.perf_counter()
                got = array.read_blocks(0, 0, n_blocks)
                read_times.append(time.perf_counter() - t)
            assert int((got.view(np.int32) > RAND_MAX // 2).sum()) == expected, \
                f"{name}: raw read bytes differ from the healthy data"
            # verified offload (bit-identical acceptance criterion)
            off_times = []
            for _ in range(runs):
                t = time.perf_counter()
                stats = sched.nvm_cmd_bpf_run(program, 0)
                off_times.append(time.perf_counter() - t)
            assert int(sched.nvm_cmd_bpf_result()) == expected, \
                f"{name}: degraded offload result differs"
            if kill is not None:
                assert stats.degraded_reads > 0, \
                    f"{name}: degraded fan-out not counted"
        out.append({
            "name": name,
            "read_seconds": float(np.min(read_times)),
            "read_mib_per_s": data_mib / float(np.min(read_times)),
            "offload_seconds": float(np.min(off_times)),
            "offload_mib_per_s": data_mib / float(np.min(off_times)),
            "degraded_chunks": stats.degraded_reads,
        })

    by = {r["name"]: r for r in out}
    floor = by["raid0_1dev"]
    # mirror round-robin is a READ multiplier at equal data size (the
    # offload-path timing is noisier — JAX dispatch overhead — so the
    # asserted tripwires are the raw-read throughputs; offloads are
    # asserted for bit-identity and degraded accounting above)
    assert by["raid1_2dev_healthy"]["read_mib_per_s"] > \
        1.15 * floor["read_mib_per_s"], \
        "healthy raid1 reads do not beat the raid0 floor"
    # degraded reads hold the single-device floor (raid1's survivor IS a
    # single device, so allow a reconstruction-overhead tolerance)
    assert by["raid1_2dev_degraded"]["read_mib_per_s"] >= \
        0.8 * floor["read_mib_per_s"], "raid1 degraded reads below the floor"
    assert by["xor_3dev_degraded"]["read_mib_per_s"] >= \
        0.8 * floor["read_mib_per_s"], "xor degraded reads below the floor"
    return out


def main(data_mib: int = 8, runs: int = 3) -> list[str]:
    rows = []
    for r in run_degraded(data_mib=data_mib, runs=runs):
        rows.append(
            f"degraded_{r['name']},{r['offload_seconds'] * 1e6:.0f},"
            f"offload_mib_per_s={r['offload_mib_per_s']:.1f};"
            f"read_mib_per_s={r['read_mib_per_s']:.1f};"
            f"degraded_chunks={r['degraded_chunks']}"
        )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
