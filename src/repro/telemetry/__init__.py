"""Unified observability for the emulator: tracing, metrics, health, alerts.

Producer side (PR 6), one import point:

  * :mod:`repro.telemetry.trace` — lock-light span recorder on wall AND
    reactor virtual time, exportable as Chrome ``trace_event`` JSON
    (Perfetto-loadable). Off by default; ``trace.set_enabled(True)`` or the
    ``tracing()`` context manager turn it on.
  * :mod:`repro.telemetry.metrics` — counters/gauges/histograms with
    snapshot/delta semantics. The global :func:`metrics.registry` aggregates
    process-wide components (reactor, gather pool, tenant queues, compile
    caches); per-instance components expose ``obj.metrics``.

Consumer side (PR 7):

  * :mod:`repro.telemetry.events` — bounded structured event log every
    layer publishes discrete happenings into (zone transitions, member
    death, SQ stalls, ring drops, ticket failures); global
    :func:`events.event_log`, JSONL export, subscription hook.
  * :mod:`repro.telemetry.health` — SMART-style per-device health: error
    counters, EWMA latency-outlier detection, composite
    HEALTHY/SUSPECT/DEGRADED/OFFLINE status, ``smart_log()`` dicts.
  * :mod:`repro.telemetry.alerts` — rule engine over metric snapshots and
    event patterns (per-tenant p99 SLO, error rates, health promotions);
    firing alerts are events and invoke registered callbacks.
"""
from . import alerts, events, health, metrics, trace
from .alerts import (Alert, AlertEngine, AlertRule, ErrorRateRule,
                     EventPatternRule, HealthPromotionRule,
                     TenantLatencySLORule, retry_storm_rule)
from .events import Event, EventLog, Severity, event_log, publish
from .health import ArrayHealthMonitor, DeviceHealthMonitor, HealthStatus
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, StatsView,
                      registry)
from .trace import span, instant, event_complete, tracing, set_enabled

__all__ = [
    "metrics",
    "trace",
    "events",
    "health",
    "alerts",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "registry",
    "span",
    "instant",
    "event_complete",
    "tracing",
    "set_enabled",
    "Event",
    "EventLog",
    "Severity",
    "event_log",
    "publish",
    "HealthStatus",
    "DeviceHealthMonitor",
    "ArrayHealthMonitor",
    "Alert",
    "AlertEngine",
    "AlertRule",
    "TenantLatencySLORule",
    "ErrorRateRule",
    "retry_storm_rule",
    "HealthPromotionRule",
    "EventPatternRule",
]
