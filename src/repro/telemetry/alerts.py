"""Alert rule engine over metric snapshots and event patterns.

The last consumer layer: :mod:`.health` says how each member *is*,
:mod:`.events` says what *happened* — this module decides when something
needs a reaction. An :class:`AlertEngine` holds a set of :class:`AlertRule`
objects and evaluates them on demand (:meth:`AlertEngine.evaluate`, the
deterministic path benchmarks and tests drive) or on a background sampling
interval (:meth:`AlertEngine.start`). Each evaluation builds one
:class:`AlertContext` — global-registry snapshot + previous snapshot, the
events published since the last evaluation, and the elapsed window — and
hands it to every rule.

Rules are **edge-triggered with incident tracking**: a rule reports the set
of currently-firing *incidents* (keyed strings, e.g. one per tenant or per
member); the engine fires an alert only when an incident key appears that
was not active on the previous evaluation, and publishes an
``alert.resolved`` event when it clears. A condition that stays true does
not re-fire every interval — the pager does not ring twice for one outage.

Firing alerts ARE events (``alert.<rule-name>`` in the shared event log,
severity from the rule) and additionally invoke callbacks registered with
:meth:`AlertEngine.on_alert` — the hook the ROADMAP's spare-promotion loop
will attach to; for now bench_health attaches one to prove the pipeline
fires end to end.

Shipped rules (the three the ISSUE names):

  * :class:`TenantLatencySLORule` — per-tenant p99 latency SLO breach, read
    from ``tenant.<t>.<series>.p99`` keys in the registry snapshot;
  * :class:`ErrorRateRule` — any matching error counter increasing faster
    than a threshold rate over the evaluation window;
  * :class:`HealthPromotionRule` — an array member promoted past SUSPECT
    into DEGRADED/OFFLINE (drives the health monitors' ``sample()``);

plus :class:`EventPatternRule` for thresholding on event bursts (e.g. "3+
``sq.stall`` events in one window").
"""
from __future__ import annotations

import fnmatch
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from .events import Event, EventLog, Severity, event_log
from .health import ArrayHealthMonitor, DeviceHealthMonitor, HealthStatus
from .metrics import MetricsRegistry, registry as global_registry

__all__ = [
    "Alert",
    "AlertContext",
    "AlertRule",
    "TenantLatencySLORule",
    "ErrorRateRule",
    "retry_storm_rule",
    "HealthPromotionRule",
    "EventPatternRule",
    "AlertEngine",
]


@dataclass(frozen=True)
class Alert:
    """One fired alert: which rule, which incident, and why."""

    rule: str
    key: str
    severity: Severity
    message: str
    t_wall: float
    tags: dict = field(default_factory=dict)


@dataclass
class AlertContext:
    """Everything a rule may look at for one evaluation."""

    snapshot: dict
    prev_snapshot: dict
    new_events: list[Event]
    dt: float                       # seconds since the previous evaluation

    def delta(self, key: str, default: float = 0.0) -> float:
        return self.snapshot.get(key, default) - \
            self.prev_snapshot.get(key, default)


class AlertRule:
    """Base rule: subclasses return ``{incident_key: (message, tags)}`` for
    every condition currently true. The engine handles edge-triggering."""

    def __init__(self, name: str, severity: Severity = Severity.ERROR):
        self.name = name
        self.severity = Severity(severity)

    def check(self, ctx: AlertContext) -> dict[str, tuple[str, dict]]:
        raise NotImplementedError


class TenantLatencySLORule(AlertRule):
    """Fires per tenant whose ``tenant.<t>.<series>.p99`` exceeds the SLO.

    ``series`` defaults to the scheduler's per-tenant end-to-end offload
    latency histogram; pass ``sq_admission_wait_seconds`` etc. to put an SLO
    on a different stage. Histograms with no samples publish no quantile
    keys, so idle tenants never page.
    """

    def __init__(self, slo_p99_seconds: float, *,
                 series: str = "offload_latency_seconds",
                 name: str = "tenant_p99_slo",
                 severity: Severity = Severity.ERROR):
        super().__init__(name, severity)
        self.slo_p99_seconds = float(slo_p99_seconds)
        self.series = series
        self._suffix = f".{series}.p99"

    def check(self, ctx: AlertContext) -> dict[str, tuple[str, dict]]:
        out: dict[str, tuple[str, dict]] = {}
        for key, val in ctx.snapshot.items():
            if not key.startswith("tenant.") or not key.endswith(self._suffix):
                continue
            tenant = key[len("tenant."):-len(self._suffix)]
            if val > self.slo_p99_seconds:
                out[tenant] = (
                    f"tenant {tenant!r} p99 {val * 1e3:.2f}ms breaches "
                    f"{self.slo_p99_seconds * 1e3:.2f}ms SLO ({self.series})",
                    {"tenant": tenant, "p99_s": val,
                     "slo_s": self.slo_p99_seconds})
        return out


class ErrorRateRule(AlertRule):
    """Fires per counter matching ``pattern`` (fnmatch glob) whose rate of
    increase over the window exceeds ``max_per_second``. With the default
    ``max_per_second=0.0`` any error growth at all fires — the right posture
    for an emulator where errors are injected, not ambient."""

    def __init__(self, *, pattern: str = "*_errors",
                 max_per_second: float = 0.0,
                 name: str = "error_rate",
                 severity: Severity = Severity.ERROR):
        super().__init__(name, severity)
        self.pattern = pattern
        self.max_per_second = float(max_per_second)

    def check(self, ctx: AlertContext) -> dict[str, tuple[str, dict]]:
        out: dict[str, tuple[str, dict]] = {}
        dt = max(ctx.dt, 1e-9)
        for key in ctx.snapshot:
            if not fnmatch.fnmatch(key, self.pattern):
                continue
            d = ctx.delta(key)
            if d > 0 and d / dt > self.max_per_second:
                out[key] = (
                    f"{key} grew by {d:g} in {ctx.dt:.3f}s "
                    f"({d / dt:.1f}/s > {self.max_per_second:g}/s)",
                    {"counter": key, "delta": d, "rate_per_s": d / dt})
        return out


def retry_storm_rule(*, max_per_second: float = 0.0,
                     name: str = "retry_storm",
                     severity: Severity = Severity.WARNING) -> ErrorRateRule:
    """The default retry-storm pager: fires per member whose absorbed-retry
    counter (``health.<member>.retries``, surfaced by
    ``DeviceHealthMonitor.register_on``) grows faster than
    ``max_per_second`` over the engine window. Retries are the SOFT fault
    signal — the datapath rode through them — so this pages an operator
    about a sick-but-serving member BEFORE exhausted budgets land in
    ``read_errors`` and the member is declared dead."""
    return ErrorRateRule(pattern="health.*.retries",
                         max_per_second=max_per_second,
                         name=name, severity=severity)


class HealthPromotionRule(AlertRule):
    """Fires when an array member's health status reaches ``at_least``
    (default DEGRADED) — the SUSPECT→DEGRADED promotion the spare-promotion
    loop keys off. Drives ``monitor.sample()`` on every evaluation so the
    engine's interval doubles as the SMART polling interval."""

    def __init__(self, monitor, *, at_least: HealthStatus = HealthStatus.DEGRADED,
                 sample: bool = True, name: str = "member_degraded",
                 severity: Severity = Severity.CRITICAL):
        super().__init__(name, severity)
        if not isinstance(monitor, (ArrayHealthMonitor, DeviceHealthMonitor)):
            raise TypeError("monitor must be an Array/DeviceHealthMonitor")
        self.monitor = monitor
        self.at_least = HealthStatus(at_least)
        self.sample = sample

    def _monitors(self) -> list[DeviceHealthMonitor]:
        if isinstance(self.monitor, ArrayHealthMonitor):
            return self.monitor.members
        return [self.monitor]

    def check(self, ctx: AlertContext) -> dict[str, tuple[str, dict]]:
        out: dict[str, tuple[str, dict]] = {}
        for m in self._monitors():
            status = m.sample() if self.sample else m.status
            if status >= self.at_least:
                out[m.name] = (
                    f"member {m.name} is {status.name} "
                    f"(threshold {self.at_least.name})",
                    {"device": m.name, "status": status.name})
        return out


class EventPatternRule(AlertRule):
    """Fires when ``min_count``+ events matching ``event_name`` (exact or
    dotted prefix) at ``min_severity``+ arrive within one evaluation
    window — burst detection over the event stream."""

    def __init__(self, event_name: str, *, min_count: int = 1,
                 min_severity: Severity = Severity.DEBUG,
                 name: Optional[str] = None,
                 severity: Severity = Severity.WARNING):
        super().__init__(name or f"burst_{event_name.replace('.', '_')}",
                         severity)
        self.event_name = event_name
        self.min_count = int(min_count)
        self.min_severity = Severity(min_severity)

    def check(self, ctx: AlertContext) -> dict[str, tuple[str, dict]]:
        hits = [e for e in ctx.new_events
                if e.severity >= self.min_severity and
                (e.name == self.event_name or
                 e.name.startswith(self.event_name + "."))]
        if len(hits) < self.min_count:
            return {}
        return {self.event_name: (
            f"{len(hits)} {self.event_name!r} events in {ctx.dt:.3f}s "
            f"(threshold {self.min_count})",
            {"event": self.event_name, "count": len(hits)})}


class AlertEngine:
    """Evaluates rules against the registry + event log; fires alerts as
    events and callbacks.

    Deterministic use (tests, benchmarks)::

        engine = AlertEngine(rules=[...])
        engine.on_alert(lambda a: reactions.append(a))
        fired = engine.evaluate()        # list[Alert] newly fired this pass

    Background use: ``engine.start(interval=0.5)`` runs ``evaluate`` on a
    daemon thread until ``stop()``.
    """

    def __init__(self, rules: Optional[list[AlertRule]] = None, *,
                 metrics: Optional[MetricsRegistry] = None,
                 events: Optional[EventLog] = None,
                 history: int = 256):
        self.rules: list[AlertRule] = list(rules or [])
        self.metrics = metrics if metrics is not None else global_registry()
        self.events = events if events is not None else event_log()
        self.fired: deque[Alert] = deque(maxlen=history)
        self._callbacks: list[Callable[[Alert], None]] = []
        self._active: dict[str, set[str]] = {}
        self._prev_snapshot: dict = {}
        self._last_eval = time.monotonic()
        self._last_seq = self.events.last_seq()
        self._lock = threading.Lock()
        # serializes whole evaluations: _active (incident edge state) is
        # read-modify-written across the rule loop, so two overlapping
        # evaluate() calls (background sampler + an explicit call, or a
        # callback that re-enters) could otherwise interleave and lose a
        # clear — suppressing the incident's alert.resolved
        self._eval_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def add_rule(self, rule: AlertRule) -> AlertRule:
        with self._lock:
            self.rules.append(rule)
        return rule

    def on_alert(self, fn: Callable[[Alert], None]) -> Callable[[], None]:
        """Register ``fn(alert)`` for every newly-fired alert; returns an
        unsubscribe callable."""
        with self._lock:
            self._callbacks.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                if fn in self._callbacks:
                    self._callbacks.remove(fn)

        return unsubscribe

    # ----------------------------------------------------------- evaluation
    def evaluate(self) -> list[Alert]:
        """Run every rule once; returns the alerts that fired *this* pass
        (incidents newly active since the previous pass). Evaluations are
        serialized; callback exceptions are isolated exactly like rule
        exceptions (published as ``alert.callback_error`` events), so a
        broken consumer can neither wedge rule evaluation nor suppress a
        later ``alert.resolved``."""
        with self._eval_lock:
            return self._evaluate_locked()

    def _evaluate_locked(self) -> list[Alert]:
        with self._lock:
            now = time.monotonic()
            snap = self.metrics.snapshot()
            ctx = AlertContext(
                snapshot=snap,
                prev_snapshot=self._prev_snapshot,
                new_events=self.events.snapshot(since_seq=self._last_seq),
                dt=max(now - self._last_eval, 1e-9),
            )
            self._prev_snapshot = snap
            self._last_eval = now
            if ctx.new_events:
                self._last_seq = ctx.new_events[-1].seq
            rules = list(self.rules)
            callbacks = list(self._callbacks)

        new_alerts: list[Alert] = []
        for rule in rules:
            try:
                incidents = rule.check(ctx)
            except Exception:
                continue            # a broken rule must not stop the sweep
            prev_active = self._active.get(rule.name, set())
            for key, (message, tags) in incidents.items():
                if key in prev_active:
                    continue        # still firing, already alerted
                alert = Alert(rule=rule.name, key=key,
                              severity=rule.severity, message=message,
                              t_wall=time.time(), tags=dict(tags))
                new_alerts.append(alert)
                self.events.publish(
                    f"alert.{rule.name}", severity=rule.severity,
                    message=message, incident=key, **tags)
            for key in prev_active - set(incidents):
                self.events.publish(
                    "alert.resolved", severity=Severity.INFO,
                    message=f"{rule.name}/{key} cleared",
                    rule=rule.name, incident=key)
            self._active[rule.name] = set(incidents)

        for alert in new_alerts:
            self.fired.append(alert)
            for fn in callbacks:
                try:
                    fn(alert)
                except Exception as e:
                    # consumer bugs stay the consumer's — but not silently:
                    # a dead promotion hook is itself an operator incident
                    self.events.publish(
                        "alert.callback_error", severity=Severity.ERROR,
                        message=f"on_alert callback "
                                f"{getattr(fn, '__name__', repr(fn))} raised "
                                f"{type(e).__name__} for {alert.rule}/"
                                f"{alert.key}: {e}",
                        rule=alert.rule, incident=alert.key,
                        error=type(e).__name__)
        return new_alerts

    def active(self, rule: Optional[str] = None) -> dict[str, set[str]]:
        """Currently-firing incident keys per rule (as of the last
        evaluation)."""
        if rule is not None:
            return {rule: set(self._active.get(rule, set()))}
        return {r: set(keys) for r, keys in self._active.items()}

    # ------------------------------------------------------------ sampling
    def start(self, interval: float = 1.0) -> None:
        """Evaluate every ``interval`` seconds on a daemon thread."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(interval):
                self.evaluate()

        self._thread = threading.Thread(
            target=loop, name="alert-engine", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None
