"""Model assembly for every assigned architecture family.

A model is a list of *segments*; each segment is a (possibly heterogeneous)
block of layer kinds repeated N times and executed with ``jax.lax.scan`` over
stacked parameters — the superblock-scan keeps HLO size (and CPU compile time
for the dry-run) independent of depth while supporting non-uniform stacks:

  dense        [attn_mlp] x L
  moe          [attn_moe] x L            (deepseek: dense layer 0 + moe x L-1)
  ssm          [ssm] x L
  hybrid       [rglru, rglru, local_attn] x 12  + [rglru, rglru]   (RG-9b, 38L)
  vlm          [self, self, self, cross, self] x 8                 (40L)
  encdec       encoder [enc] x 24 -> memory; decoder [dec_cross] x 24

``forward`` (train / prefill), ``decode_step`` (one token against a cache),
``param_specs`` / ``cache_specs`` (single source of truth for shapes, logical
sharding axes, and initializers) all share the same layout description.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    apply_mlp, apply_norm, cdtype, embed_specs, mlp_specs, norm_specs,
)
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec, stack_layer_specs
from repro.sharding import shard_act, use_param

__all__ = [
    "Segment", "decoder_layout", "param_specs", "cache_specs",
    "forward", "decode_step", "loss_fn",
]

MOE_AUX_WEIGHT = 0.01


@dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]
    repeats: int


# ---------------------------------------------------------------- layouts

def decoder_layout(cfg: ModelConfig) -> list[Segment]:
    L = cfg.num_layers
    if cfg.family == "ssm":
        return [Segment(("ssm",), L)]
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rglru", "rglru", "local_attn")
        full, rem = divmod(L, len(pat))
        segs = [Segment(tuple(pat), full)] if full else []
        if rem:
            segs.append(Segment(tuple(pat[:rem]), 1))
        return segs
    if cfg.family == "moe":
        if cfg.first_layer_dense:
            return [Segment(("dense0",), 1), Segment(("attn_moe",), L - 1)]
        return [Segment(("attn_moe",), L)]
    if cfg.family == "vlm" and cfg.cross_attn_stride:
        s, o = cfg.cross_attn_stride, cfg.cross_attn_offset
        pat = tuple("cross_mlp" if i == o else "attn_mlp" for i in range(s))
        full, rem = divmod(L, s)
        segs = [Segment(pat, full)] if full else []
        if rem:
            segs.append(Segment(pat[:rem], 1))
        return segs
    if cfg.is_encoder_decoder:
        return [Segment(("dec_cross",), L)]
    return [Segment(("attn_mlp",), L)]


def encoder_layout(cfg: ModelConfig) -> list[Segment]:
    return [Segment(("enc",), cfg.encoder_layers)] if cfg.is_encoder_decoder else []


# ----------------------------------------------------------- kind: specs

def _kind_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "ssm":
        return {"ln": norm_specs(cfg), "ssm": ssm_mod.ssm_specs(cfg)}
    if kind == "rglru":
        return {"ln1": norm_specs(cfg), "rec": rglru_mod.rglru_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    if kind in ("attn_mlp", "local_attn", "enc"):
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    if kind == "dense0":
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg),
                "mlp": mlp_specs(cfg, cfg.dense_layer_d_ff or cfg.d_ff)}
    if kind == "attn_moe":
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "ln2": norm_specs(cfg), "moe": moe_mod.moe_specs(cfg)}
    if kind == "cross_mlp":
        return {"ln1": norm_specs(cfg), "cross": attn.cross_attn_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    if kind == "dec_cross":
        return {"ln1": norm_specs(cfg), "attn": attn.attn_specs(cfg),
                "lnx": norm_specs(cfg), "cross": attn.cross_attn_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    raise ValueError(kind)


def param_specs(cfg: ModelConfig) -> dict:
    specs: dict[str, Any] = {"embed": embed_specs(cfg)}
    if cfg.is_encoder_decoder:
        specs["enc_segments"] = [
            stack_layer_specs(
                {f"k{i}_{k}": _kind_specs(cfg, k) for i, k in enumerate(s.kinds)},
                s.repeats)
            for s in encoder_layout(cfg)
        ]
        specs["enc_norm"] = norm_specs(cfg)
    specs["segments"] = [
        stack_layer_specs(
            {f"k{i}_{k}": _kind_specs(cfg, k) for i, k in enumerate(s.kinds)},
            s.repeats)
        for s in decoder_layout(cfg)
    ]
    specs["final_norm"] = norm_specs(cfg)
    return specs


# ----------------------------------------------------------- kind: apply

def _apply_kind(cfg: ModelConfig, kind: str, p: dict, x, ctx: dict,
                collect_cache: bool):
    """Returns (x, aux, cache_entry_or_None). Training / prefill path."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    pos = ctx["positions"]

    def kv_of(attn_p, inp, window):
        if not collect_cache:
            return None
        k, v = attn._project_kv(cfg, attn_p, inp, pos)
        return _ring_pack(k, v, window)

    if kind == "ssm":
        h = apply_norm(cfg, p["ln"], x)
        if collect_cache:
            y, cache = ssm_mod.apply_ssm(cfg, p["ssm"], h, return_cache=True)
        else:
            y = ssm_mod.apply_ssm(cfg, p["ssm"], h)
        return x + checkpoint_name(y, "blk_out"), aux, cache
    if kind == "rglru":
        h = apply_norm(cfg, p["ln1"], x)
        if collect_cache:
            y, cache = rglru_mod.apply_rglru(cfg, p["rec"], h, return_cache=True)
        else:
            y = rglru_mod.apply_rglru(cfg, p["rec"], h)
        x = x + checkpoint_name(y, "blk_out")
        x = x + checkpoint_name(
            apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x)), "blk_out")
        return x, aux, cache
    if kind in ("attn_mlp", "dense0", "local_attn", "attn_moe"):
        window = cfg.local_window if kind == "local_attn" else cfg.sliding_window
        h = apply_norm(cfg, p["ln1"], x)
        if cfg.parallel_block:
            a = attn.apply_attention(cfg, p["attn"], h, pos, window=window)
            m_p = p["moe"] if kind == "attn_moe" else p["mlp"]
            if kind == "attn_moe":
                m, aux = moe_mod.apply_moe(cfg, m_p, h)
            else:
                m = apply_mlp(cfg, m_p, h)
            x = x + checkpoint_name(a, "blk_out") + checkpoint_name(m, "blk_out")
        else:
            cache = kv_of(p["attn"], h, window)
            a = attn.apply_attention(cfg, p["attn"], h, pos, window=window)
            x = x + checkpoint_name(a, "blk_out")
            h2 = apply_norm(cfg, p["ln2"], x)
            if kind == "attn_moe":
                m, aux = moe_mod.apply_moe(cfg, p["moe"], h2)
            else:
                m = apply_mlp(cfg, p["mlp"], h2)
            x = x + checkpoint_name(m, "blk_out")
        if cfg.parallel_block and collect_cache:
            cache = kv_of(p["attn"], h, window)
        return x, aux, cache
    if kind == "cross_mlp":
        h = apply_norm(cfg, p["ln1"], x)
        x = x + attn.apply_cross_attention(cfg, p["cross"], h, ctx["memory"])
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        if collect_cache:
            mem_pos = jnp.zeros(ctx["memory"].shape[:2], jnp.int32)
            mk, mv = attn._project_kv(cfg, p["cross"], ctx["memory"], mem_pos,
                                      use_rope=False)
            cache = {"mem_k": mk, "mem_v": mv}
        return x, aux, cache
    if kind == "enc":
        h = apply_norm(cfg, p["ln1"], x)
        x = x + attn.apply_attention(cfg, p["attn"], h, pos, causal=False)
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, aux, cache
    if kind == "dec_cross":
        h = apply_norm(cfg, p["ln1"], x)
        cache_sa = kv_of(p["attn"], h, None)
        x = x + attn.apply_attention(cfg, p["attn"], h, pos)
        hx = apply_norm(cfg, p["lnx"], x)
        x = x + attn.apply_cross_attention(cfg, p["cross"], hx, ctx["memory"])
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        if collect_cache:
            mem_pos = jnp.zeros(ctx["memory"].shape[:2], jnp.int32)
            mk, mv = attn._project_kv(cfg, p["cross"], ctx["memory"], mem_pos,
                                      use_rope=False)
            cache = {**cache_sa, "mem_k": mk, "mem_v": mv}
        return x, aux, cache
    raise ValueError(kind)


def _ring_pack(k, v, window):
    """Pack prefill K/V into the decode cache layout (ring for windowed)."""
    if window is None or k.shape[1] <= window:
        return {"k": k, "v": v}
    L = k.shape[1]
    idx = (jnp.arange(L - window, L)) % window
    ring_k = jnp.zeros((k.shape[0], window, *k.shape[2:]), k.dtype).at[:, idx].set(
        k[:, L - window:])
    ring_v = jnp.zeros((v.shape[0], window, *v.shape[2:]), v.dtype).at[:, idx].set(
        v[:, L - window:])
    return {"k": ring_k, "v": ring_v}


# ---------------------------------------------------------- kind: decode

def _decode_kind(cfg: ModelConfig, kind: str, p: dict, x, cache, ctx: dict):
    pos = ctx["pos"]
    if kind == "ssm":
        h = apply_norm(cfg, p["ln"], x)
        y, cache = ssm_mod.ssm_decode_step(cfg, p["ssm"], h, cache)
        return x + y, cache
    if kind == "rglru":
        h = apply_norm(cfg, p["ln1"], x)
        y, cache = rglru_mod.rglru_decode_step(cfg, p["rec"], h, cache)
        x = x + y
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, cache
    if kind in ("attn_mlp", "dense0", "local_attn", "attn_moe"):
        window = cfg.local_window if kind == "local_attn" else cfg.sliding_window
        h = apply_norm(cfg, p["ln1"], x)
        a, kc, vc = attn.decode_attention(
            cfg, p["attn"], h, cache["k"], cache["v"], pos, window=window)
        cache = {"k": kc, "v": vc}
        if cfg.parallel_block:
            if kind == "attn_moe":
                m, _ = moe_mod.apply_moe(cfg, p["moe"], h)
            else:
                m = apply_mlp(cfg, p["mlp"], h)
            return x + a + m, cache
        x = x + a
        h2 = apply_norm(cfg, p["ln2"], x)
        if kind == "attn_moe":
            m, _ = moe_mod.apply_moe(cfg, p["moe"], h2)
        else:
            m = apply_mlp(cfg, p["mlp"], h2)
        return x + m, cache
    if kind == "cross_mlp":
        h = apply_norm(cfg, p["ln1"], x)
        x = x + attn.decode_cross_attention(cfg, p["cross"], h,
                                            cache["mem_k"], cache["mem_v"])
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, cache
    if kind == "dec_cross":
        h = apply_norm(cfg, p["ln1"], x)
        a, kc, vc = attn.decode_attention(cfg, p["attn"], h,
                                          cache["k"], cache["v"], pos)
        x = x + a
        hx = apply_norm(cfg, p["lnx"], x)
        x = x + attn.decode_cross_attention(cfg, p["cross"], hx,
                                            cache["mem_k"], cache["mem_v"])
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, {**cache, "k": kc, "v": vc}
    raise ValueError(kind)


# -------------------------------------------------------------- cache spec

def _kind_cache_specs(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      mem_len: int) -> Optional[dict]:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    kv_axes = ("act_batch", "act_kv_seq", "act_kv_heads", None)

    def kv(S):
        return {"k": ParamSpec((batch, S, KV, hd), kv_axes, "zeros", cdt),
                "v": ParamSpec((batch, S, KV, hd), kv_axes, "zeros", cdt)}

    if kind == "ssm":
        di, ds, nh, hp, kc = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_conv)
        return {
            "conv": ParamSpec((batch, kc - 1, di + 2 * ds),
                              ("act_batch", None, None), "zeros", cdt),
            "state": ParamSpec((batch, nh, hp, ds),
                               ("act_batch", "act_ssm_heads", None, None),
                               "zeros", jnp.float32),
        }
    if kind == "rglru":
        dr, kc = cfg.d_model, cfg.ssm_conv
        return {
            "conv": ParamSpec((batch, kc - 1, dr),
                              ("act_batch", None, "act_ssm_inner"), "zeros", cdt),
            "h": ParamSpec((batch, dr), ("act_batch", "act_ssm_inner"),
                           "zeros", jnp.float32),
        }
    if kind in ("attn_mlp", "dense0", "attn_moe"):
        S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
        return kv(S)
    if kind == "local_attn":
        return kv(min(seq_len, cfg.local_window))
    if kind == "cross_mlp":
        return {"mem_k": ParamSpec((batch, mem_len, KV, hd), kv_axes, "zeros", cdt),
                "mem_v": ParamSpec((batch, mem_len, KV, hd), kv_axes, "zeros", cdt)}
    if kind == "dec_cross":
        return {**kv(seq_len),
                "mem_k": ParamSpec((batch, mem_len, KV, hd), kv_axes, "zeros", cdt),
                "mem_v": ParamSpec((batch, mem_len, KV, hd), kv_axes, "zeros", cdt)}
    if kind == "enc":
        return None
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> list:
    """ParamSpec tree for the decode cache, mirroring `segments`."""
    mem_len = memory_len(cfg, seq_len)
    segs = []
    for s in decoder_layout(cfg):
        block = {f"k{i}_{k}": _kind_cache_specs(cfg, k, batch, seq_len, mem_len)
                 for i, k in enumerate(s.kinds)}
        block = {k: v for k, v in block.items() if v is not None}
        segs.append(stack_layer_specs(block, s.repeats))
    return segs


def memory_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    if cfg.is_encoder_decoder:
        return int(seq_len * cfg.encoder_seq_factor)
    return 0


# ------------------------------------------------------------- full model

def _embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(cdtype(cfg))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _lm_head(cfg, params, x):
    if cfg.tie_embeddings:
        w = use_param(params["embed"]["tok"], ("vocab", "embed")).T
    else:
        w = use_param(params["embed"]["head"], ("embed", "vocab"))
    logits = x @ w.astype(cdtype(cfg))
    return shard_act(logits, ("act_batch", "act_seq", "act_vocab"))


def _run_segments(cfg, seg_params, layout, x, ctx, collect_cache, remat):
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for seg, sp in zip(layout, seg_params):
        def body(carry, layer_p, _seg=seg):
            x, aux = carry
            cache_out = {}
            for i, kind in enumerate(_seg.kinds):
                key = f"k{i}_{kind}"
                x, a, c = _apply_kind(cfg, kind, layer_p[key], x, ctx,
                                      collect_cache)
                aux = aux + a
                if c is not None:
                    cache_out[key] = c
            return (x, aux), cache_out
        if remat and cfg.remat != "none":
            if cfg.remat == "save_collectives":
                # save each block's (post-all-reduce) output so the backward
                # pass does not re-run the TP collectives during remat —
                # trades ~3x saved-activation bytes for ~1/3 of the
                # collective traffic (§Perf iteration 4)
                policy = jax.checkpoint_policies.save_only_these_names(
                    "blk_out")
                body = jax.checkpoint(body, prevent_cse=False, policy=policy)
            else:
                body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), seg_cache = jax.lax.scan(
            body, (x, aux), sp, unroll=seg.repeats if cfg.scan_unroll else 1)
        caches.append(seg_cache)
    return x, aux, caches


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            collect_cache: bool = False, remat: bool = True):
    """batch: tokens [B, L] (+ frames / patches for audio / vlm).
    Returns (logits [B, L, V] compute-dtype, aux_loss, caches_or_None)."""
    tokens = batch["tokens"]
    B, L = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
    memory = None
    if cfg.is_encoder_decoder:
        frames = batch["frames"].astype(cdtype(cfg))  # stub frontend output
        Lf = frames.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(Lf, dtype=jnp.int32)[None, :],
                                   (B, Lf))
        enc_ctx = {"positions": enc_pos, "memory": None}
        memory, _, _ = _run_segments(cfg, params["enc_segments"],
                                     encoder_layout(cfg), frames, enc_ctx,
                                     False, remat)
        memory = apply_norm(cfg, params["enc_norm"], memory)
    elif cfg.family == "vlm":
        memory = batch["patches"].astype(cdtype(cfg))  # stub vision frontend

    x = _embed_tokens(cfg, params, tokens)
    x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
    ctx = {"positions": positions, "memory": memory}
    x, aux, caches = _run_segments(cfg, params["segments"], decoder_layout(cfg),
                                   x, ctx, collect_cache, remat)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _lm_head(cfg, params, x)
    return logits, aux * MOE_AUX_WEIGHT, (caches if collect_cache else None)


def decode_step(cfg: ModelConfig, params: dict, cache: list, tokens: jnp.ndarray,
                pos: jnp.ndarray):
    """One decode step. tokens: [B, 1]; pos: scalar int32 (current absolute
    position). Returns (logits [B, V], new_cache)."""
    x = _embed_tokens(cfg, params, tokens)
    ctx = {"pos": pos}
    new_caches = []
    for seg, sp, sc in zip(decoder_layout(cfg), params["segments"], cache):
        def body(x, inp, _seg=seg):
            layer_p, layer_c = inp
            new_c = {}
            for i, kind in enumerate(_seg.kinds):
                key = f"k{i}_{kind}"
                c_in = layer_c.get(key) if isinstance(layer_c, dict) else None
                x, c_out = _decode_kind(cfg, kind, layer_p[key], x, c_in, ctx)
                if c_out is not None:
                    new_c[key] = c_out
            return x, new_c
        x, seg_cache = jax.lax.scan(
            body, x, (sp, sc), unroll=seg.repeats if cfg.scan_unroll else 1)
        new_caches.append(seg_cache)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _lm_head(cfg, params, x)
    return logits[:, 0, :], new_caches


# -------------------------------------------------------------------- loss

def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat: bool = True):
    """Next-token cross-entropy (f32 math over compute-dtype logits)."""
    logits, aux, _ = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}
