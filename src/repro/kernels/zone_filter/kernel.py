"""Pallas TPU kernel: streaming filtered reduction over a zone.

This is the paper's Figure 2 hot loop (predicate over 64Mi integers at page
granularity) re-tiled for the TPU memory hierarchy:

  * the zone lives in HBM as ``[n_pages, page_elems]``;
  * the grid streams fixed *blocks* of pages through VMEM
    (``BlockSpec((pages_per_block, page_elems))``) — the paper's
    "CSD DRAM is small, process per page" constraint becomes
    "the working set must fit the ~16 MiB VMEM";
  * each grid step reduces its block on the VPU and accumulates into a
    per-block partials vector; only partials (n_blocks values, not the
    zone) leave the kernel — near-data processing at the HBM boundary.

Program transforms (the eBPF-analogue ALU/CMP chain) are traced into the
kernel body as fused elementwise ops, so one kernel serves every verified
program with a reduce terminal.

Alignment: ``page_elems`` (1024 int32 for the paper's 4 KiB pages) is a
multiple of the 128-lane VPU width; ``pages_per_block`` is a multiple of 8
sublanes.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["filtered_reduce_pallas", "filtered_reduce_pallas_batched",
           "DEFAULT_BLOCK_PAGES"]

DEFAULT_BLOCK_PAGES = 512   # 512 pages x 4 KiB = 2 MiB block in VMEM


def _pick_block_pages(block_pages: int, n_pages: int) -> int:
    """Largest block size <= ``block_pages`` that tiles ``n_pages`` evenly."""
    bp = min(block_pages, n_pages)
    while n_pages % bp:
        bp -= 1
    return bp


def _acc_dtype(kind: str, dtype) -> jnp.dtype:
    if kind == "count":
        return jnp.int32
    if kind == "sum":
        return jnp.float32 if dtype.kind == "f" else jnp.int32
    return dtype


def _reduce_kernel(x_ref, out_ref, *, transform, kind, acc_dtype):
    """One grid step: reduce one VMEM block to one partial."""
    x = x_ref[...]
    vals, mask = transform(x)
    # dtype pinned explicitly: under 64-bit trace mode jnp.sum would promote
    # int32 partials to int64 and miss the out_ref dtype
    if kind == "count":
        out_ref[0] = jnp.sum(mask.astype(jnp.int32), dtype=jnp.int32)
    elif kind == "sum":
        out_ref[0] = jnp.sum(jnp.where(mask, vals, 0).astype(acc_dtype),
                             dtype=acc_dtype)
    elif kind == "min":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).max
        out_ref[0] = jnp.min(jnp.where(mask, vals, ident))
    elif kind == "max":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).min
        out_ref[0] = jnp.max(jnp.where(mask, vals, ident))
    else:
        raise ValueError(kind)


def filtered_reduce_pallas(
    pages: jnp.ndarray,
    *,
    kind: str = "count",
    transform: Optional[Callable] = None,
    block_pages: int = DEFAULT_BLOCK_PAGES,
    interpret: bool = True,
) -> jnp.ndarray:
    """Filtered reduction over a zone buffer [n_pages, page_elems].

    ``transform(x) -> (vals, mask)`` is the fused program chain (defaults to
    the identity with an all-true mask). Returns a scalar: int32 count,
    f32/i64-widened sum, or the dtype min/max.

    ``interpret=True`` runs the kernel body on CPU (validation); on TPU pass
    ``interpret=False``.
    """
    n_pages, page_elems = pages.shape
    bp = _pick_block_pages(block_pages, n_pages)
    n_blocks = n_pages // bp
    if transform is None:
        transform = lambda x: (x, jnp.ones(x.shape, bool))
    acc_dtype = _acc_dtype(kind, pages.dtype)

    kernel = functools.partial(_reduce_kernel, transform=transform, kind=kind,
                               acc_dtype=acc_dtype)
    partials = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((bp, page_elems), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), acc_dtype),
        interpret=interpret,
    )(pages)
    return _combine_partials(partials, kind, acc_dtype)


def _combine_partials(partials: jnp.ndarray, kind: str, acc_dtype,
                      axis=None) -> jnp.ndarray:
    """Final tree-reduce of the tiny partials vector (fused into the same
    jit as the kernel call)."""
    if kind == "count":
        return partials.sum(dtype=jnp.int32, axis=axis)
    if kind == "sum":
        return partials.astype(jnp.float32).sum(axis=axis) \
            if acc_dtype == jnp.float32 else partials.sum(dtype=jnp.int32, axis=axis)
    if kind == "min":
        return partials.min(axis=axis)
    return partials.max(axis=axis)


def _batched_reduce_kernel(x_ref, out_ref, *, transform, kind, acc_dtype):
    """One grid step of the chunk-batched kernel: reduce one VMEM block of
    one chunk to one partial. The leading block axis is the chunk axis
    (block size 1), so the body is the single-chunk body on ``x_ref[0]``."""
    x = x_ref[0]
    vals, mask = transform(x)
    if kind == "count":
        out_ref[0, 0] = jnp.sum(mask.astype(jnp.int32), dtype=jnp.int32)
    elif kind == "sum":
        out_ref[0, 0] = jnp.sum(jnp.where(mask, vals, 0).astype(acc_dtype),
                                dtype=acc_dtype)
    elif kind == "min":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).max
        out_ref[0, 0] = jnp.min(jnp.where(mask, vals, ident))
    elif kind == "max":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).min
        out_ref[0, 0] = jnp.max(jnp.where(mask, vals, ident))
    else:
        raise ValueError(kind)


def filtered_reduce_pallas_batched(
    pages: jnp.ndarray,
    *,
    kind: str = "count",
    transform: Optional[Callable] = None,
    block_pages: int = DEFAULT_BLOCK_PAGES,
    interpret: bool = True,
) -> jnp.ndarray:
    """Chunk-batched filtered reduction: ``[n_chunks, n_pages, page_elems]``
    -> one reduced value per chunk (``[n_chunks]``).

    The grid gains a leading dimension over the CHUNK axis — the array
    scheduler's striped fan-out compiles ONE kernel and executes every
    same-shape stripe chunk of a device in a single ``pallas_call``, exactly
    as the vmapped XLA JIT tier already does. Per-chunk accumulation order
    matches the single-chunk kernel (same ``block_pages`` tiling), so integer
    and min/max results are bit-identical to running chunks one by one.
    """
    n_chunks, n_pages, page_elems = pages.shape
    bp = _pick_block_pages(block_pages, n_pages)
    n_blocks = n_pages // bp
    if transform is None:
        transform = lambda x: (x, jnp.ones(x.shape, bool))
    acc_dtype = _acc_dtype(kind, pages.dtype)

    kernel = functools.partial(_batched_reduce_kernel, transform=transform,
                               kind=kind, acc_dtype=acc_dtype)
    partials = pl.pallas_call(
        kernel,
        grid=(n_chunks, n_blocks),
        in_specs=[pl.BlockSpec((1, bp, page_elems), lambda c, i: (c, i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda c, i: (c, i)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, n_blocks), acc_dtype),
        interpret=interpret,
    )(pages)
    return _combine_partials(partials, kind, acc_dtype, axis=1)
