"""Logical-axis -> mesh-axis sharding rules (MaxText-style GSPMD frontend).

Two rule tables ship by default:

  * ``TRAIN_RULES`` — FSDP(+pod) over parameters ("embed" -> data axes, i.e.
    ZeRO-3: optimizer state and params sharded over the data-parallel axes),
    Megatron TP over heads / mlp / vocab / experts, batch DP over (pod, data).
  * ``SERVE_RULES`` — pure TP for weights (params replicated over data — no
    optimizer states at inference), batch over (pod, data), KV-cache sequence
    dim sharded over model when KV heads don't divide the model axis
    (flash-decode style; GSPMD inserts the partial-softmax reductions).

Activations are annotated inside model code with :func:`shard_act` against the
ambient rules installed by :func:`use_rules` — so model definitions stay
mesh-agnostic and per-(arch x shape) overrides are pure data.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _is_param_spec(x) -> bool:
    # duck-typed to avoid a circular import (models.params imports nothing
    # from sharding, but the models package __init__ pulls in transformer,
    # which needs this module)
    return type(x).__name__ == "ParamSpec" and hasattr(x, "axes")

__all__ = [
    "Rules", "TRAIN_RULES", "SERVE_RULES", "rules_for", "logical_to_spec",
    "param_shardings", "shard_act", "use_rules", "current_rules",
]

MeshAxes = Union[None, str, tuple[str, ...]]


@dataclass(frozen=True)
class Rules:
    """Mapping from logical axis name to mesh axis (or axes)."""

    table: dict[str, MeshAxes]
    name: str = "rules"

    def get(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.table.get(logical, None)

    def override(self, name: str = "", **changes: MeshAxes) -> "Rules":
        t = dict(self.table)
        t.update(changes)
        return Rules(t, name or self.name + "+")


# --------------------------------------------------------------------- rules

_DATA_AXES = ("pod", "data")   # collapse to what the mesh actually has

TRAIN_RULES = Rules(
    {
        # ---- parameters
        "layers": None,                  # scanned; never sharded
        "embed": "data",                 # FSDP / ZeRO-3 shard dim
        "embed_pod": ("pod", "data"),    # FSDP over pod too (multi-pod default)
        "q_heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "conv": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "ssm_heads": "model",
        "ssm_head_dim": None,
        # ---- activations
        "act_batch": ("pod", "data"),
        "act_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_kv_seq": None,
        "act_experts": "model",
        "act_groups": ("pod", "data"),
        "act_ssm_inner": "model",
        "act_ssm_heads": "model",
    },
    name="train",
)

SERVE_RULES = Rules(
    {
        "layers": None,
        "embed": None,                   # params replicated over data at serve
        "embed_pod": None,
        "q_heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "conv": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "ssm_heads": "model",
        "ssm_head_dim": None,
        "act_batch": ("pod", "data"),
        "act_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_kv_seq": None,              # overridden to "model" for SP-KV decode
        "act_experts": "model",
        "act_groups": ("pod", "data"),
        "act_ssm_inner": "model",
        "act_ssm_heads": "model",
    },
    name="serve",
)


def rules_for(kind: str, cfg=None, mesh: Optional[Mesh] = None,
              overrides: Optional[dict[str, MeshAxes]] = None) -> Rules:
    """Pick the rule table for a shape kind ('train'|'prefill'|'decode') and
    specialize it to the arch + mesh.

    * decode: KV-cache seq goes to "model" when kv heads don't divide the
      model axis (avoids GSPMD padding waste on the 8-kv-head archs);
    * train: FSDP over pod as well when the mesh has a pod axis.
    """
    base = TRAIN_RULES if kind == "train" else SERVE_RULES
    model_size = None
    axes = ()
    sizes: dict[str, int] = {}
    if mesh is not None:
        axes = tuple(mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        model_size = sizes.get("model")
    t: dict[str, MeshAxes] = {}
    if kind == "train" and "pod" in axes:
        t["embed"] = ("pod", "data")
    if cfg is not None and getattr(cfg, "family", "") == "moe" and mesh is not None:
        batch_shards = sizes.get("pod", 1) * sizes.get("data", 1)
        expert_bytes = 3 * cfg.d_model * cfg.expert_d_ff * 2
        # weight-gathering EP pays off only when the token bytes crossing the
        # mesh dwarf the expert weights — true for train/prefill (1M tokens),
        # inverted at decode (128 tokens vs 1.1 GB of experts; §Perf iter. 8)
        fine_grained = (cfg.num_experts >= 32 and expert_bytes <= 64 * 2**20
                        and kind != "decode")
        if fine_grained:
            # §Perf iteration 2 (deepseek-moe): the sort-based dispatch's
            # scatter/gather over data-dependent indices cannot be partitioned
            # by GSPMD when the slot tensors span the model axis — it falls
            # back to replicate+mask+all-reduce of [G, Tg*k, d]-sized tensors
            # (measured 51 GB per op). Fine-grained experts are tiny, so
            # invert the movement: dispatch groups shard over EVERY mesh axis
            # (fully token-local scatter/gather) and the expert weights are
            # all-gathered on use (<< token bytes).
            t["act_groups"] = tuple(a for a in ("pod", "data", "model")
                                    if a in axes)
            t["act_experts"] = None
            t["act_expert_mlp"] = None
            group_shards = batch_shards * (model_size or 1)
            if cfg.moe_groups % max(group_shards, 1):
                t["act_groups"] = None
        else:
            if cfg.moe_groups % batch_shards:
                # scatter/gather through a *padded* group dim corrupts
                # dispatch (GSPMD pads uneven dims); replicate groups instead
                t["act_groups"] = None
            if model_size and cfg.num_experts % model_size:
                # grok-1: 8 experts on a 16-way model axis — shard the expert
                # FFN dim (TP-within-expert) instead of the expert dim
                t["experts"] = None
                t["act_experts"] = None
                t["expert_mlp"] = "model"
                t["act_expert_mlp"] = "model"
    if kind == "decode" and cfg is not None and model_size:
        kv = getattr(cfg, "num_kv_heads", 0)
        if kv and kv % model_size != 0:
            # flash-decode: shard the cache's sequence dim instead of heads
            t["act_kv_seq"] = "model"
            t["act_kv_heads"] = None
            t["act_heads"] = None if cfg.num_heads % model_size else "model"
    if overrides:
        t.update(overrides)
    out = base.override(f"{base.name}:{kind}", **t) if t else base
    # drop mesh axes the mesh doesn't have (e.g. single-pod has no "pod")
    if mesh is not None:
        cleaned = {}
        for k, v in out.table.items():
            if v is None:
                cleaned[k] = None
            elif isinstance(v, str):
                cleaned[k] = v if v in axes else None
            else:
                kept = tuple(a for a in v if a in axes)
                cleaned[k] = kept if kept else None
        out = Rules(cleaned, out.name)
    return out


# ----------------------------------------------------------------- plumbing

def logical_to_spec(rules: Rules, logical_axes: Sequence[Optional[str]],
                    shape: Optional[Sequence[int]] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axes to a PartitionSpec.

    When ``shape`` + ``mesh`` are provided, mesh axes that do not divide the
    dimension are dropped (suffix-first): jit input shardings REQUIRE even
    divisibility (unlike with_sharding_constraint, which pads), so e.g. a
    1-kv-head weight on a 16-way model axis degrades to replicated, and a
    256206-vocab embedding drops the model axis. This keeps every
    (arch x shape x mesh) cell lowerable without per-arch special-casing;
    the roofline then shows what the degradation costs.
    """
    sizes = dict(mesh.shape) if mesh is not None else {}   # Mesh or AbstractMesh
    used: set[str] = set()
    parts = []
    for i, ax in enumerate(logical_axes):
        mesh_ax = rules.get(ax)
        if mesh_ax is None:
            parts.append(None)
            continue
        if isinstance(mesh_ax, str):
            mesh_ax = (mesh_ax,)
        kept = tuple(a for a in mesh_ax if a not in used)
        if shape is not None and sizes:
            dim = shape[i]
            while kept:
                prod = 1
                for a in kept:
                    prod *= sizes.get(a, 1)
                if prod and dim % prod == 0:
                    break
                kept = kept[:-1]          # drop the innermost axis first
        used.update(kept)
        parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*parts)


def param_shardings(specs, mesh: Mesh, rules: Rules):
    """NamedSharding tree matching a ParamSpec tree (divisibility-degraded)."""
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, logical_to_spec(rules, s.axes, s.shape, mesh)),
        specs,
        is_leaf=_is_param_spec,
    )


def named_sharding_for(shape: Sequence[int],
                       logical_axes: Sequence[Optional[str]],
                       mesh: Mesh, rules: Rules) -> NamedSharding:
    """Divisibility-degraded NamedSharding for an arbitrary array shape."""
    return NamedSharding(mesh, logical_to_spec(rules, logical_axes, shape, mesh))


_current_rules: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    """Install ambient rules for :func:`shard_act` (used while tracing)."""
    tok = _current_rules.set(rules)
    try:
        yield
    finally:
        _current_rules.reset(tok)


def current_rules() -> Optional[Rules]:
    return _current_rules.get()


def _ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def shard_act(x, logical_axes: Sequence[Optional[str]]):
    """Annotate an activation with logical axes; no-op outside `use_rules`
    (keeps single-device smoke tests annotation-free). Mesh axes that do not
    divide the dimension are dropped (GSPMD padding on uneven constraint dims
    causes replicate+all-reduce round-trips)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(rules, logical_axes, x.shape, _ambient_mesh())
    return jax.lax.with_sharding_constraint(x, spec)


# storage logical axis -> compute-time logical axis: the FSDP ("embed") dim
# is GATHERED at use, tensor-parallel dims stay sharded
_PARAM_COMPUTE_AXES = {
    "embed": None,          # FSDP: all-gather before the matmul
    "embed_pod": None,
    "q_heads": "act_heads",
    "kv_heads": "act_kv_heads",
    "mlp": "act_mlp",
    "vocab": "act_vocab",
    "experts": "act_experts",
    "expert_mlp": "act_expert_mlp",
    "ssm_inner": "act_ssm_inner",
    "ssm_heads": "act_ssm_heads",
    "ssm_state": None,
    "conv": None,
    "head_dim": None,
    "layers": None,
}


def use_param(w, storage_axes: Sequence[Optional[str]]):
    """Pin a weight to its COMPUTE sharding at the use site (FSDP all-gather
    of the "embed" dim, TP dims unchanged).

    Without this, GSPMD propagates the storage sharding (embed@data) into
    dot outputs, where it conflicts with batch@data — the partitioner then
    replicates the batch dim and emits full-batch f32 all-reduces in the
    BACKWARD pass (measured 25.7 GB/op at deepseek scale; §Perf iteration 5).
    Pinning the gather makes the FSDP cost explicit: one bf16 weight
    all-gather per use, exactly ZeRO-3 semantics.
    """
    rules = current_rules()
    if rules is None:
        return w
    compute_axes = tuple(_PARAM_COMPUTE_AXES.get(a, None) if a else None
                         for a in storage_axes)
    spec = logical_to_spec(rules, compute_axes, w.shape, _ambient_mesh())
    return jax.lax.with_sharding_constraint(w, spec)
