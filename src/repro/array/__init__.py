"""CSD array: multi-device striping + NVMe-style offload scheduling.

The subsystem the paper defers as future work — asynchronous execution and
multi-device operation — built on the repo's single-device primitives:

  * :mod:`repro.array.striping`  — ``StripedZoneArray``: N ZNS devices as one
    logical zoned address space (RAID-0 zone striping; ``ZonedDevice``
    drop-in, so every existing consumer works unchanged);
  * :mod:`repro.array.queues`    — NVMe-style per-tenant submission/completion
    queue pairs with depth limits, backpressure, and weighted round-robin
    arbitration;
  * :mod:`repro.array.scheduler` — ``OffloadScheduler``: verify once, fan out
    per device (vmapped-JIT batching for same-shape shards), scatter-gather
    with a program-aware combiner, aggregated ``ArrayOffloadStats``.
"""
from repro.array.striping import LogicalZone, StripeChunk, StripedZoneArray
from repro.array.queues import (
    Completion,
    CompletionQueue,
    OffloadCommand,
    QueueFullError,
    QueuePair,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.array.scheduler import (
    ArrayOffloadError,
    ArrayOffloadStats,
    OffloadScheduler,
)

__all__ = [
    "StripedZoneArray", "LogicalZone", "StripeChunk",
    "SubmissionQueue", "CompletionQueue", "QueuePair", "QueueFullError",
    "OffloadCommand", "Completion", "WeightedRoundRobinArbiter",
    "OffloadScheduler", "ArrayOffloadStats", "ArrayOffloadError",
]
