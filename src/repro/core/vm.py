"""Execution tiers for verified offload programs.

The paper evaluates three ways to execute the same offloaded computation
(Figure 2); we reproduce all three, plus the TPU-native tier the paper lists
as future hardware backends:

  tier "native"     hand-written host code (paper: SPDK userspace loop)
                    -> :func:`run_oracle` (vectorized numpy; also the test
                    oracle for every other tier)
  tier "interp"     stack-machine VM, one instruction at a time, per-access
                    memory bounds checks (paper: uBPF without JIT)
                    -> :func:`interpret_program`
  tier "jit"        program compiled before execution (paper: uBPF JIT/x86;
                    here: XLA via jax.jit), page-streamed with lax.scan
                    -> :func:`jit_program`
  tier "kernel"     Pallas TPU kernel streaming zone blocks HBM->VMEM
                    (repro.kernels.zone_filter / zone_reduce; wired up by
                    repro.core.csd.NvmCsd)

All tiers process the zone at **page granularity** — the paper's conservative
design for small CSD DRAM, which on TPU becomes the VMEM-residency constraint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.experimental
import jax.numpy as jnp

from repro.core.programs import (
    CMP_OPS,
    Instruction,
    OpCode,
    Program,
)

__all__ = [
    "OffloadResult",
    "run_oracle",
    "interpret_program",
    "jit_program",
    "jit_program_batched",
    "JittedProgram",
]


# ---------------------------------------------------------------------------
# shared semantics helpers
# ---------------------------------------------------------------------------

_SUM_WIDEN = {
    np.dtype(np.int32): np.int64, np.dtype(np.int64): np.int64,
    np.dtype(np.uint32): np.int64,
    np.dtype(np.float32): np.float64, np.dtype(np.float64): np.float64,
}


def _minmax_identity(op: OpCode, dtype: np.dtype):
    info = np.iinfo(dtype) if np.issubdtype(dtype, np.integer) else np.finfo(dtype)
    return info.max if op == OpCode.RED_MIN else info.min


def _apply_alu_np(x: np.ndarray, insn: Instruction) -> np.ndarray:
    op, imm = insn.op, insn.imm
    dt = x.dtype
    with np.errstate(over="ignore"):
        if op == OpCode.ADD:
            return (x + dt.type(imm)).astype(dt)
        if op == OpCode.SUB:
            return (x - dt.type(imm)).astype(dt)
        if op == OpCode.MUL:
            return (x * dt.type(imm)).astype(dt)
        if op == OpCode.AND:
            return x & dt.type(imm)
        if op == OpCode.OR:
            return x | dt.type(imm)
        if op == OpCode.XOR:
            return x ^ dt.type(imm)
        if op == OpCode.SHL:
            return (x << imm).astype(dt)
        if op == OpCode.SHR:
            return (x >> imm).astype(dt)
        if op == OpCode.MOD:
            return (x % dt.type(imm)).astype(dt)
        if op == OpCode.ABS:
            return np.abs(x)
        if op == OpCode.NEG:
            return (-x).astype(dt)
    raise AssertionError(op)


def _apply_cmp_np(x: np.ndarray, insn: Instruction) -> np.ndarray:
    imm = x.dtype.type(insn.imm)
    return {
        OpCode.CMP_GT: x > imm, OpCode.CMP_GE: x >= imm,
        OpCode.CMP_LT: x < imm, OpCode.CMP_LE: x <= imm,
        OpCode.CMP_EQ: x == imm, OpCode.CMP_NE: x != imm,
    }[insn.op]


def _hist_bin_np(x: np.ndarray, lo, hi, bins: int) -> tuple[np.ndarray, np.ndarray]:
    in_range = (x >= lo) & (x < hi)
    # use float64 bin math so int and float streams agree across tiers
    idx = np.floor((x.astype(np.float64) - lo) * bins / (hi - lo)).astype(np.int64)
    idx = np.clip(idx, 0, bins - 1)
    return idx, in_range


@dataclass
class OffloadResult:
    """What travels back over the link -- the whole point of the paper."""

    value: object                      # scalar, histogram array, or (values, count)
    bytes_returned: int
    pages_processed: int
    insns_executed: int
    exec_seconds: float
    compile_seconds: float = 0.0
    read_seconds: float = 0.0          # time inside device transfers
    cache_hits: int = 0                # compiled-executable cache hits
    cache_misses: int = 0


# ---------------------------------------------------------------------------
# tier "native": vectorized numpy — doubles as the semantic oracle for tests
# ---------------------------------------------------------------------------

def run_oracle(program: Program, data: np.ndarray) -> object:
    """Vectorized reference semantics over the whole (typed) zone contents."""
    x = np.asarray(data, dtype=np.dtype(program.input_dtype)).reshape(-1)
    records = None
    mask = np.ones(x.shape, dtype=bool)
    for insn in program.insns[:-1]:
        if insn.op == OpCode.FIELD:
            stride, index = insn.imm
            records = x.reshape(-1, stride)
            x = records[:, index]
            mask = np.ones(x.shape, dtype=bool)
        elif insn.op in CMP_OPS:
            mask &= _apply_cmp_np(x, insn)
        else:
            x = _apply_alu_np(x, insn)
    term = program.terminal
    if term.op == OpCode.SELECT_REC:
        cap = program.select_capacity
        sel = records[mask]
        out = np.zeros((cap, records.shape[1]), records.dtype)
        n = min(sel.shape[0], cap)
        out[:n] = sel[:n]
        return out, np.int64(sel.shape[0])
    if term.op == OpCode.RED_COUNT:
        return np.int64(mask.sum())
    if term.op == OpCode.RED_SUM:
        widen = _SUM_WIDEN[x.dtype]
        return widen(x[mask].astype(widen).sum())
    if term.op in (OpCode.RED_MIN, OpCode.RED_MAX):
        ident = x.dtype.type(_minmax_identity(term.op, x.dtype))
        sel = x[mask]
        if sel.size == 0:
            return ident
        return sel.min() if term.op == OpCode.RED_MIN else sel.max()
    if term.op == OpCode.RED_HIST:
        lo, hi, bins = term.imm
        idx, in_range = _hist_bin_np(x, lo, hi, bins)
        return np.bincount(idx[mask & in_range], minlength=bins).astype(np.int64)
    if term.op == OpCode.SELECT:
        cap = program.select_capacity
        sel = x[mask]
        out = np.zeros(cap, dtype=x.dtype)
        n = min(sel.size, cap)
        out[:n] = sel[:n]
        return out, np.int64(sel.size)   # count reports ALL matches (truncation visible)
    raise AssertionError(term)


# ---------------------------------------------------------------------------
# tier "interp": stack-machine VM (paper's uBPF-without-JIT)
# ---------------------------------------------------------------------------

def interpret_program(
    program: Program,
    read_page: Callable[[int], np.ndarray],
    n_pages: int,
    page_elems: int,
) -> OffloadResult:
    """One instruction at a time, one page at a time, with per-access memory
    bounds checks -- deliberately mirrors the uBPF stack machine the paper
    benchmarks as its slow tier. ``read_page`` is the device's bounds-checked
    ``bpf_read`` hook."""
    dtype = np.dtype(program.input_dtype)
    term = program.terminal
    # accumulator init
    count = np.int64(0)
    acc_sum = _SUM_WIDEN[dtype](0)
    acc_mm = dtype.type(_minmax_identity(term.op, dtype)) \
        if term.op in (OpCode.RED_MIN, OpCode.RED_MAX) else None
    hist = np.zeros(term.imm[2], dtype=np.int64) if term.op == OpCode.RED_HIST else None
    sel_buf = np.zeros(program.select_capacity, dtype=dtype) \
        if term.op == OpCode.SELECT else None
    rec_stride = program.insns[0].imm[0] if (
        term.op == OpCode.SELECT_REC) else None
    rec_buf = np.zeros((program.select_capacity, rec_stride), dtype=dtype) \
        if term.op == OpCode.SELECT_REC else None
    sel_n = np.int64(0)

    insns_executed = 0
    t0 = time.perf_counter()
    for p in range(n_pages):
        page = np.asarray(read_page(p))
        # reinterpret in place (pages are block-aligned, so the typed view is
        # free); raw uint8 device reads and pre-typed test doubles both work
        x = page.reshape(-1).view(dtype) if page.dtype != dtype \
            else page.reshape(-1)
        # explicit bounds check per access (the uBPF interp overhead the
        # paper attributes its slow tier to)
        if x.size != page_elems:
            raise IndexError(
                f"page {p}: access of {x.size} elements outside page bound {page_elems}"
            )
        mask = np.ones(x.shape, dtype=bool)
        records = None
        for insn in program.insns[:-1]:
            insns_executed += 1
            if insn.op == OpCode.FIELD:
                stride, index = insn.imm
                if x.size % stride != 0 or index >= stride:  # bounds check
                    raise IndexError(f"FIELD access out of record bounds on page {p}")
                records = x.reshape(-1, stride)
                x = records[:, index]
                mask = np.ones(x.shape, dtype=bool)
            elif insn.op in CMP_OPS:
                mask &= _apply_cmp_np(x, insn)
            else:
                x = _apply_alu_np(x, insn)
        insns_executed += 1  # the terminal
        if term.op == OpCode.RED_COUNT:
            count += mask.sum()
        elif term.op == OpCode.RED_SUM:
            acc_sum += x[mask].astype(acc_sum.dtype).sum()
        elif term.op == OpCode.RED_MIN:
            sel = x[mask]
            if sel.size:
                acc_mm = min(acc_mm, sel.min())
        elif term.op == OpCode.RED_MAX:
            sel = x[mask]
            if sel.size:
                acc_mm = max(acc_mm, sel.max())
        elif term.op == OpCode.RED_HIST:
            lo, hi, bins = term.imm
            idx, in_range = _hist_bin_np(x, lo, hi, bins)
            hist += np.bincount(idx[mask & in_range], minlength=bins).astype(np.int64)
        elif term.op == OpCode.SELECT:
            sel = x[mask]
            space = program.select_capacity - int(sel_n)
            if space > 0 and sel.size:
                take = min(space, sel.size)
                # bounds-checked write into the return buffer
                sel_buf[int(sel_n) : int(sel_n) + take] = sel[:take]
            sel_n += sel.size
        elif term.op == OpCode.SELECT_REC:
            sel = records[mask]
            space = program.select_capacity - int(sel_n)
            if space > 0 and sel.shape[0]:
                take = min(space, sel.shape[0])
                rec_buf[int(sel_n) : int(sel_n) + take] = sel[:take]
            sel_n += sel.shape[0]
    dt_exec = time.perf_counter() - t0

    if term.op == OpCode.RED_COUNT:
        value, nbytes = count, 8
    elif term.op == OpCode.RED_SUM:
        value, nbytes = acc_sum, 8
    elif term.op in (OpCode.RED_MIN, OpCode.RED_MAX):
        value, nbytes = acc_mm, dtype.itemsize
    elif term.op == OpCode.RED_HIST:
        value, nbytes = hist, hist.nbytes
    elif term.op == OpCode.SELECT_REC:
        value, nbytes = (rec_buf, sel_n), rec_buf.nbytes + 8
    else:
        value, nbytes = (sel_buf, sel_n), sel_buf.nbytes + 8
    return OffloadResult(value, nbytes, n_pages, insns_executed, dt_exec)


# ---------------------------------------------------------------------------
# tier "jit": XLA-compiled, page-streamed with lax.scan (paper's uBPF-JIT)
# ---------------------------------------------------------------------------

@dataclass
class JittedProgram:
    fn: Callable                     # (pages[n_pages, page_elems]) -> result
    compile_seconds: float           # the paper's "JIT time" statistic
    n_pages: int
    page_elems: int
    program: Program

    def __call__(self, pages) -> object:
        # the executable was compiled under 64-bit mode; the call must run
        # under it too, or device_put canonicalizes int64/float64 zone pages
        # down to 32 bits and the input aval check rejects them
        with jax.experimental.enable_x64():
            return self.fn(pages)


def _stream_mask_jnp(program: Program, x: jnp.ndarray):
    mask = jnp.ones(x.shape, dtype=bool)
    for insn in program.insns[:-1]:
        op, imm = insn.op, insn.imm
        if op == OpCode.FIELD:
            stride, index = imm
            x = x.reshape(-1, stride)[:, index]
            mask = jnp.ones(x.shape, dtype=bool)
        elif op in CMP_OPS:
            imm_t = jnp.asarray(imm, dtype=x.dtype)
            mask &= {
                OpCode.CMP_GT: x > imm_t, OpCode.CMP_GE: x >= imm_t,
                OpCode.CMP_LT: x < imm_t, OpCode.CMP_LE: x <= imm_t,
                OpCode.CMP_EQ: x == imm_t, OpCode.CMP_NE: x != imm_t,
            }[op]
        elif op == OpCode.ABS:
            x = jnp.abs(x)
        elif op == OpCode.NEG:
            x = -x
        else:
            imm_t = jnp.asarray(imm, dtype=x.dtype)
            x = {
                OpCode.ADD: lambda: x + imm_t, OpCode.SUB: lambda: x - imm_t,
                OpCode.MUL: lambda: x * imm_t, OpCode.AND: lambda: x & imm_t,
                OpCode.OR: lambda: x | imm_t, OpCode.XOR: lambda: x ^ imm_t,
                OpCode.SHL: lambda: x << imm, OpCode.SHR: lambda: x >> imm,
                OpCode.MOD: lambda: x % imm_t,
            }[op]()
    return x, mask


def _build_program_runner(program: Program):
    """Build the page-scanning ``run(pages)`` closure shared by the single
    (:func:`jit_program`) and chunk-batched (:func:`jit_program_batched`)
    compile paths."""
    dtype = np.dtype(program.input_dtype)
    term = program.terminal
    cap = program.select_capacity

    def init_carry():
        if term.op == OpCode.RED_COUNT:
            return jnp.zeros((), jnp.int64)
        if term.op == OpCode.RED_SUM:
            return jnp.zeros((), _SUM_WIDEN[dtype])
        if term.op in (OpCode.RED_MIN, OpCode.RED_MAX):
            return jnp.asarray(_minmax_identity(term.op, dtype), dtype)
        if term.op == OpCode.RED_HIST:
            return jnp.zeros((term.imm[2],), jnp.int64)
        if term.op == OpCode.SELECT:
            return (jnp.zeros((cap + 1,), dtype), jnp.zeros((), jnp.int64))
        if term.op == OpCode.SELECT_REC:
            stride = program.insns[0].imm[0]
            return (jnp.zeros((cap + 1, stride), dtype),
                    jnp.zeros((), jnp.int64))
        raise AssertionError(term)

    def page_step(carry, page):
        x, mask = _stream_mask_jnp(program, page)
        if term.op == OpCode.RED_COUNT:
            return carry + mask.sum(dtype=jnp.int64), None
        if term.op == OpCode.RED_SUM:
            return carry + jnp.where(mask, x, 0).astype(carry.dtype).sum(), None
        if term.op == OpCode.RED_MIN:
            ident = jnp.asarray(_minmax_identity(term.op, dtype), dtype)
            return jnp.minimum(carry, jnp.where(mask, x, ident).min()), None
        if term.op == OpCode.RED_MAX:
            ident = jnp.asarray(_minmax_identity(term.op, dtype), dtype)
            return jnp.maximum(carry, jnp.where(mask, x, ident).max()), None
        if term.op == OpCode.RED_HIST:
            lo, hi, bins = term.imm
            in_range = (x >= lo) & (x < hi)
            idx = jnp.floor(
                (x.astype(jnp.float64) - lo) * bins / (hi - lo)
            ).astype(jnp.int64)
            idx = jnp.clip(idx, 0, bins - 1)
            upd = jnp.where(mask & in_range, 1, 0).astype(jnp.int64)
            return carry.at[idx].add(upd), None
        if term.op == OpCode.SELECT:
            buf, n = carry
            pos = n + jnp.cumsum(mask) - 1
            ok = mask & (pos < cap)
            # overflow writes land in the scratch slot [cap]
            buf = buf.at[jnp.where(ok, pos, cap)].set(x)
            return (buf, n + mask.sum(dtype=jnp.int64)), None
        if term.op == OpCode.SELECT_REC:
            buf, n = carry
            stride = program.insns[0].imm[0]
            records = page.reshape(-1, stride)
            pos = n + jnp.cumsum(mask) - 1
            ok = mask & (pos < cap)
            buf = buf.at[jnp.where(ok, pos, cap)].set(records)
            return (buf, n + mask.sum(dtype=jnp.int64)), None
        raise AssertionError(term)

    def run(pages):
        carry, _ = jax.lax.scan(page_step, init_carry(), pages)
        if term.op in (OpCode.SELECT, OpCode.SELECT_REC):
            buf, n = carry
            return buf[:cap], n
        return carry

    return run


def jit_program(
    program: Program,
    n_pages: int,
    page_elems: int,
    *,
    donate: bool = False,
) -> JittedProgram:
    """Compile ``program`` to XLA. The compiled function scans the zone one
    page at a time (bounded working set — the VMEM/CSD-DRAM constraint) and
    carries only the reduction accumulator."""
    dtype = np.dtype(program.input_dtype)
    run = _build_program_runner(program)
    spec = jax.ShapeDtypeStruct((n_pages, page_elems), dtype)
    t0 = time.perf_counter()
    # int64 accumulators need 64-bit mode at *trace* time; scope it to the
    # offload compiler so the model stack keeps JAX's 32-bit defaults.
    with jax.experimental.enable_x64():
        jitted = jax.jit(run, donate_argnums=(0,) if donate else ())
        compiled = jitted.lower(spec).compile()
    compile_seconds = time.perf_counter() - t0
    return JittedProgram(compiled, compile_seconds, n_pages, page_elems, program)


def jit_program_batched(
    program: Program,
    n_chunks: int,
    n_pages: int,
    page_elems: int,
) -> JittedProgram:
    """Compile ``program`` vmapped over a leading *chunk* axis.

    The array scheduler uses this to execute every same-shape shard of a
    striped offload in ONE XLA call: input ``[n_chunks, n_pages, page_elems]``,
    output a per-chunk result batch (e.g. ``[n_chunks]`` partial sums, or
    ``([n_chunks, cap], [n_chunks])`` for SELECT) that the combiner then
    re-reduces in logical stripe order."""
    dtype = np.dtype(program.input_dtype)
    run = _build_program_runner(program)
    spec = jax.ShapeDtypeStruct((n_chunks, n_pages, page_elems), dtype)
    t0 = time.perf_counter()
    with jax.experimental.enable_x64():
        compiled = jax.jit(jax.vmap(run)).lower(spec).compile()
    compile_seconds = time.perf_counter() - t0
    return JittedProgram(compiled, compile_seconds, n_pages, page_elems, program)
