"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence ``h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)`` is a
linear scan — training uses ``jax.lax.associative_scan`` (O(log L) depth,
parallel over devices); decode is the O(1) single-step update. Input/recency
gates are block-diagonal linears (num_heads blocks) as in the Griffin paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cdtype
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import shard_act, use_param

__all__ = ["rglru_specs", "apply_rglru", "rglru_decode_step", "rglru_cache_specs"]


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dr = d                                  # lru width = d_model (RG-9b)
    nb = max(cfg.num_heads, 1)              # gate blocks
    bw = dr // nb
    kc = cfg.ssm_conv
    return {
        "wx": ParamSpec((d, dr), ("embed", "ssm_inner"), init="fan_in"),
        "wg": ParamSpec((d, dr), ("embed", "ssm_inner"), init="fan_in"),
        "conv": ParamSpec((kc, dr), ("conv", "ssm_inner"), init="fan_in"),
        "w_i": ParamSpec((nb, bw, bw), ("ssm_heads", None, None), init="fan_in"),
        "b_i": ParamSpec((dr,), ("ssm_inner",), init="zeros"),
        "w_r": ParamSpec((nb, bw, bw), ("ssm_heads", None, None), init="fan_in"),
        "b_r": ParamSpec((dr,), ("ssm_inner",), init="zeros"),
        "lam": ParamSpec((dr,), ("ssm_inner",), init="rglru_a", dtype=jnp.float32),
        "wo": ParamSpec((dr, d), ("ssm_inner", "embed"), init="fan_in"),
    }


def _block_diag(w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., dr]; w: [nb, bw, bw] block-diagonal linear."""
    nb, bw, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, bw)
    y = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype))
    return y.reshape(*x.shape) + b.astype(x.dtype)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))


def _gates(cfg: ModelConfig, p: dict, xc: jnp.ndarray):
    """Returns (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid(_block_diag(p["w_r"], p["b_r"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(p["w_i"], p["b_i"], xc).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r       # [..., dr] f32
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * xc.astype(jnp.float32))
    return log_a, b


def apply_rglru(cfg: ModelConfig, p: dict, u: jnp.ndarray,
                return_cache: bool = False):
    """u: [B, L, d] (training / prefill, parallel scan). With
    ``return_cache``, also returns the decode cache (conv tail + h_T)."""
    dt = cdtype(cfg)
    x = u @ use_param(p["wx"], ("embed", "ssm_inner")).astype(dt)
    g = jax.nn.gelu(u @ use_param(p["wg"], ("embed", "ssm_inner")).astype(dt), approximate=True)
    xc = _causal_conv(x, p["conv"].astype(dt))
    xc = shard_act(xc, ("act_batch", "act_seq", "act_ssm_inner"))
    log_a, b = _gates(cfg, p, xc)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(dt) * g) @ use_param(p["wo"], ("ssm_inner", "embed")).astype(dt)
    if return_cache:
        kc = cfg.ssm_conv
        L = x.shape[1]
        tail = x[:, L - (kc - 1):, :] if L >= kc - 1 else jnp.pad(
            x, ((0, 0), (kc - 1 - L, 0), (0, 0)))
        return out, {"conv": tail, "h": h[:, -1, :]}
    return out


def rglru_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    dr, kc = cfg.d_model, cfg.ssm_conv
    return {
        "conv": jax.ShapeDtypeStruct((batch, kc - 1, dr),
                                     jnp.dtype(cfg.compute_dtype)),
        "h": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
    }


def rglru_decode_step(cfg: ModelConfig, p: dict, u: jnp.ndarray, cache: dict):
    """u: [B, 1, d]; O(1) update of (conv window, hidden state)."""
    dt = cdtype(cfg)
    x = (u @ p["wx"].astype(dt))[:, 0, :]                        # [B, dr]
    g = jax.nn.gelu((u @ p["wg"].astype(dt))[:, 0, :], approximate=True)
    hist = jnp.concatenate([cache["conv"], x[:, None, :]], axis=1)  # [B, kc, dr]
    xc = jnp.einsum("bkd,kd->bd", hist, p["conv"].astype(dt))
    log_a, b = _gates(cfg, p, xc)
    h = jnp.exp(log_a) * cache["h"] + b                          # [B, dr] f32
    y = (h.astype(dt) * g) @ p["wo"].astype(dt)
    return y[:, None, :], {"conv": hist[:, 1:, :], "h": h}
