"""ZCSD core: the paper's contribution as a composable library.

  * :mod:`repro.core.programs` — the offload program IR (eBPF analogue)
  * :mod:`repro.core.verifier` — bounded-execution / memory-safety verifier
  * :mod:`repro.core.vm`       — interpreter + XLA-JIT execution tiers
  * :mod:`repro.core.csd`      — the NvmCsd device (two-part API, stats)
  * :mod:`repro.core.cache`    — shared compiled-executable cache
  * :mod:`repro.core.prefetch` — read/compute overlap primitives
"""
from repro.core.cache import CacheStats, CompiledProgramCache, default_cache
from repro.core.prefetch import LookaheadReader, RingReader, prefetched
from repro.core.programs import (
    Instruction,
    OpCode,
    Program,
    field_reduce,
    filter_count,
    filter_select,
    filter_sum,
    histogram,
)
from repro.core.verifier import VerifierLimits, VerifyError, verify_program
from repro.core.vm import OffloadResult, interpret_program, jit_program, run_oracle
from repro.core.csd import CsdTier, NvmCsd, OffloadStats

__all__ = [
    "Instruction", "OpCode", "Program",
    "filter_count", "filter_sum", "filter_select", "histogram", "field_reduce",
    "VerifyError", "VerifierLimits", "verify_program",
    "OffloadResult", "interpret_program", "jit_program", "run_oracle",
    "NvmCsd", "CsdTier", "OffloadStats",
    "CacheStats", "CompiledProgramCache", "default_cache",
    "LookaheadReader", "RingReader", "prefetched",
]
