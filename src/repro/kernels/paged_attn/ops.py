"""Public jit'd wrapper for zoned-KV paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attn.kernel import paged_attention_pallas

__all__ = ["paged_attention"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_zones, v_zones, zone_table, lengths, *,
                    interpret: bool = True):
    """Flash-decode over an append-only zoned KV pool (see kernel.py)."""
    return paged_attention_pallas(q, k_zones, v_zones, zone_table, lengths,
                                  interpret=interpret)
