"""CSD array: multi-device striping + NVMe-style offload scheduling.

The subsystem the paper defers as future work — asynchronous execution and
multi-device operation — built on the repo's single-device primitives:

  * :mod:`repro.array.striping`  — ``StripedZoneArray``: N ZNS devices as one
    logical zoned address space (``ZonedDevice`` drop-in, so every existing
    consumer works unchanged) with selectable redundancy: ``raid0``
    striping, ``raid1`` mirror pairs (round-robin reads, survivor redirect),
    or ``xor`` rotating parity (degraded reads reconstruct a dead member's
    chunks from the surviving row members on the completion ring);
  * :mod:`repro.array.queues`    — NVMe-style per-tenant submission/completion
    queue pairs with depth limits, backpressure, and weighted round-robin
    arbitration;
  * :mod:`repro.array.scheduler` — ``OffloadScheduler``: verify once, fan out
    per device (vmapped-JIT batching for same-shape shards), scatter-gather
    with a program-aware combiner, aggregated ``ArrayOffloadStats``;
  * :mod:`repro.array.rebuild`   — ``ArrayManager``: hot spares, online
    rebuild-to-spare on a metered ``"rebuild"`` tenant with per-zone
    cutover, background parity/mirror scrub, and automatic spare promotion
    off the alert engine's ``member_degraded`` incidents.
"""
from repro.array.striping import (
    LogicalZone,
    REDUNDANCY_MODES,
    StripeChunk,
    StripedZoneArray,
)
from repro.array.queues import (
    Completion,
    CompletionQueue,
    OffloadCommand,
    QueueFullError,
    QueuePair,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.array.scheduler import (
    ArrayOffloadError,
    ArrayOffloadStats,
    OffloadScheduler,
)
from repro.array.rebuild import ArrayManager, RebuildError

__all__ = [
    "StripedZoneArray", "LogicalZone", "StripeChunk", "REDUNDANCY_MODES",
    "SubmissionQueue", "CompletionQueue", "QueuePair", "QueueFullError",
    "OffloadCommand", "Completion", "WeightedRoundRobinArbiter",
    "OffloadScheduler", "ArrayOffloadStats", "ArrayOffloadError",
    "ArrayManager", "RebuildError",
]
