"""Benchmark driver: one function per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines. Scaled-down sizes by default
(CI-friendly on 1 CPU core); pass --full for the paper's exact 256 MiB zone.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact sizes (256 MiB zone, 5 runs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: filter,toolchain,pushdown,"
                         "checkpoint,paged_attn,roofline,array")
    args = ap.parse_args()

    from benchmarks import (bench_array, bench_checkpoint, bench_filter,
                            bench_paged_attn, bench_pushdown, bench_toolchain,
                            roofline)

    suites = {
        "filter": lambda: bench_filter.main(
            zone_mib=256 if args.full else 32, runs=5 if args.full else 3),
        "array": lambda: bench_array.main(
            data_mib=64 if args.full else 16, runs=5 if args.full else 3),
        "toolchain": bench_toolchain.main,
        "pushdown": bench_pushdown.main,
        "checkpoint": bench_checkpoint.main,
        "paged_attn": bench_paged_attn.main,
        "roofline": roofline.main,
    }
    chosen = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        try:
            for row in suites[name]():
                print(row)
        except Exception:
            failures += 1
            print(f"{name},ERROR,{traceback.format_exc(limit=1)!r}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
