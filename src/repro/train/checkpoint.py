"""Zoned checkpoint store: fault-tolerant training state on ZNS semantics.

The checkpoint substrate is built directly on the paper's storage model:

  * **append-only**: a checkpoint is a sequence of zone appends (one record
    stream per pytree leaf) into data zones — never an in-place update;
  * **atomic commit**: the manifest (leaf index: zone/offset/shape/dtype +
    step + a payload checksum) is appended to a dedicated manifest zone
    LAST. Recovery scans the manifest zone and takes the newest manifest
    whose payload verifies — a torn/partial checkpoint (crash mid-write) is
    simply never referenced, mirroring log-structured FS commit records;
  * **host-managed GC**: freeing an old checkpoint = ``reset_zone`` on its
    data zones (the ZNS reset primitive; the device never garbage-collects
    behind the host's back);
  * **elastic restore**: leaves are stored as full logical arrays, so a
    checkpoint written on one mesh restores onto ANY mesh/sharding — the
    elastic-scaling path (grow/shrink the pod count between runs).
"""
from __future__ import annotations

import hashlib
import json
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.array import StripedZoneArray
from repro.zns import ZonedDevice, ZoneState

__all__ = ["ZonedCheckpointStore", "CheckpointError"]

MANIFEST_MAGIC = "zcsd-ckpt-v1"


class CheckpointError(Exception):
    pass


def _leaf_to_bytes(x) -> tuple[bytes, str, tuple]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jax.numpy.bfloat16:
        return arr.view(np.uint16).tobytes(), "bfloat16", arr.shape
    return arr.tobytes(), str(arr.dtype), arr.shape


def _leaf_from_bytes(raw: bytes, dtype: str, shape: tuple) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes
        return np.frombuffer(raw, np.uint16).view(ml_dtypes.bfloat16).reshape(shape)
    return np.frombuffer(raw, np.dtype(dtype)).reshape(shape).copy()


class ZonedCheckpointStore:
    """Checkpoints on a (file-backed) ZonedDevice.

    Zone 0 is the manifest zone; zones 1..N-1 hold payload. Payload zones are
    used round-robin per checkpoint generation so GC (zone reset) can reclaim
    whole generations.
    """

    def __init__(self, path: Optional[Path | str] = None, *,
                 device: Optional[ZonedDevice | StripedZoneArray] = None,
                 num_zones: int = 16,
                 zone_bytes: int = 256 * 1024 * 1024,
                 keep: int = 2):
        if device is None:
            device = ZonedDevice(num_zones=num_zones, zone_bytes=zone_bytes,
                                 block_bytes=4096,
                                 backing_file=path)
        self.device = device
        self.keep = keep
        self._recover()

    @classmethod
    def striped(cls, directory: Path | str, *, num_devices: int = 4,
                num_zones: int = 16,
                member_zone_bytes: int = 64 * 1024 * 1024,
                stripe_blocks: int = 256, keep: int = 2,
                ) -> "ZonedCheckpointStore":
        """Checkpoint store over a striped array of file-backed ZNS devices.

        Leaf payloads stripe across ``num_devices`` member files
        (``directory/member{i}.zns``) in ``stripe_blocks``-block chunks —
        save/restore bandwidth aggregates over every member, and a reopened
        store recovers the striped manifests exactly like the single-device
        path (the logical zone's write pointer distributes to the members).

        The array geometry is persisted to ``directory/array.json`` on first
        use and ADOPTED on reopen — a stale geometry would de-interleave
        member blocks in the wrong order and render every checkpoint
        unreadable, so the sidecar, not the arguments, is the truth for an
        existing store.
        """
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        sidecar = directory / "array.json"
        geometry = {
            "num_devices": num_devices, "num_zones": num_zones,
            "member_zone_bytes": member_zone_bytes,
            "stripe_blocks": stripe_blocks,
        }
        if sidecar.exists():
            geometry = json.loads(sidecar.read_text())
        else:
            sidecar.write_text(json.dumps(geometry))
        devices = [
            ZonedDevice(num_zones=geometry["num_zones"],
                        zone_bytes=geometry["member_zone_bytes"],
                        block_bytes=4096,
                        backing_file=directory / f"member{i}.zns")
            for i in range(geometry["num_devices"])
        ]
        array = StripedZoneArray(devices,
                                 stripe_blocks=geometry["stripe_blocks"])
        return cls(device=array, keep=keep)

    # --------------------------------------------------------------- write
    def save(self, step: int, tree: Any) -> dict:
        """Append a checkpoint; returns its manifest."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        zone_ids = self._pick_payload_zones()
        entries = []
        zi = 0
        crc = 0
        for path_, leaf in leaves:
            raw, dtype, shape = _leaf_to_bytes(leaf)
            crc = zlib.crc32(raw, crc)
            placed = False
            for attempt in range(len(zone_ids)):
                zid = zone_ids[(zi + attempt) % len(zone_ids)]
                z = self.device.zone(zid)
                nblocks = -(-len(raw) // self.device.block_bytes)
                if z.is_writable and nblocks <= z.remaining_blocks:
                    start = self.device.zone_append(zid, raw)
                    zi = (zi + attempt) % len(zone_ids)
                    entries.append({
                        "path": jax.tree_util.keystr(path_),
                        "zone": zid, "block": int(start),
                        "bytes": len(raw), "dtype": dtype,
                        "shape": list(shape),
                    })
                    placed = True
                    break
            if not placed:
                raise CheckpointError("no payload zone has room; raise num_zones")
        manifest = {
            "magic": MANIFEST_MAGIC, "step": int(step),
            "entries": entries, "crc32": crc,
            "treedef": str(treedef),
        }
        self._append_manifest(manifest)
        self._manifests.append(manifest)
        self.gc()
        return manifest

    def _append_manifest(self, manifest: dict) -> None:
        raw = json.dumps(manifest).encode()
        header = len(raw).to_bytes(8, "little") + hashlib.sha256(raw).digest()
        self.device.zone_append(0, header + raw)

    def _pick_payload_zones(self) -> list[int]:
        ids = [z.zone_id for z in self.device.zones[1:]
               if z.state in (ZoneState.EMPTY, ZoneState.OPEN)]
        if not ids:
            raise CheckpointError("no writable payload zones (GC needed)")
        # prefer empty zones so each generation owns whole zones
        ids.sort(key=lambda i: (self.device.zone(i).write_pointer, i))
        return ids

    # ---------------------------------------------------------------- read
    def _recover(self) -> None:
        """Scan the manifest zone for valid commit records (crash recovery).
        Covers both the live-device case and a file-backed reopen, where the
        zone metadata is volatile and the log is the truth."""
        self._manifests: list[dict] = []
        self._scan_raw_manifest_zone()

    def _scan_raw_manifest_zone(self) -> None:
        bb = self.device.block_bytes
        z = self.device.zone(0)
        # read every block that may contain manifests
        max_blocks = z.write_pointer if z.write_pointer else z.capacity_blocks
        if z.write_pointer == 0:
            z.write_pointer = z.capacity_blocks  # allow raw scan
            raw = self.device.read_blocks_view(0, 0, max_blocks or z.capacity_blocks)
            z.write_pointer = 0
        else:
            raw = self.device.read_blocks_view(0, 0, z.write_pointer)
        buf = raw.tobytes()    # the one copy: bytes for the header parser
        off = 0
        found_blocks = 0
        while off + 40 <= len(buf):
            ln = int.from_bytes(buf[off : off + 8], "little")
            if ln == 0 or ln > 64 * 1024 * 1024 or off + 40 + ln > len(buf):
                # skip to next block boundary
                off = ((off // bb) + 1) * bb
                if off >= len(buf):
                    break
                continue
            digest = buf[off + 8 : off + 40]
            body = buf[off + 40 : off + 40 + ln]
            if hashlib.sha256(body).digest() == digest:
                try:
                    m = json.loads(body)
                    if m.get("magic") == MANIFEST_MAGIC:
                        self._manifests.append(m)
                        found_blocks = -(-(off + 40 + ln) // bb)
                except json.JSONDecodeError:
                    pass
                off = ((off + 40 + ln + bb - 1) // bb) * bb
            else:
                off = ((off // bb) + 1) * bb
        if z.write_pointer == 0 and found_blocks:
            # restore the manifest zone's write pointer after a reopen
            z.write_pointer = found_blocks
            z.state = ZoneState.OPEN
        # restore payload zone write pointers from the surviving manifests
        for m in self._manifests:
            for e in m["entries"]:
                zid = e["zone"]
                zz = self.device.zone(zid)
                end = e["block"] + -(-e["bytes"] // bb)
                if end > zz.write_pointer:
                    zz.write_pointer = end
                    if zz.state == ZoneState.EMPTY:
                        zz.state = ZoneState.OPEN

    def latest_step(self) -> Optional[int]:
        return self._manifests[-1]["step"] if self._manifests else None

    def steps(self) -> list[int]:
        return [m["step"] for m in self._manifests]

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shardings: Any = None) -> Any:
        """Restore a checkpoint as a pytree.

        ``like`` supplies the treedef (e.g. abstract state); ``shardings``
        (optional NamedSharding tree) device_puts each leaf — restoring onto
        a *different* mesh than the one that wrote it (elastic scaling).
        """
        if not self._manifests:
            raise CheckpointError("no checkpoints found")
        manifest = self._manifests[-1] if step is None else next(
            (m for m in reversed(self._manifests) if m["step"] == step), None)
        if manifest is None:
            raise CheckpointError(f"step {step} not found; have {self.steps()}")
        arrays = []
        crc = 0
        for e in manifest["entries"]:
            nblocks = -(-e["bytes"] // self.device.block_bytes)
            raw = self.device.read_blocks_view(e["zone"], e["block"], nblocks)
            raw = raw.tobytes()[: e["bytes"]]    # one copy: leaf bytes
            crc = zlib.crc32(raw, crc)
            arrays.append(_leaf_from_bytes(raw, e["dtype"], tuple(e["shape"])))
        if crc != manifest["crc32"]:
            raise CheckpointError("payload checksum mismatch (torn checkpoint?)")
        if like is None:
            raise CheckpointError("restore requires `like` for the treedef")
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        if len(flat_like) != len(arrays):
            raise CheckpointError(
                f"leaf count mismatch: ckpt {len(arrays)} vs like {len(flat_like)}")
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    # ------------------------------------------------------------------ GC
    def gc(self) -> int:
        """Host-managed GC: drop all but the newest ``keep`` checkpoints and
        reset any payload zone no longer referenced (the ZNS reset story)."""
        if len(self._manifests) <= self.keep:
            return 0
        self._manifests = self._manifests[-self.keep:]
        live = {(e["zone"]) for m in self._manifests for e in m["entries"]}
        resets = 0
        for z in self.device.zones[1:]:
            if z.zone_id not in live and z.write_pointer > 0:
                self.device.reset_zone(z.zone_id)
                resets += 1
        return resets

    def flush(self) -> None:
        self.device.flush()
