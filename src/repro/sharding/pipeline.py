"""Opt-in pipeline parallelism: GPipe-style microbatch streaming.

Stages are laid out on a ``pipe`` mesh axis; each device holds one stage's
parameters (sharded on the leading stage dim). Microbatches stream through
the pipeline with ``jax.lax.ppermute`` ring transfers inside ``shard_map``;
the scan has the classic ``n_micro + n_stages - 1`` fill/drain schedule. The
production 512-chip mesh uses "pod" as outer data-parallel by default;
configuring ``("pipe", "data", "model")`` instead turns this on (e.g. for
cross-DCN pods where pipeline's point-to-point traffic beats all-reduce).

Bubble fraction = (S-1)/(S-1+M): callers pick n_micro >= 4x stages.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages - 1 + n_micro)


def pipeline_apply(
    stage_fn: Callable,                # (stage_params, x) -> x
    stage_params,                      # pytree, leaves [n_stages, ...]
    xs: jnp.ndarray,                   # [n_micro, micro_batch, ...]
    *,
    mesh: Mesh,
    axis_name: str = "pipe",
) -> jnp.ndarray:
    """Run ``n_stages`` sequential stages over ``n_micro`` microbatches.
    Returns [n_micro, micro_batch, ...] — identical to applying the stages
    sequentially (the test asserts this)."""
    n_stages = dict(mesh.shape)[axis_name]
    n_micro = xs.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def run(params, xs_local):
        stage = jax.lax.axis_index(axis_name)
        p = jax.tree.map(lambda a: a[0], params)       # this device's stage
        T = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs_local[0])              # inbound activation
        outs = jnp.zeros_like(xs_local)

        def step(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (while available); other stages
            # consume what arrived over the ring
            inject = xs_local[jnp.clip(t, 0, n_micro - 1)]
            x_in = jnp.where(stage == 0, inject, buf)
            y = stage_fn(p, x_in)
            # the LAST stage emits microbatch t-(S-1); everyone else forwards
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (out_idx < n_micro) & (
                stage == n_stages - 1)
            upd = jnp.where(valid, y, outs[jnp.clip(out_idx, 0, n_micro - 1)])
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, upd, jnp.clip(out_idx, 0, n_micro - 1), 0)
            buf = jax.lax.ppermute(y, axis_name, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(T))
        # only the last stage holds real outputs; broadcast over the ring
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis_name)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis_name), stage_params)
    if hasattr(jax, "shard_map"):                      # jax >= 0.6
        smap = jax.shard_map(
            run, mesh=mesh, in_specs=(spec_params, P()), out_specs=P(),
            check_vma=False)
    else:                                              # jax 0.4.x
        from jax.experimental.shard_map import shard_map
        smap = shard_map(
            run, mesh=mesh, in_specs=(spec_params, P()), out_specs=P(),
            check_rep=False)
    return jax.jit(smap)(stage_params, xs)
