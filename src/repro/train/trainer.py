"""Training loop: zone-fed batches -> jit train_step -> zoned checkpoints.

Fault-tolerance contract:
  * a run can be killed at ANY point; restarting with the same
    ``TrainerConfig`` resumes from the newest committed checkpoint and
    replays the data pipeline to the right position (batch index is part of
    the train state via `step`);
  * checkpoint writes are atomic (manifest-commit, see checkpoint.py), so a
    crash mid-save leaves the previous checkpoint live;
  * restore reshards onto whatever mesh the restart runs with (elastic).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models.params import abstract_params, init_params
from repro.train.checkpoint import ZonedCheckpointStore
from repro.train.step import TrainHyper, make_train_step, train_state_specs

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    seed: int = 0
    hyper: TrainHyper = field(default_factory=TrainHyper)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 store: Optional[ZonedCheckpointStore] = None,
                 mesh=None, state_shardings=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.store = store
        self.mesh = mesh
        self.state_shardings = state_shardings
        self.step_fn = jax.jit(make_train_step(cfg, tcfg.hyper),
                               in_shardings=(state_shardings, None)
                               if state_shardings else None,
                               out_shardings=(state_shardings, None)
                               if state_shardings else None)
        self.state = None
        self.history: list[dict] = []

    # ------------------------------------------------------------ lifecycle
    def init_or_resume(self) -> int:
        """Returns the step to start from."""
        specs = train_state_specs(self.cfg)
        if self.store is not None and self.store.latest_step() is not None:
            like = abstract_params(specs)
            self.state = self.store.restore(like=like,
                                            shardings=self.state_shardings)
            start = int(np.asarray(jax.device_get(self.state["step"])))
            return start
        self.state = init_params(specs, jax.random.PRNGKey(self.tcfg.seed))
        if self.state_shardings is not None:
            self.state = jax.device_put(self.state, self.state_shardings)
        return 0

    def save(self) -> None:
        if self.store is not None:
            step = int(np.asarray(jax.device_get(self.state["step"])))
            self.store.save(step, self.state)
            self.store.flush()

    # ----------------------------------------------------------------- run
    def run(self, batches: Iterable[dict],
            on_step: Optional[Callable[[int, dict], None]] = None) -> dict:
        start = self.init_or_resume()
        it = iter(batches)
        # replay the pipeline to the resume point (deterministic iterator)
        for _ in range(start):
            next(it)
        last_metrics: dict = {}
        for step in range(start, self.tcfg.total_steps):
            try:
                batch = next(it)
            except StopIteration:
                break
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(np.asarray(jax.device_get(v)))
                       for k, v in metrics.items()}
            metrics["step_seconds"] = time.perf_counter() - t0
            metrics["step"] = step
            self.history.append(metrics)
            last_metrics = metrics
            if on_step is not None:
                on_step(step, metrics)
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self.save()
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"[train] step={step + 1} loss={metrics.get('loss', 0):.4f} "
                      f"({metrics['step_seconds'] * 1e3:.0f} ms)")
        self.save()
        return last_metrics
