"""RAID-0-style zone striping over multiple ZNS devices.

The paper defers multi-device operation as future work; real CSD deployments
aggregate many devices behind one logical address space. A
:class:`StripedZoneArray` presents N identical :class:`~repro.zns.ZonedDevice`
members as ONE logical zoned device:

  * logical zone ``z`` is the union of member zone ``z`` on every device;
    its capacity is ``N x member_zone_blocks``;
  * the logical block stream is striped round-robin in *chunks* of
    ``stripe_blocks`` blocks: logical chunk ``k`` lives on device ``k % N``
    at member-local chunk ``k // N``;
  * appends and reads preserve ZNS semantics end-to-end — the logical write
    pointer is the sum of the member write pointers, member appends land
    exactly at each member's write pointer (a contiguous logical range maps
    to one contiguous member-local range per device), and the logical zone
    state machine is derived from the members'.

The class is a drop-in for ``ZonedDevice`` everywhere the repo consumes one
(``NvmCsd``, ``ZoneDataStore``, ``ZonedCheckpointStore``): a 1-member array
is the degenerate single-device path.
"""
from __future__ import annotations

import concurrent.futures
import threading
from typing import Callable, Optional, Sequence

import numpy as np

from repro.zns.device import (
    OutOfBoundsError,
    ZonedDevice,
    ZoneFullError,
    ZoneState,
    ZoneStateError,
    block_aligned_dtype,
    payload_as_uint8,
)
from repro.zns.ring import (
    CompletionBarrier,
    CompletionRing,
    IoFuture,
    in_reactor_thread,
)

__all__ = ["StripedZoneArray", "LogicalZone", "StripeChunk"]

# Gather-interleave memcpys for reactor-retired member reads run here, NOT on
# the reactor thread: the reactor must stay a pointer-moving completion pump
# (a pair of concurrent 64 MiB striped reads would otherwise serialize
# ~100 MiB of memcpy ahead of every other due completion in the process).
# Bounded and shared — threads scale with concurrent gathers in progress,
# never with in-flight transfers, so the ring model's claim stands.
_gather_pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
_gather_pool_lock = threading.Lock()


def _gather_executor() -> concurrent.futures.ThreadPoolExecutor:
    global _gather_pool
    with _gather_pool_lock:
        if _gather_pool is None:
            _gather_pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="stripe-gather")
        return _gather_pool


class StripeChunk:
    """One stripe chunk of a logical zone extent, in logical order.

    ``index`` is the global chunk index (logical order key), ``device`` the
    member device index, ``local_off``/``n_blocks`` the member-local extent.
    """

    __slots__ = ("index", "device", "local_off", "n_blocks", "logical_off")

    def __init__(self, index: int, device: int, local_off: int,
                 n_blocks: int, logical_off: int):
        self.index = index
        self.device = device
        self.local_off = local_off
        self.n_blocks = n_blocks
        self.logical_off = logical_off

    def __repr__(self) -> str:
        return (f"StripeChunk(#{self.index} dev{self.device} "
                f"local[{self.local_off},+{self.n_blocks}))")


class LogicalZone:
    """View of one logical (striped) zone.

    Duck-types the fields of :class:`repro.zns.device.Zone` that callers use:
    ``zone_id``, ``write_pointer`` (settable — distributes to members, needed
    by checkpoint recovery), ``state`` (derived; settable — broadcast),
    ``capacity_blocks``, ``remaining_blocks``, ``is_writable``,
    ``reset_count``.
    """

    def __init__(self, array: "StripedZoneArray", zone_id: int):
        self._array = array
        self.zone_id = zone_id

    def _members(self):
        return [d.zone(self.zone_id) for d in self._array.devices]

    @property
    def capacity_blocks(self) -> int:
        return self._array.zone_blocks

    @property
    def write_pointer(self) -> int:
        return sum(z.write_pointer for z in self._members())

    @write_pointer.setter
    def write_pointer(self, w: int) -> None:
        # Distribute a logical write pointer across members: member d owns
        # the logical blocks whose stripe chunk index is congruent to d.
        arr = self._array
        s, n = arr.stripe_blocks, arr.n_devices
        full_rows, rem = divmod(int(w), s * n)
        rem_chunks, partial = divmod(rem, s)
        for d, z in enumerate(self._members()):
            wp = full_rows * s
            if d < rem_chunks:
                wp += s
            elif d == rem_chunks:
                wp += partial
            z.write_pointer = wp

    @property
    def state(self) -> ZoneState:
        states = {z.state for z in self._members()}
        if ZoneState.OFFLINE in states:
            return ZoneState.OFFLINE
        if ZoneState.READ_ONLY in states:
            return ZoneState.READ_ONLY
        if states == {ZoneState.EMPTY}:
            return ZoneState.EMPTY
        if states == {ZoneState.FULL}:
            return ZoneState.FULL
        return ZoneState.OPEN

    @state.setter
    def state(self, st: ZoneState) -> None:
        for z in self._members():
            z.state = st

    @property
    def reset_count(self) -> int:
        return max(z.reset_count for z in self._members())

    @property
    def remaining_blocks(self) -> int:
        return self.capacity_blocks - self.write_pointer

    @property
    def is_writable(self) -> bool:
        return self.state in (ZoneState.EMPTY, ZoneState.OPEN)

    def __repr__(self) -> str:
        return (f"LogicalZone(id={self.zone_id}, wp={self.write_pointer}/"
                f"{self.capacity_blocks}, state={self.state.value})")


class StripedZoneArray:
    """N identical ZNS devices presented as one logical zoned device."""

    def __init__(self, devices: Sequence[ZonedDevice], *, stripe_blocks: int = 16):
        if not devices:
            raise ValueError("StripedZoneArray needs at least one device")
        d0 = devices[0]
        for i, d in enumerate(devices):
            if (d.num_zones, d.zone_blocks, d.block_bytes) != (
                    d0.num_zones, d0.zone_blocks, d0.block_bytes):
                raise ValueError(
                    f"member {i} geometry {(d.num_zones, d.zone_blocks, d.block_bytes)} "
                    f"differs from member 0 {(d0.num_zones, d0.zone_blocks, d0.block_bytes)}"
                )
        if stripe_blocks <= 0:
            raise ValueError("stripe_blocks must be positive")
        if d0.zone_blocks % stripe_blocks != 0:
            raise ValueError(
                f"stripe_blocks {stripe_blocks} must divide member zone size "
                f"{d0.zone_blocks} (chunks may not straddle member zones)"
            )
        self.devices = list(devices)
        self.n_devices = len(self.devices)
        self.stripe_blocks = int(stripe_blocks)
        self.num_zones = d0.num_zones
        self.block_bytes = d0.block_bytes
        # logical geometry: every member contributes its whole zone
        self.zone_blocks = d0.zone_blocks * self.n_devices
        self.zone_bytes = self.zone_blocks * self.block_bytes
        self._lock = threading.RLock()
        # member transfers fan out as in-flight completion-ring descriptors
        # (repro.zns.ring): an N-member read holds N reactor slots and ZERO
        # worker threads, and CONCURRENT logical reads (different zones /
        # tenants) overlap on the members' per-zone virtual clocks instead of
        # queuing behind a thread-pool's size.
        self.zones = [LogicalZone(self, z) for z in range(self.num_zones)]
        # array-level host-copy accounting (member counters only see their
        # own transfers; the stripe gather-copy happens here)
        self._gather_bytes_copied = 0

    # -------------------------------------------------------- address math
    def block_location(self, block: int) -> tuple[int, int]:
        """Logical block -> (device index, member-local block)."""
        s, n = self.stripe_blocks, self.n_devices
        chunk, within = divmod(block, s)
        return chunk % n, (chunk // n) * s + within

    def chunks(self, zone_id: int, block_off: int, n_blocks: int) -> list[StripeChunk]:
        """Decompose a logical extent into stripe chunks, in logical order.

        Each chunk is contiguous both logically and on its member device —
        the unit the offload scheduler fans out.
        """
        self.zone(zone_id)  # bounds-check the zone id
        s = self.stripe_blocks
        out: list[StripeChunk] = []
        b, end = block_off, block_off + n_blocks
        while b < end:
            chunk = b // s
            take = min(end - b, (chunk + 1) * s - b)
            dev, local = self.block_location(b)
            out.append(StripeChunk(chunk, dev, local, take, b))
            b += take
        return out

    # ------------------------------------------------------------- zones
    def zone(self, zone_id: int) -> LogicalZone:
        if not 0 <= zone_id < self.num_zones:
            raise OutOfBoundsError(f"zone {zone_id} out of range [0,{self.num_zones})")
        return self.zones[zone_id]

    def report_zones(self) -> list[LogicalZone]:
        return list(self.zones)

    def open_zones(self) -> list[LogicalZone]:
        return [z for z in self.zones if z.state == ZoneState.OPEN]

    # ------------------------------------------------------------- append
    def zone_append(self, zone_id: int, data: np.ndarray | bytes) -> int:
        """Striped Zone Append: split ``data`` into stripe chunks and append
        each member's share at that member's write pointer. Returns the
        logical start block. Synchronous shim over :meth:`submit_append` —
        member transfers share one wall-clock window (each member's emulated
        busy time runs on its own zone clock), the call returns at the last
        member's completion deadline."""
        return self.submit_append(zone_id, data).result()

    def submit_append(self, zone_id: int, data: np.ndarray | bytes, *,
                      ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous striped Zone Append: member writes land immediately
        (metadata and bytes, under the array lock), the returned future
        retires when the LAST member completion does, with the logical start
        block as its value. ``fut.submitted_block`` carries the logical start
        synchronously."""
        raw = payload_as_uint8(data)
        nblocks = -(-raw.size // self.block_bytes)  # ceil
        member_futs: list[IoFuture] = []
        with self._lock:
            z = self.zone(zone_id)
            if not z.is_writable:
                raise ZoneStateError(
                    f"logical zone {zone_id} not writable (state={z.state})")
            start = z.write_pointer
            if nblocks > z.remaining_blocks:
                raise ZoneFullError(
                    f"append of {nblocks} blocks exceeds logical zone {zone_id} "
                    f"remaining {z.remaining_blocks}"
                )
            padded = np.zeros(nblocks * self.block_bytes, np.uint8)
            padded[: raw.size] = raw
            blocks = padded.reshape(nblocks, self.block_bytes)
            owner = ((np.arange(start, start + nblocks) // self.stripe_blocks)
                     % self.n_devices)
            for d, dev in enumerate(self.devices):
                share = blocks[owner == d]
                if share.size == 0:
                    continue
                # member-local target is contiguous and starts at the member
                # write pointer (appends only ever go through the array)
                f = dev.submit_append(zone_id, share)
                expect = self.block_location(
                    int(np.flatnonzero(owner == d)[0]) + start)[1]
                if f.submitted_block != expect:
                    raise ZoneStateError(
                        f"stripe desync on device {d} zone {zone_id}: member "
                        f"append landed at {f.submitted_block}, expected {expect}"
                    )
                member_futs.append(f)

        agg = IoFuture(op="append", zone_id=zone_id, block_off=start,
                       nblocks=nblocks,
                       service_seconds=max(
                           (f.service_seconds for f in member_futs),
                           default=0.0),
                       ring=ring)
        agg.submitted_block = start
        self._join_members(agg, member_futs, lambda: start)
        return agg

    @staticmethod
    def _join_members(agg: IoFuture, member_futs: list[IoFuture],
                      finalize: Callable[[], object]) -> None:
        """Retire ``agg`` with ``finalize()`` (or the first member error) once
        every member future has retired. Members that completed inline fire
        their callback inline, so a fully-inline fan-out retires ``agg``
        before this returns (including the zero-member case)."""
        barrier = CompletionBarrier(
            len(member_futs),
            lambda _vals, err: agg.fail(err) if err is not None
            else agg.complete(finalize()))
        for i, f in enumerate(member_futs):
            f.add_done_callback(lambda f, i=i: barrier.settle(i, f.error))

    # --------------------------------------------------------------- read
    def read_blocks(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Striped read: one contiguous member read per device, interleaved
        back into logical order.

        Only the bounds check and address math run under the array lock;
        member transfers (and their emulated bandwidth time) ride the
        completion ring, so concurrent array-level reads — different zones,
        different tenants — overlap instead of queuing behind one logical
        read or a worker-pool's thread count. Safe
        against concurrent appends because the logical write pointer only
        covers member blocks whose appends have fully landed (appends update
        it last, under this lock). Resetting + rewriting a zone while a read
        of it is in flight is a host protocol bug (same contract as
        ``ZonedDevice.read_blocks_view``, and as real ZNS hardware).
        """
        out = self.submit_read(zone_id, block_off, nblocks).result()
        out = np.asarray(out)
        out = out.view()               # the gather buffer is private: hand the
        out.flags.writeable = True     # sync caller an owned, mutable stream
        return out

    def submit_read(self, zone_id: int, block_off: int, nblocks: int, *,
                    dtype: Optional[np.dtype | str] = None,
                    ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous striped read: one in-flight member transfer per
        device, each gathered into logical stripe order as its completion
        retires; the returned future retires with the last member's, valued
        as the read-only interleaved extent (``dtype``-typed when given).

        Member transfers ride the completion ring, so a fan-out across N
        members consumes N in-flight reactor slots and ZERO worker threads —
        array concurrency is bounded by the emulated devices' zone clocks,
        not by a pool size.
        """
        if dtype is not None:
            dtype = block_aligned_dtype(self.block_bytes, dtype)
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.OFFLINE:
                raise ZoneStateError(f"logical zone {zone_id} is offline")
            if block_off < 0 or nblocks < 0 or block_off + nblocks > z.write_pointer:
                raise OutOfBoundsError(
                    f"read [{block_off},{block_off + nblocks}) beyond write pointer "
                    f"{z.write_pointer} of logical zone {zone_id}"
                )
        agg = IoFuture(op="read", zone_id=zone_id, block_off=block_off,
                       nblocks=nblocks, ring=ring)
        out = np.empty((nblocks, self.block_bytes), np.uint8)

        def finalize():
            with self._lock:
                self._gather_bytes_copied += out.nbytes
            flat = out.reshape(-1)
            if dtype is not None:
                flat = flat.view(dtype)
            flat.flags.writeable = False
            return flat

        if nblocks == 0:
            agg.complete(finalize())
            return agg
        bidx = np.arange(block_off, block_off + nblocks)
        chunk = bidx // self.stripe_blocks
        owner = chunk % self.n_devices
        local = (chunk // self.n_devices) * self.stripe_blocks \
            + bidx % self.stripe_blocks

        member_work: list[tuple[IoFuture, np.ndarray]] = []
        for d, dev in enumerate(self.devices):
            sel = owner == d
            if not sel.any():
                continue
            lsel = local[sel]
            member_work.append(
                (dev.submit_read(zone_id, int(lsel[0]), int(lsel.size)), sel))
        agg.service_seconds = max(f.service_seconds for f, _ in member_work)
        barrier = CompletionBarrier(
            len(member_work),
            lambda _vals, err: agg.fail(err) if err is not None
            else agg.complete(finalize()))
        # Member completions firing inline (the non-emulated fast path) copy
        # right on the submitting thread; completions retired by a reactor
        # pump hand their copy to the gather pool — detected by thread, not
        # by submission phase, so the pump NEVER memcpys even when a short
        # emulated transfer retires mid-registration.
        def on_member(f: IoFuture, sel: np.ndarray, i: int) -> None:
            def gather_share() -> None:
                # member view -> interleave copy at completion time: ONE
                # host-side copy total per byte (the stripe gather IS the
                # one unavoidable copy on the array path)
                if f.error is None:
                    out[sel] = f.value.reshape(-1, self.block_bytes)
                barrier.settle(i, f.error)
            if in_reactor_thread():
                _gather_executor().submit(gather_share)
            else:
                gather_share()

        for i, (f, sel) in enumerate(member_work):
            f.add_done_callback(lambda f, sel=sel, i=i: on_member(f, sel, i))
        return agg

    def read_blocks_view(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Minimal-copy read for the ``ZonedDevice`` view contract: a striped
        extent is not contiguous in any member buffer, so the stripe gather
        into logical order IS the single unavoidable copy."""
        out = self.read_blocks(zone_id, block_off, nblocks)
        out.flags.writeable = False
        return out

    def read_extent(self, zone_id: int, block_off: int, nblocks: int,
                    dtype: np.dtype | str) -> np.ndarray:
        """Dtype-typed minimal-copy read (one gather copy; the reinterpreting
        view is free — block alignment exceeds any element alignment)."""
        dtype = block_aligned_dtype(self.block_bytes, dtype)
        return self.read_blocks_view(zone_id, block_off, nblocks).view(dtype)

    def read_zone(self, zone_id: int) -> np.ndarray:
        return self.read_blocks(zone_id, 0, self.zone(zone_id).write_pointer)

    # ---------------------------------------------------- zone management
    def finish_zone(self, zone_id: int) -> None:
        for dev in self.devices:
            dev.finish_zone(zone_id)

    def set_read_only(self, zone_id: int) -> None:
        for dev in self.devices:
            dev.set_read_only(zone_id)

    def reset_zone(self, zone_id: int) -> None:
        with self._lock:
            if self.zone(zone_id).state == ZoneState.OFFLINE:
                raise ZoneStateError(f"logical zone {zone_id} is offline")
            for dev in self.devices:
                dev.reset_zone(zone_id)

    def set_offline(self, zone_id: int, *, device: Optional[int] = None) -> None:
        """Fault injection: kill the zone on one member (``device``) or all."""
        targets = self.devices if device is None else [self.devices[device]]
        for dev in targets:
            dev.set_offline(zone_id)

    # --------------------------------------------------------------- misc
    def flush(self) -> None:
        for dev in self.devices:
            dev.flush()

    def close(self) -> None:
        """Kept for API compatibility: member I/O rides the shared completion
        ring now, so the array holds no worker threads to release."""

    def __enter__(self) -> "StripedZoneArray":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def lba_size(self) -> int:
        return self.block_bytes

    @property
    def stats(self) -> dict:
        """Aggregate member device statistics (NVMe log-page analogue), plus
        the array-level stripe gather copies."""
        agg: dict[str, int] = {}
        for dev in self.devices:
            for k, v in dev.stats.items():
                agg[k] = agg.get(k, 0) + v
        agg["bytes_copied"] = agg.get("bytes_copied", 0) + self._gather_bytes_copied
        return agg

    def utilization(self) -> float:
        written = sum(z.write_pointer for z in self.zones)
        return written / float(self.num_zones * self.zone_blocks)
