from repro.sharding.rules import (
    Rules,
    TRAIN_RULES,
    SERVE_RULES,
    rules_for,
    logical_to_spec,
    named_sharding_for,
    param_shardings,
    shard_act,
    use_param,
    use_rules,
    current_rules,
)

__all__ = [
    "Rules", "TRAIN_RULES", "SERVE_RULES", "rules_for", "logical_to_spec",
    "named_sharding_for", "param_shardings", "shard_act", "use_param",
    "use_rules", "current_rules",
]
