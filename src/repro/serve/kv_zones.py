"""Zoned KV-cache manager: the ZNS abstraction applied to serving.

A KV cache is append-only storage: each decode step appends one token's K/V
and nothing is ever updated in place — exactly the write model ZNS zones
mandate. The manager maps sequences onto fixed-size KV zones from a shared
pool (HBM analogue of the device's zone pool):

  * a sequence owns an ordered list of zones (its "zone table" row);
  * appending K/V advances the active zone's write pointer; when full, a new
    zone is allocated (zone transition EMPTY -> OPEN -> FULL);
  * evicting a sequence = host-managed ``reset`` of its zones back to the
    pool (the paper's GC primitive — no device-side GC ever moves data);
  * attention over a sequence's history is computed by the paged Pallas
    kernel directly against the zone pool (repro.kernels.paged_attn).

This gives serving the same fragmentation-free, explicitly-managed memory
model vLLM gets from PagedAttention, derived here from ZNS semantics.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attn.ops import paged_attention
from repro.telemetry.metrics import MetricsRegistry, StatsView

_POOL_SEQ = itertools.count()

__all__ = ["KVZonePool", "KVZoneError"]


class KVZoneError(Exception):
    pass


@dataclass
class _SeqState:
    zones: list[int] = field(default_factory=list)
    length: int = 0


class KVZonePool:
    """num_zones zones of zone_len tokens each, [KV, head_dim] per token."""

    def __init__(self, *, num_zones: int, zone_len: int, kv_heads: int,
                 head_dim: int, max_zones_per_seq: int,
                 dtype=jnp.bfloat16):
        self.num_zones = num_zones
        self.zone_len = zone_len
        self.max_zones_per_seq = max_zones_per_seq
        self.k = jnp.zeros((num_zones, zone_len, kv_heads, head_dim), dtype)
        self.v = jnp.zeros((num_zones, zone_len, kv_heads, head_dim), dtype)
        self._free = list(range(num_zones))
        self._seqs: dict[int, _SeqState] = {}
        # pool counters on a private registry (pools are unbounded);
        # `stats` keeps its dict shape as a live view
        self.metrics = MetricsRegistry(f"kvpool{next(_POOL_SEQ)}")
        self._c_alloc = self.metrics.counter("zones_allocated")
        self._c_reset = self.metrics.counter("zones_reset")
        self._c_tokens = self.metrics.counter("tokens_appended")
        self.stats = StatsView({"zones_allocated": self._c_alloc,
                                "zones_reset": self._c_reset,
                                "tokens_appended": self._c_tokens})

    # ---------------------------------------------------------- lifecycle
    def add_sequence(self, seq_id: int) -> None:
        if seq_id in self._seqs:
            raise KVZoneError(f"sequence {seq_id} exists")
        self._seqs[seq_id] = _SeqState()

    def evict(self, seq_id: int) -> None:
        """Host-managed GC: reset the sequence's zones back to the pool."""
        st = self._seqs.pop(seq_id, None)
        if st is None:
            return
        for z in st.zones:
            self._free.append(z)
        self._c_reset.inc(len(st.zones))

    def _alloc_zone(self, st: _SeqState) -> int:
        if len(st.zones) >= self.max_zones_per_seq:
            raise KVZoneError("sequence exceeds max_zones_per_seq")
        if not self._free:
            raise KVZoneError("zone pool exhausted (evict something)")
        z = self._free.pop(0)
        st.zones.append(z)
        self._c_alloc.inc()
        return z

    # ------------------------------------------------------------- append
    def append(self, seq_id: int, k_tok: jnp.ndarray, v_tok: jnp.ndarray):
        """Append one token's K/V ([KV, head_dim]) — the Zone Append."""
        st = self._seqs[seq_id]
        slot = st.length % self.zone_len
        if slot == 0:
            self._alloc_zone(st)
        z = st.zones[-1]
        self.k = self.k.at[z, slot].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[z, slot].set(v_tok.astype(self.v.dtype))
        st.length += 1
        self._c_tokens.inc()

    # ---------------------------------------------------------- attention
    def zone_table(self, seq_ids: list[int]) -> tuple[jnp.ndarray, jnp.ndarray]:
        tab = np.full((len(seq_ids), self.max_zones_per_seq), -1, np.int32)
        lengths = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            st = self._seqs[sid]
            tab[i, : len(st.zones)] = st.zones
            lengths[i] = st.length
        return jnp.asarray(tab), jnp.asarray(lengths)

    def attend(self, seq_ids: list[int], q: jnp.ndarray, *,
               interpret: bool = True) -> jnp.ndarray:
        """q: [B, H, head_dim] (B == len(seq_ids)). Flash-decode over the
        zone pool via the Pallas kernel."""
        tab, lengths = self.zone_table(seq_ids)
        return paged_attention(q, self.k, self.v, tab, lengths,
                               interpret=interpret)

    def utilization(self) -> float:
        used = self.num_zones - len(self._free)
        return used / self.num_zones
