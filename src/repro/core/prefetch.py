"""Read/compute overlap primitives for the offload hot path.

In-storage processing wins come from overlapping I/O with compute
(arXiv:2112.12415): while the execution tier crunches extent chunk ``k``, the
next chunk's device transfer should already be in flight. Three shapes of
that pattern live here:

  * :class:`RingReader` — the completion-ring shape and the default: a
    sequential page reader that keeps ``depth`` ``submit_read`` futures in
    flight on the device's reactor. No producer thread at all — the emulated
    transfer of page ``p+depth`` elapses on the zone's virtual clock while
    the interpreter crunches page ``p``, and ONE reactor thread drives every
    reader in the process;
  * :func:`prefetched` — a double-buffered iterator over work items whose
    ``fetch`` runs ``depth`` items ahead on an executor (generic: any fetch
    callable, not just ring-capable devices);
  * :class:`LookaheadReader` — the pre-ring thread-backed page reader kept
    for fetch callables that are not ring-backed; each instance burns a
    producer thread, which is exactly what the ring model removes.

Overlap only helps because the device models transfer time on per-zone
virtual-time queues rather than under its metadata lock (see
``ZonedDevice._claim_slot``) — against a device that serializes every
transfer, lookahead buys nothing.
"""
from __future__ import annotations

import concurrent.futures
import queue
import threading
import time
from collections import deque
from typing import TYPE_CHECKING, Callable, Iterator, Optional, Sequence, TypeVar

if TYPE_CHECKING:
    from repro.zns.ring import IoFuture

__all__ = ["RingReader", "prefetched", "LookaheadReader"]

T = TypeVar("T")
R = TypeVar("R")


class RingReader:
    """Sequential ``read_page(p)`` drop-in backed by completion-ring futures.

    ``submit(p)`` must return an :class:`~repro.zns.ring.IoFuture` (e.g. a
    bound ``device.submit_read``). The reader eagerly submits the first
    ``depth`` pages — claiming their slots on the zone's virtual-time queue —
    and tops the window back up as the consumer advances, so page ``p+depth``
    is always in flight while page ``p`` is being consumed.

    ``read_seconds`` accumulates the *emulated service time* of consumed
    pages (``IoFuture.service_seconds``) — the device-transfer time the
    overlap hides, same meaning the thread-backed reader reported.
    """

    def __init__(self, submit: Callable[[int], "IoFuture"], n_items: int, *,
                 depth: int = 2):
        self._submit = submit
        self.n_items = int(n_items)
        self._depth = max(int(depth), 1)
        self._futs: deque["IoFuture"] = deque()
        self._submitted = 0
        self._next = 0
        self.read_seconds = 0.0
        for _ in range(min(self._depth, self.n_items)):
            self._submit_next()

    def _submit_next(self) -> None:
        self._futs.append(self._submit(self._submitted))
        self._submitted += 1

    def __call__(self, p: int):
        if p != self._next:
            raise ValueError(
                f"RingReader is sequential: expected page {self._next}, "
                f"got {p}")
        self._next += 1
        fut = self._futs.popleft()
        if self._submitted < self.n_items:
            self._submit_next()
        value = fut.result()
        self.read_seconds += fut.service_seconds
        return value

    def close(self) -> None:
        """Abandoned in-flight futures just retire on the reactor (reads are
        side-effect-free); nothing to release."""
        self._futs.clear()

    def __enter__(self) -> "RingReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def prefetched(
    items: Sequence[T],
    fetch: Callable[[T], R],
    *,
    executor: Optional[concurrent.futures.Executor] = None,
    depth: int = 2,
) -> Iterator[R]:
    """Yield ``fetch(item)`` for each item in order, keeping up to ``depth``
    fetches in flight on ``executor`` while the caller consumes earlier
    results. With no executor (or depth < 1) degrades to sequential fetching.

    The first ``depth`` fetches are submitted EAGERLY (at call time, not at
    the first ``next()``), so device reads start while the caller is still
    setting up — e.g. paying a compile-cache miss. Abandoning the iterator
    early leaves in-flight fetches to complete on the executor (reads are
    side-effect-free); exceptions from ``fetch`` surface at the
    corresponding ``next()``.
    """
    items = list(items)
    if executor is None or depth < 1 or len(items) <= 1:
        def _sequential() -> Iterator[R]:
            for it in items:
                yield fetch(it)
        return _sequential()

    futs: deque = deque(executor.submit(fetch, it) for it in items[:depth])

    def _overlapped() -> Iterator[R]:
        for j in range(len(items)):
            value = futs.popleft().result()
            nxt = j + depth
            if nxt < len(items):
                futs.append(executor.submit(fetch, items[nxt]))
            yield value

    return _overlapped()


class LookaheadReader:
    """Sequential ``read_page(p)`` drop-in that streams pages through a
    bounded lookahead queue filled by a background producer thread.

    The interp tier consumes pages strictly in order, so the producer simply
    runs ``fetch(0..n_items)`` ahead of the consumer, at most ``depth`` pages
    in flight. ``read_seconds`` accumulates the producer's time inside
    ``fetch`` — the device transfer time the overlap hides.
    """

    def __init__(self, fetch: Callable[[int], R], n_items: int, *,
                 depth: int = 2):
        self._fetch = fetch
        self.n_items = int(n_items)
        self._q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._next = 0
        self.read_seconds = 0.0
        self._thread = threading.Thread(
            target=self._produce, name="page-lookahead", daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        for p in range(self.n_items):
            if self._stop.is_set():
                return
            try:
                t0 = time.perf_counter()
                item = (p, self._fetch(p), None)
                self.read_seconds += time.perf_counter() - t0
            except BaseException as e:  # delivered at the consumer's read
                item = (p, None, e)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.05)
                    break
                except queue.Full:
                    continue
            if item[2] is not None:
                return

    def __call__(self, p: int) -> R:
        if p != self._next:
            raise ValueError(
                f"LookaheadReader is sequential: expected page {self._next}, "
                f"got {p}")
        self._next += 1
        idx, value, err = self._q.get()
        assert idx == p
        if err is not None:
            raise err
        return value

    def close(self) -> None:
        """Release the producer (safe after partial consumption)."""
        self._stop.set()
        while True:  # unblock a producer waiting on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "LookaheadReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
