"""SMART-style health monitoring per array member.

An NVMe device exposes a SMART / Health Information log page (error counts,
media wear, composite temperature) that fleet tooling polls to decide when a
drive is *about* to fail; a ZFS-style array manager layers pool health on
top (ONLINE / DEGRADED / FAULTED per vdev). This module is that consumer
side for the emulated ZNS fleet — PR 6 built the telemetry *producers*
(per-device counters and latency histograms); a :class:`DeviceHealthMonitor`
turns them into an operator verdict:

  * **error counters** — read/append protocol+media errors and zone
    READ_ONLY / OFFLINE transition counts, read straight off the device's
    private :class:`~repro.telemetry.metrics.MetricsRegistry`;
  * **latency outlier detection** — an EWMA baseline of the mean emulated
    read/append latency per sampling window, with a deviation threshold
    (``outlier_factor``): a window whose mean exceeds ``factor x baseline``
    increments ``latency_outliers`` and publishes a ``health.latency_outlier``
    event (the drive-is-slowing signal SMART vendors encode as attribute
    thresholds);
  * **composite status** — HEALTHY / SUSPECT / DEGRADED / OFFLINE per
    device, recomputed each :meth:`sample`; every transition publishes a
    ``health.status`` event carrying the from/to pair, so the event log
    shows the escalation path a human would have watched.

Status semantics (deterministic, threshold-documented):

  * ``OFFLINE``  — every zone of the member is OFFLINE (the device is gone);
  * ``DEGRADED`` — the OFFLINE-zone fraction reached
    ``degraded_zone_fraction`` (default 0.5), or the window error rate
    (errors / I/O ops) reached ``error_rate_threshold``;
  * ``SUSPECT``  — anything visibly wrong short of that: any OFFLINE or
    READ_ONLY zone, any window errors, or a latency outlier within the last
    ``suspect_memory_windows`` samples;
  * ``HEALTHY``  — none of the above.

:meth:`smart_log` returns the whole picture as one dict (the log-page
analogue); :meth:`register_on` folds the numeric subset into a registry
snapshot as a collector. :class:`ArrayHealthMonitor` runs one monitor per
array member — the input the alert engine's SUSPECT→DEGRADED promotion rule
(and the ROADMAP's future spare-promotion loop) consumes.

The module deliberately duck-types the device (``.metrics``,
``.report_zones()``, ``.dev_ordinal``) instead of importing
:mod:`repro.zns.device` — the device imports the telemetry package, so a
typed import here would be circular. Zone states compare by their ``.value``
strings for the same reason.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Optional

from .events import EventLog, Severity, event_log
from .metrics import MetricsRegistry

__all__ = ["HealthStatus", "DeviceHealthMonitor", "ArrayHealthMonitor"]


class HealthStatus(enum.IntEnum):
    """Composite member verdict; ordered so ``>=`` severity tests work."""

    HEALTHY = 0
    SUSPECT = 1
    DEGRADED = 2
    OFFLINE = 3


# scrub_mismatches: parity/mirror inconsistencies the background scrub
# (repro.array.rebuild) charged to this device — silent corruption counts
# as a media error for classification, same as an explicit read error
_ERROR_KEYS = ("read_errors", "append_errors", "scrub_mismatches")
_OPS_KEYS = ("blocks_read", "blocks_appended")
# Soft fault signals: retries the datapath ABSORBED and per-op timeouts.
# They classify a member SUSPECT (a retry storm pages before a hard
# failure does) but never DEGRADED — only exhausted retry budgets land in
# read_errors/append_errors and trigger ejection/rebuild.
_SOFT_KEYS = ("retries", "io_timeouts")


class DeviceHealthMonitor:
    """SMART-log consumer for one emulated ZNS device.

    Call :meth:`sample` periodically (the alert engine's interval, a
    dashboard refresh, or explicitly in tests/benchmarks); each call reads
    the device's metrics registry and zone report, updates the EWMA latency
    baselines, and recomputes the composite status. All state transitions
    publish into ``events`` (the global log by default).
    """

    def __init__(
        self,
        device,
        *,
        ewma_alpha: float = 0.3,
        outlier_factor: float = 4.0,
        min_baseline_windows: int = 3,
        suspect_memory_windows: int = 3,
        degraded_zone_fraction: float = 0.5,
        error_rate_threshold: float = 0.01,
        events: Optional[EventLog] = None,
        name: Optional[str] = None,
    ):
        self.device = device
        self.ewma_alpha = float(ewma_alpha)
        self.outlier_factor = float(outlier_factor)
        self.min_baseline_windows = int(min_baseline_windows)
        self.suspect_memory_windows = int(suspect_memory_windows)
        self.degraded_zone_fraction = float(degraded_zone_fraction)
        self.error_rate_threshold = float(error_rate_threshold)
        self.events = events if events is not None else event_log()
        self.name = name or f"dev{getattr(device, 'dev_ordinal', '?')}"
        self._lock = threading.Lock()
        self._t_created = time.monotonic()
        self._prev_snap: dict = {}
        # per-op EWMA state: baseline mean seconds + windows folded in
        self._ewma = {"read": 0.0, "append": 0.0}
        self._ewma_n = {"read": 0, "append": 0}
        self._windows = 0
        self._last_outlier_window = -10**9
        self.latency_outliers = 0
        self._status = HealthStatus.HEALTHY
        # last-window deltas, kept for smart_log / debugging
        self._win_errors = 0
        self._win_ops = 0
        self._win_soft = 0

    # ------------------------------------------------------------ sampling
    def _zone_counts(self) -> tuple[int, int, int]:
        zones = self.device.report_zones()
        off = sum(1 for z in zones if z.state.value == "offline")
        ro = sum(1 for z in zones if z.state.value == "read_only")
        return len(zones), off, ro

    def _update_ewma(self, op: str, snap: dict, prev: dict) -> bool:
        """Fold one window of ``<op>.service_seconds`` into the EWMA
        baseline; True when the window is an outlier against a warm
        baseline."""
        count = snap.get(f"{op}.service_seconds.count", 0) - \
            prev.get(f"{op}.service_seconds.count", 0)
        total = snap.get(f"{op}.service_seconds.sum", 0.0) - \
            prev.get(f"{op}.service_seconds.sum", 0.0)
        if count <= 0:
            return False            # idle window: baseline unchanged
        mean = total / count
        base = self._ewma[op]
        warm = self._ewma_n[op] >= self.min_baseline_windows
        outlier = warm and base > 0 and mean > self.outlier_factor * base
        if not outlier:
            # outlier windows are excluded from the baseline — a sick device
            # must not teach the monitor that sick is normal
            self._ewma[op] = mean if self._ewma_n[op] == 0 else \
                (1 - self.ewma_alpha) * base + self.ewma_alpha * mean
            self._ewma_n[op] += 1
        return outlier

    def sample(self) -> HealthStatus:
        """Read the device, update baselines, recompute + publish status."""
        with self._lock:
            snap = self.device.metrics.snapshot()
            prev, self._prev_snap = self._prev_snap, snap
            self._windows += 1
            outlier = False
            for op in ("read", "append"):
                if self._update_ewma(op, snap, prev):
                    outlier = True
            if outlier:
                self.latency_outliers += 1
                self._last_outlier_window = self._windows
            self._win_errors = sum(
                snap.get(k, 0) - prev.get(k, 0) for k in _ERROR_KEYS)
            self._win_ops = sum(
                snap.get(k, 0) - prev.get(k, 0) for k in _OPS_KEYS)
            self._win_soft = sum(
                snap.get(k, 0) - prev.get(k, 0) for k in _SOFT_KEYS)
            n_zones, off, ro = self._zone_counts()
            status = self._classify(n_zones, off, ro, outlier)
            prev_status, self._status = self._status, status
        if outlier:
            self.events.publish(
                "health.latency_outlier", severity=Severity.WARNING,
                message=f"{self.name}: window latency exceeded "
                        f"{self.outlier_factor:g}x EWMA baseline",
                device=self.name)
        if status is not prev_status:
            sev = Severity.INFO if status is HealthStatus.HEALTHY else (
                Severity.WARNING if status is HealthStatus.SUSPECT
                else Severity.ERROR)
            self.events.publish(
                "health.status", severity=sev,
                message=f"{self.name}: {prev_status.name} -> {status.name}",
                device=self.name, from_status=prev_status.name,
                to_status=status.name)
        return status

    def _classify(self, n_zones: int, off: int, ro: int,
                  outlier: bool) -> HealthStatus:
        if n_zones and off == n_zones:
            return HealthStatus.OFFLINE
        error_rate = self._win_errors / self._win_ops \
            if self._win_ops > 0 else (1.0 if self._win_errors else 0.0)
        if (n_zones and off / n_zones >= self.degraded_zone_fraction) or \
                (self._win_errors and
                 error_rate >= self.error_rate_threshold):
            return HealthStatus.DEGRADED
        recent_outlier = outlier or (
            self._windows - self._last_outlier_window
            < self.suspect_memory_windows)
        if off or ro or self._win_errors or self._win_soft or recent_outlier:
            return HealthStatus.SUSPECT
        return HealthStatus.HEALTHY

    # ------------------------------------------------------------- reports
    @property
    def status(self) -> HealthStatus:
        """Last sampled status (HEALTHY before the first :meth:`sample`)."""
        return self._status

    def smart_log(self) -> dict:
        """The NVMe SMART / Health Information log-page analogue: one dict
        with the composite status, raw counters, zone-state census, latency
        baselines and outlier counts."""
        with self._lock:
            snap = self.device.metrics.snapshot()
            n_zones, off, ro = self._zone_counts()
            return {
                "device": self.name,
                "status": self._status.name,
                "status_code": int(self._status),
                "power_on_seconds": time.monotonic() - self._t_created,
                "blocks_read": snap.get("blocks_read", 0),
                "blocks_appended": snap.get("blocks_appended", 0),
                "read_errors": snap.get("read_errors", 0),
                "append_errors": snap.get("append_errors", 0),
                "scrub_mismatches": snap.get("scrub_mismatches", 0),
                "media_errors": sum(snap.get(k, 0) for k in _ERROR_KEYS),
                "retries": snap.get("retries", 0),
                "io_timeouts": snap.get("io_timeouts", 0),
                "faults_injected": snap.get("faults_injected", 0),
                "zone_resets": snap.get("zone_resets", 0),
                "zone_readonly_transitions":
                    snap.get("zone_readonly_transitions", 0),
                "zone_offline_transitions":
                    snap.get("zone_offline_transitions", 0),
                "zones": n_zones,
                "zones_offline": off,
                "zones_read_only": ro,
                "latency_outliers": self.latency_outliers,
                "read_latency_baseline_s": self._ewma["read"],
                "append_latency_baseline_s": self._ewma["append"],
                "read_p99_s": snap.get("read.service_seconds.p99", 0.0),
                "append_p99_s": snap.get("append.service_seconds.p99", 0.0),
                "sample_windows": self._windows,
            }

    def register_on(self, registry: MetricsRegistry) -> None:
        """Fold the numeric SMART attributes into ``registry`` snapshots as
        a ``health.<name>`` collector (idempotent re-registration)."""
        def collect() -> dict:
            log = self.smart_log()
            return {k: v for k, v in log.items()
                    if isinstance(v, (int, float)) and not isinstance(v, bool)}
        registry.register_collector(f"health.{self.name}", collect)


class ArrayHealthMonitor:
    """One :class:`DeviceHealthMonitor` per member of a striped array —
    the pool-health view an array manager polls.

    ``sample()`` samples every member and returns ``{member_index: status}``;
    ``worst()`` is the pool verdict. The monitors publish their own
    transition events; the alert engine's promotion rule watches
    :meth:`statuses` for members crossing into DEGRADED.
    """

    def __init__(self, array, *, events: Optional[EventLog] = None, **kw):
        self.array = array
        self.events = events if events is not None else event_log()
        self.members = [
            DeviceHealthMonitor(
                d, events=self.events,
                name=f"member{i}/dev{getattr(d, 'dev_ordinal', i)}", **kw)
            for i, d in enumerate(array.devices)
        ]

    def sample(self) -> dict[int, HealthStatus]:
        return {i: m.sample() for i, m in enumerate(self.members)}

    def statuses(self) -> dict[int, HealthStatus]:
        return {i: m.status for i, m in enumerate(self.members)}

    def worst(self) -> HealthStatus:
        return max((m.status for m in self.members),
                   default=HealthStatus.HEALTHY)

    def smart_logs(self) -> list[dict]:
        return [m.smart_log() for m in self.members]

    def register_on(self, registry: MetricsRegistry) -> None:
        for m in self.members:
            m.register_on(registry)
