"""Distribution tests on a small 8-device host mesh (4 data x 2 model).

Verifies per family: train_step and serve_step lower + compile + RUN with
sharded params/batch on the reduced configs, and that the sharded result
matches the single-device result (GSPMD correctness, not just compileability).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.models import abstract_params, cache_specs, init_params
from repro.models.api import make_batch
from repro.serve.step import make_serve_step
from repro.sharding import param_shardings, rules_for, use_rules
from repro.train.step import TrainHyper, make_train_step, train_state_specs

pytestmark = [
    pytest.mark.skipif(
        len(jax.devices()) < 8,
        reason="needs 8 host devices (XLA_FLAGS set too late)"),
    # GSPMD lower+compile+run per family dominates full-suite wall time
    # (~4 min); tier-1 (`make test`) skips it, `make test-all` runs it
    pytest.mark.slow,
]

ARCHS = ["granite-8b", "deepseek-moe-16b", "grok-1-314b", "mamba2-780m",
         "recurrentgemma-9b", "seamless-m4t-large-v2", "llama-3.2-vision-11b",
         "h2o-danube-1.8b"]


def small_mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_train_step_matches_single_device(arch):
    cfg = get_reduced(arch)
    mesh = small_mesh()
    hyper = TrainHyper(grad_accum=2)
    step = make_train_step(cfg, hyper)
    state_specs = train_state_specs(cfg)
    state = init_params(state_specs, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 8, 64)

    # single-device reference
    ref_state, ref_metrics = jax.jit(step)(state, batch)

    rules = rules_for("train", cfg, mesh)
    state_sh = param_shardings(state_specs, mesh, rules)
    batch_sh = {k: NamedSharding(mesh, P(("data",), *([None] * (v.ndim - 1))))
                for k, v in batch.items()}
    with use_rules(rules), mesh:
        sharded_step = jax.jit(step, in_shardings=(state_sh, batch_sh),
                               out_shardings=(state_sh, NamedSharding(mesh, P())))
        state_p = jax.device_put(state, state_sh)
        batch_p = jax.device_put(batch, batch_sh)
        new_state, metrics = sharded_step(state_p, batch_p)

    np.testing.assert_allclose(float(metrics["loss"]),
                               float(ref_metrics["loss"]), rtol=2e-2)
    # spot-check a parameter tree leaf agrees
    ref_leaf = jax.tree.leaves(ref_state["params"])[0]
    got_leaf = jax.tree.leaves(jax.device_get(new_state["params"]))[0]
    np.testing.assert_allclose(np.asarray(got_leaf, np.float32),
                               np.asarray(ref_leaf, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ["granite-8b", "deepseek-moe-16b",
                                  "mamba2-780m", "recurrentgemma-9b"])
def test_sharded_serve_step_runs(arch):
    cfg = get_reduced(arch)
    mesh = small_mesh()
    step = make_serve_step(cfg)
    params = init_params(train_state_specs(cfg), jax.random.PRNGKey(0))["params"]
    B, S = 8, 64
    c_specs = cache_specs(cfg, B, S)
    cache = init_params(c_specs, jax.random.PRNGKey(1))
    rules = rules_for("decode", cfg, mesh)
    p_sh = param_shardings(train_state_specs(cfg)["params"], mesh, rules)
    c_sh = param_shardings(c_specs, mesh, rules)
    tok_sh = NamedSharding(mesh, P("data", None))
    with use_rules(rules), mesh:
        f = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh,
                                        NamedSharding(mesh, P())))
        nxt, logits, new_cache = f(
            jax.device_put(params, p_sh), jax.device_put(cache, c_sh),
            jnp.zeros((B, 1), jnp.int32), jnp.asarray(3, jnp.int32))
    assert nxt.shape == (B, 1)
    assert jnp.isfinite(np.asarray(logits, np.float32)).all()
