"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: instantiate a family-preserving reduced config,
run one forward pass and one grad step, assert output shapes and no NaNs;
run a few decode steps and check cache-consistency against the parallel
forward pass where the family permits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import (
    cache_specs, decode_step, forward, init_params, loss_fn, param_specs,
)
from repro.models.api import make_batch

BATCH, SEQ = 2, 64


@pytest.fixture(scope="module")
def setups():
    cache = {}
    def get(arch_id):
        if arch_id not in cache:
            cfg = get_reduced(arch_id)
            params = init_params(param_specs(cfg), jax.random.PRNGKey(0))
            cache[arch_id] = (cfg, params)
        return cache[arch_id]
    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id, setups):
    cfg, params = setups(arch_id)
    batch = make_batch(cfg, BATCH, SEQ)
    logits, aux, _ = jax.jit(
        lambda p, b: forward(cfg, p, b, remat=False))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch_id}: NaN/Inf"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id, setups):
    cfg, params = setups(arch_id)
    batch = make_batch(cfg, BATCH, SEQ, seed=1)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: loss_fn(cfg, p_, b), has_aux=True)(p)
        p2 = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return loss, p2

    loss, new_params = step(params, batch)
    assert jnp.isfinite(loss), f"{arch_id}: loss not finite"
    # params actually changed
    changed = jax.tree.leaves(jax.tree.map(
        lambda a, b_: bool((a != b_).any()), params, new_params))
    assert any(changed)
    # loss is in a sane range for random init (~ln V)
    assert 0.1 < float(loss) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_runs(arch_id, setups):
    cfg, params = setups(arch_id)
    specs = cache_specs(cfg, BATCH, SEQ)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"))
    tokens = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    logits, cache = step(params, cache, tokens, jnp.asarray(0, jnp.int32))
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    logits2, _ = step(params, cache, tokens + 1, jnp.asarray(1, jnp.int32))
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()


DECODE_CONSISTENCY_ARCHS = [
    "h2o-danube-1.8b", "starcoder2-3b", "granite-8b", "command-r-plus-104b",
    "grok-1-314b", "deepseek-moe-16b", "mamba2-780m", "recurrentgemma-9b",
]


@pytest.mark.parametrize("arch_id", DECODE_CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch_id):
    """Token-by-token decode reproduces the teacher-forced forward logits.
    Run in f32 so numerical noise can't hide cache-logic bugs."""
    from repro.models.params import ParamSpec
    cfg = get_reduced(arch_id).replace(
        compute_dtype="float32", param_dtype="float32")
    if cfg.family == "moe":
        # lift capacity so routing drops no tokens: the teacher-forced pass
        # and the per-token decode otherwise drop *different* tokens
        cfg = cfg.replace(
            moe_capacity_factor=float(cfg.num_experts / cfg.moe_top_k))
    specs = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.axes, s.init, jnp.float32, s.init_scale),
        param_specs(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))
    params = init_params(specs, jax.random.PRNGKey(0))
    T = 12
    batch = make_batch(cfg, 1, T, seed=3)
    ref_logits, _, _ = jax.jit(
        lambda p, b: forward(cfg, p, b, remat=False))(params, batch)

    cspecs = cache_specs(cfg, 1, T)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape,
                            jnp.float32 if s.dtype == jnp.bfloat16 else s.dtype),
        cspecs, is_leaf=lambda x: isinstance(x, ParamSpec))
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(T):
        logits, cache = step(params, cache, batch["tokens"][:, t : t + 1],
                             jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t, :], np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch_id}: decode diverges from forward at t={t}",
        )


@pytest.mark.parametrize("arch_id", ["mamba2-780m", "recurrentgemma-9b",
                                     "granite-8b", "h2o-danube-1.8b",
                                     "llama-3.2-vision-11b",
                                     "seamless-m4t-large-v2"])
def test_prefill_then_decode_matches_forward(arch_id):
    """prefill(0..T-1) -> decode(T-1..) continues exactly like the
    teacher-forced forward pass (cache handoff correctness, f32)."""
    from repro.models.params import ParamSpec
    from repro.serve.step import make_prefill_step
    cfg = get_reduced(arch_id).replace(
        compute_dtype="float32", param_dtype="float32")
    specs = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.axes, s.init, jnp.float32, s.init_scale),
        param_specs(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))
    params = init_params(specs, jax.random.PRNGKey(0))
    T, EXTRA = 8, 4
    full = make_batch(cfg, 1, T + EXTRA, seed=5)
    ref_logits, _, _ = jax.jit(
        lambda p, b: forward(cfg, p, b, remat=False))(params, full)

    prefix = {k: (v[:, :T] if v.ndim == 2 else v) for k, v in full.items()}
    prefill = make_prefill_step(cfg)
    last_logits, cache = jax.jit(lambda p, b: prefill(p, b))(params, prefix)
    np.testing.assert_allclose(np.asarray(last_logits[0]),
                               np.asarray(ref_logits[0, T - 1]),
                               rtol=2e-3, atol=2e-3)
    # cache from prefill must be sized for the full decode range
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    # grow KV caches: prefill returns T-sized caches; decode needs T+EXTRA.
    def grow(leaf):
        if leaf.ndim >= 2 and leaf.shape[1] == T and leaf.dtype != jnp.float32:
            pad = [(0, 0)] * leaf.ndim
            pad[1] = (0, EXTRA)
            return jnp.pad(leaf, pad)
        return leaf
    # identify KV leaves by comparing to cache_specs layout
    specs_full = cache_specs(cfg, 1, T + EXTRA)
    cache_full = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), specs_full,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"))
    def fit(pre, full_z):
        if pre.shape == full_z.shape:
            return pre.astype(full_z.dtype)
        # KV cache: copy the prefix along the seq dim
        idx = tuple(slice(0, s) for s in pre.shape)
        return full_z.astype(full_z.dtype).at[idx].set(pre.astype(full_z.dtype))
    cache = jax.tree.map(fit, cache, cache_full)
    for t in range(T, T + EXTRA):
        logits, cache = step(params, cache, full["tokens"][:, t : t + 1],
                             jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(ref_logits[0, t]),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch_id}: prefill->decode diverges at t={t}")


def test_param_counts_match_published_class():
    """Full configs land in the right parameter-count ballpark."""
    from repro.configs import get_config
    expect = {
        "h2o-danube-1.8b": (1.3e9, 2.4e9),
        "starcoder2-3b": (2.4e9, 3.8e9),
        "granite-8b": (6.5e9, 9.5e9),
        "command-r-plus-104b": (85e9, 125e9),
        "grok-1-314b": (250e9, 370e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "recurrentgemma-9b": (7e9, 11.5e9),
        "llama-3.2-vision-11b": (8e9, 12e9),     # backbone (frontend stubbed)
        "seamless-m4t-large-v2": (1.4e9, 2.9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]B"
