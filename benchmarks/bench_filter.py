"""Paper Figure 2 reproduction: integer-filter offload across execution tiers.

Workload (faithful to §4): fill one zone with random int32s, count those
above RAND_MAX/2 (~50% selectivity), processing at page (4 KiB) granularity.
Scenarios:

  1. native   — host reads the zone and filters with vectorized numpy
                (the paper's "SPDK without computational capabilities");
  2. interp   — ZCSD uBPF-analogue stack machine, one instruction at a time,
                per-access bounds checks (paper scenario 2);
  3. jit      — ZCSD with the program JIT-compiled (XLA), page-streamed
                (paper scenario 3; 'JIT time' reported separately);
  4. kernel   — Pallas zone-filter kernel (interpret mode on CPU) — the
                additional hardware-backend tier the paper lists as ongoing
                work.

Reported per scenario: init+fill seconds, filter seconds, JIT seconds, and
bytes moved to the host. The paper's key claims to check: JIT within ~1% of
native (we report the measured gap), interpreter slowest by a wide margin.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import CsdTier, NvmCsd, filter_count
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1


@dataclass
class Scenario:
    name: str
    fill_seconds: float
    filter_seconds: float
    jit_seconds: float
    bytes_to_host: int
    result: int


def run_figure2(zone_mib: int = 256, runs: int = 5, include_interp: bool = True,
                seed: int = 0) -> list[Scenario]:
    zone_bytes = zone_mib * 1024 * 1024
    n_ints = zone_bytes // 4
    program = filter_count("int32", "gt", RAND_MAX // 2)

    t0 = time.perf_counter()
    dev = ZonedDevice(num_zones=1, zone_bytes=zone_bytes, block_bytes=4096)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, n_ints, dtype=np.int32)
    dev.zone_append(0, data)
    fill_seconds = time.perf_counter() - t0
    expected = int((data > RAND_MAX // 2).sum())

    out: list[Scenario] = []

    # 1. native (SPDK-style): read whole zone to host, numpy filter
    times = []
    for _ in range(runs):
        t = time.perf_counter()
        raw = dev.read_zone(0)          # the whole zone crosses the link
        host = raw.view(np.int32)       # retype in place, no second copy
        res = int((host > RAND_MAX // 2).sum())
        times.append(time.perf_counter() - t)
    assert res == expected
    out.append(Scenario("native-host", fill_seconds, float(np.mean(times)),
                        0.0, zone_bytes, res))

    csd = NvmCsd(dev)

    # 2. interp
    if include_interp:
        t = time.perf_counter()
        stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.INTERP)
        dt = time.perf_counter() - t
        res = int(csd.nvm_cmd_bpf_result())
        assert res == expected
        out.append(Scenario("zcsd-interp", fill_seconds, dt, 0.0,
                            stats.bytes_returned, res))

    # 3. jit (first call pays compile; steady-state measured after)
    stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
    jit_seconds = stats.jit_seconds
    times = []
    for _ in range(runs):
        t = time.perf_counter()
        stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        times.append(time.perf_counter() - t)
    res = int(csd.nvm_cmd_bpf_result())
    assert res == expected
    out.append(Scenario("zcsd-jit", fill_seconds, float(np.mean(times)),
                        jit_seconds, stats.bytes_returned, res))

    # 4. kernel (Pallas, interpret mode on CPU; first call compiles)
    csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.KERNEL)
    times = []
    for _ in range(max(runs // 2, 1)):
        t = time.perf_counter()
        stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.KERNEL)
        times.append(time.perf_counter() - t)
    res = int(csd.nvm_cmd_bpf_result())
    assert res == expected
    out.append(Scenario("zcsd-pallas(interp)", fill_seconds,
                        float(np.mean(times)), 0.0, stats.bytes_returned, res))
    return out


def main(zone_mib: int = 32, runs: int = 3) -> list[str]:
    rows = []
    scenarios = run_figure2(zone_mib=zone_mib, runs=runs)
    native = scenarios[0].filter_seconds
    for s in scenarios:
        rows.append(
            f"fig2_{s.name},{s.filter_seconds * 1e6:.0f},"
            f"vs_native={s.filter_seconds / native:.2f}x;"
            f"jit_us={s.jit_seconds * 1e6:.0f};bytes_to_host={s.bytes_to_host}"
        )
    return rows


if __name__ == "__main__":
    for r in main(zone_mib=256, runs=5):
        print(r)
