"""Input construction: abstract specs (dry-run) and random batches (tests).

``input_specs(cfg, shape)`` returns the exact pytree a step function is
lowered against — ShapeDtypeStructs only, no allocation. Modality frontends
(audio frames, vision patches) are STUBS per the assignment: the specs carry
precomputed embeddings in model space.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache_specs, abstract_params
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.transformer import memory_len

__all__ = ["train_input_specs", "decode_input_specs", "make_batch",
           "make_decode_inputs"]


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch specs for train_step / prefill forward."""
    B, L = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, L), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        Lf = int(L * cfg.encoder_seq_factor)
        specs["frames"] = jax.ShapeDtypeStruct((B, Lf, cfg.d_model), cdt)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), cdt)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Specs for serve_step: one new token against a seq_len-deep cache."""
    B, L = shape.global_batch, shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": abstract_params(cache_specs(cfg, B, L)),
    }


# ------------------------------------------------------------ concrete data

def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        Lf = int(seq * cfg.encoder_seq_factor)
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, Lf, cfg.d_model)) * 0.05, cdt)
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_image_tokens, cfg.d_model)) * 0.05,
            cdt)
    return out


def make_decode_inputs(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    from repro.models.params import init_params
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
    cache = init_params(cache_specs(cfg, batch, seq), jax.random.PRNGKey(seed))
    return tokens, jnp.asarray(0, jnp.int32), cache
