"""Offload VM semantics: all execution tiers agree with the numpy oracle,
and the verifier rejects exactly the unsafe programs."""
import numpy as np
import pytest

from repro.core import (
    CsdTier,
    Instruction,
    NvmCsd,
    OpCode,
    Program,
    VerifyError,
    field_reduce,
    filter_count,
    filter_select,
    filter_sum,
    histogram,
    interpret_program,
    jit_program,
    run_oracle,
    verify_program,
)
from repro.zns import ZonedDevice

RNG = np.random.default_rng(42)


def make_zone_data(n_pages=8, page_elems=1024, dtype=np.int32, seed=0):
    rng = np.random.default_rng(seed)
    if np.issubdtype(np.dtype(dtype), np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min // 2, info.max // 2,
                            (n_pages, page_elems)).astype(dtype)
    return rng.standard_normal((n_pages, page_elems)).astype(dtype) * 100


def run_all_tiers(program, data):
    """Run on oracle / interpreter / XLA-JIT; return the three results."""
    n_pages, page_elems = data.shape
    oracle = run_oracle(program, data)
    interp = interpret_program(
        program, lambda p: data[p], n_pages, page_elems
    ).value
    jp = jit_program(program, n_pages, page_elems)
    jit = jp(data)
    return oracle, interp, jit


PROGRAMS = [
    filter_count("int32", "gt", 2**30),            # the paper's Fig.2 workload
    filter_count("int32", "le", 0),
    filter_sum("int32", "gt", 0),
    filter_sum("float32", "lt", 0.0),
    Program("int32", (Instruction(OpCode.ABS), Instruction(OpCode.RED_MAX))),
    Program("int32", (Instruction(OpCode.RED_MIN),)),
    Program("int32", (Instruction(OpCode.AND, 0xFF), Instruction(OpCode.CMP_EQ, 7),
                      Instruction(OpCode.RED_COUNT)), name="masked_eq"),
    Program("int32", (Instruction(OpCode.SHR, 8), Instruction(OpCode.CMP_GT, 100),
                      Instruction(OpCode.RED_SUM)), name="shift_sum"),
    Program("float32", (Instruction(OpCode.MUL, 2.0), Instruction(OpCode.CMP_GE, 10.0),
                        Instruction(OpCode.RED_COUNT)), name="scaled_count"),
    histogram("int32", -(2**30), 2**30, 64),
    field_reduce("int32", stride=4, index=2, kind="sum", cmp="gt", threshold=0),
    field_reduce("int32", stride=8, index=0, kind="max"),
]


@pytest.mark.parametrize("program", PROGRAMS, ids=lambda p: p.name)
def test_tiers_agree(program):
    data = make_zone_data(dtype=np.dtype(program.input_dtype))
    oracle, interp, jit = run_all_tiers(program, data)
    np.testing.assert_allclose(np.asarray(interp), np.asarray(oracle), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(jit), np.asarray(oracle), rtol=1e-6)


def test_select_tiers_agree():
    program = filter_select("int32", "gt", 2**29, capacity=16384)
    data = make_zone_data()
    (ov, on), (iv, in_), (jv, jn) = run_all_tiers(program, data)
    assert on == in_ == int(jn)
    n = min(int(on), 16384)
    np.testing.assert_array_equal(iv[:n], ov[:n])
    np.testing.assert_array_equal(np.asarray(jv)[:n], ov[:n])


def test_select_overflow_reports_true_count():
    program = filter_select("int32", "ge", np.iinfo(np.int32).min, capacity=8)
    data = make_zone_data(n_pages=2, page_elems=64)
    (_, on), (_, in_), (_, jn) = run_all_tiers(program, data)
    assert on == in_ == int(jn) == 128  # all match; capacity 8 << 128


def test_empty_min_returns_identity():
    program = Program("int32", (Instruction(OpCode.CMP_GT, np.iinfo(np.int32).max - 1),
                                Instruction(OpCode.RED_MIN)))
    data = make_zone_data(n_pages=2, page_elems=128)
    oracle, interp, jit = run_all_tiers(program, data)
    assert oracle == interp == int(jit) == np.iinfo(np.int32).max


# ------------------------------------------------------------------ verifier

def test_verifier_accepts_fig2_program():
    n = verify_program(filter_count("int32", "gt", 2**30),
                       page_elems=1024, n_pages=65536)
    assert n == 2 * 65536  # proven dynamic bound


@pytest.mark.parametrize("bad, msg", [
    (Program("int8", (Instruction(OpCode.RED_COUNT),)), "unsupported dtype"),
    (Program("int32", ()), "empty"),
    (Program("int32", (Instruction(OpCode.CMP_GT, 0),)), "not a terminal"),
    (Program("int32", (Instruction(OpCode.RED_COUNT), Instruction(OpCode.CMP_GT, 0),
                       Instruction(OpCode.RED_COUNT))), "not last"),
    (Program("float32", (Instruction(OpCode.AND, 3), Instruction(OpCode.RED_COUNT))),
     "bitwise op on non-integer"),
    (Program("int32", (Instruction(OpCode.SHL, 99), Instruction(OpCode.RED_COUNT))),
     "shift amount"),
    (Program("int32", (Instruction(OpCode.MOD, 0), Instruction(OpCode.RED_COUNT))),
     "modulo by zero"),
    (Program("int32", (Instruction(OpCode.CMP_GT, 2**40), Instruction(OpCode.RED_COUNT))),
     "out of int32 range"),
    (Program("int32", (Instruction(OpCode.RED_HIST, (5, 5, 16)),)), "empty histogram"),
    (Program("int32", (Instruction(OpCode.RED_HIST, (0, 10, 0)),)), "bins"),
    (Program("int32", (Instruction(OpCode.SELECT),)), "select_capacity"),
    (Program("int32", (Instruction(OpCode.CMP_GT, 0), Instruction(OpCode.FIELD, (4, 0)),
                       Instruction(OpCode.RED_COUNT))), "first instruction"),
    (Program("int32", (Instruction(OpCode.FIELD, (3, 1)), Instruction(OpCode.RED_COUNT))),
     "does not divide"),
    (Program("int32", (Instruction(OpCode.FIELD, (4, 9)), Instruction(OpCode.RED_COUNT))),
     "invalid FIELD"),
])
def test_verifier_rejects(bad, msg):
    with pytest.raises(VerifyError, match=msg):
        verify_program(bad, page_elems=1024, n_pages=16)


def test_verifier_dynamic_budget():
    from repro.core.verifier import VerifierLimits
    prog = filter_count("int32", "gt", 0)
    with pytest.raises(VerifyError, match="dynamic instruction bound"):
        verify_program(prog, page_elems=1024, n_pages=10**9,
                       limits=VerifierLimits(max_dynamic_insns=10**6))


# ------------------------------------------------------------------ NvmCsd

@pytest.fixture
def csd():
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=4096)
    data = make_zone_data(n_pages=256, page_elems=1024, seed=7)
    dev.zone_append(0, data)
    return NvmCsd(dev), data


def test_csd_run_matches_oracle_all_tiers(csd):
    dev_csd, data = csd
    program = filter_count("int32", "gt", 2**30)
    expected = run_oracle(program, data)
    for tier in (CsdTier.INTERP, CsdTier.JIT):
        stats = dev_csd.nvm_cmd_bpf_run(program, 0, tier=tier)
        assert int(dev_csd.nvm_cmd_bpf_result()) == int(expected)
        assert stats.pages == 256
        assert stats.bytes_read == 256 * 4096
        assert stats.bytes_returned <= 8
        assert stats.movement_saved_bytes == 256 * 4096 - stats.bytes_returned
        assert stats.insns_verified == 2 * 256


def test_csd_rejects_unwritten_extent(csd):
    dev_csd, _ = csd
    program = filter_count("int32", "gt", 0)
    with pytest.raises(VerifyError, match="write pointer"):
        dev_csd.nvm_cmd_bpf_run(program, 0, n_blocks=512)  # only 256 written
    with pytest.raises(VerifyError):
        dev_csd.nvm_cmd_bpf_run(program, 1)  # zone 1 empty


def test_csd_jit_cache_reports_compile_once(csd):
    dev_csd, _ = csd
    program = filter_sum("int32", "gt", 0)
    s1 = dev_csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
    s2 = dev_csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
    assert s1.jit_seconds > 0.0       # paper's "JIT time" statistic
    assert s2.jit_seconds == 0.0      # cached


def test_csd_async(csd):
    dev_csd, data = csd
    program = filter_count("int32", "gt", 0)
    fut = dev_csd.nvm_cmd_bpf_run_async(program, 0, tier=CsdTier.JIT)
    stats = fut.result(timeout=60)
    assert stats.pages == 256
    assert int(dev_csd.nvm_cmd_bpf_result()) == int(run_oracle(program, data))


def test_csd_oracle_path(csd):
    dev_csd, data = csd
    program = histogram("int32", -(2**30), 2**30, 32)
    got, _ = dev_csd.run_and_fetch(program, 0, tier=CsdTier.JIT)
    np.testing.assert_array_equal(np.asarray(got), dev_csd.oracle(program, 0))
