"""Attention: GQA projections, chunked (flash-style) training attention,
cross-attention, and cache-based decode attention.

Training/prefill attention is *chunked over KV blocks* with an online softmax
(lax.scan) so the [Lq, Lk] logit tensor never materializes — the working set
is one [Lq, chunk] block, which is what keeps the 32k-token prefill inside
per-device memory at the production mesh. Causal, sliding-window (SWA) and
local-window masks are all expressed per block.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import cdtype, rope, softcap
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import shard_act, use_param

__all__ = [
    "attn_specs", "cross_attn_specs", "apply_attention", "apply_cross_attention",
    "decode_attention", "chunked_attention",
]

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, H, hd), ("embed", "q_heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": ParamSpec((H, hd, d), ("q_heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.use_bias:
        specs["bq"] = ParamSpec((H, hd), ("q_heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return specs


cross_attn_specs = attn_specs  # same weight layout; K/V read the memory


def _project_q(cfg, p, x, positions, use_rope=True):
    dt = cdtype(cfg)
    wq = use_param(p["wq"], ("embed", "q_heads", "head_dim"))
    q = jnp.einsum("bld,dnh->blnh", x, wq.astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if use_rope:
        q = rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    return q  # [B, L, H, hd]


def _project_kv(cfg, p, x, positions, use_rope=True):
    dt = cdtype(cfg)
    wk = use_param(p["wk"], ("embed", "kv_heads", "head_dim"))
    wv = use_param(p["wv"], ("embed", "kv_heads", "head_dim"))
    k = jnp.einsum("bld,dnh->blnh", x, wk.astype(dt))
    v = jnp.einsum("bld,dnh->blnh", x, wv.astype(dt))
    if "bk" in p:
        k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    if use_rope:
        k = rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    return k, v  # [B, S, KV, hd]


def _out_proj(cfg, p, o, B, Lq):
    dt = cdtype(cfg)
    wo = use_param(p["wo"], ("q_heads", "head_dim", "embed"))
    y = jnp.einsum("blnh,nhd->bld", o.reshape(B, Lq, cfg.num_heads, cfg.head_dim),
                   wo.astype(dt))
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y


def chunked_attention(
    cfg: ModelConfig,
    q: jnp.ndarray,             # [B, Lq, H, hd]
    k: jnp.ndarray,             # [B, Lk, KV, hd]
    v: jnp.ndarray,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Online-softmax attention, scanned over KV chunks. Returns [B, Lq, H, hd].

    GQA layout note (§Perf iteration 1): K/V are broadcast to the FULL head
    dim before the einsums so every attention tensor shares one ``H`` dim
    sharded over "model". Splitting heads into [KV, G] instead puts a
    KV-sized dim (8, 2, 1, ...) on a 16-way axis — GSPMD pads it and
    round-trips ~GB-scale f32 intermediates through all-gathers per layer
    (measured: 15 GB/layer/device on granite-8b). The broadcast is a
    zero-FLOP intra-device op XLA fuses into the matmul operand.
    """
    B, Lq, H, hd = q.shape
    _, Lk, KV, _ = k.shape
    G = H // KV
    C = min(cfg.attn_chunk, Lk)
    n_chunks = -(-Lk // C)
    pad = n_chunks * C - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    qh = q.transpose(0, 2, 1, 3) * scale                        # [B,H,Lq,hd]
    kh = jnp.repeat(k.reshape(B, n_chunks, C, KV, hd), G, axis=3) \
        .transpose(1, 0, 3, 2, 4)                               # [nC,B,H,C,hd]
    vh = jnp.repeat(v.reshape(B, n_chunks, C, KV, hd), G, axis=3) \
        .transpose(1, 0, 3, 2, 4)
    qh = shard_act(qh, ("act_batch", "act_heads", None, None))
    kh = shard_act(kh, (None, "act_batch", "act_heads", None, None))
    vh = shard_act(vh, (None, "act_batch", "act_heads", None, None))
    qpos = q_offset + jnp.arange(Lq)

    def block(carry, inp):
        m, l, acc = carry
        kc, vc, cidx = inp
        kpos = cidx * C + jnp.arange(C)
        logits = jnp.einsum("bhld,bhcd->bhlc", qh, kc,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.attn_logit_softcap)
        mask = jnp.ones((Lq, C), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        mask &= (kpos < Lk)[None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhlc,bhcd->bhld", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Lq), jnp.float32)
    a0 = jnp.zeros((B, H, Lq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        block, (m0, l0, a0), (kh, vh, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)                             # [B,Lq,H,hd]
    return out.astype(q.dtype)


def apply_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,                 # [B, L, d]
    positions: jnp.ndarray,         # [B, L]
    *,
    window: Optional[int] = None,
    causal: bool = True,
) -> jnp.ndarray:
    B, L, _ = x.shape
    q = _project_q(cfg, p, x, positions)
    k, v = _project_kv(cfg, p, x, positions)
    q = shard_act(q, ("act_batch", "act_seq", "act_heads", None))
    k = shard_act(k, ("act_batch", "act_seq", "act_kv_heads", None))
    v = shard_act(v, ("act_batch", "act_seq", "act_kv_heads", None))
    o = chunked_attention(cfg, q, k, v, causal=causal,
                          window=window or cfg.sliding_window)
    y = _out_proj(cfg, p, o, B, L)
    # pin the output to batch sharding: the FSDP-sharded wo puts "embed"@data
    # on the result, which otherwise conflicts with batch@data and makes
    # GSPMD replicate the batch dim (full-batch f32 all-reduces)
    return shard_act(y, ("act_batch", "act_seq", "act_embed"))


def apply_cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,               # [B, L, d] queries
    memory: jnp.ndarray,          # [B, S, d] encoder / vision states
) -> jnp.ndarray:
    B, L, _ = x.shape
    zero_pos = jnp.zeros((B, x.shape[1]), jnp.int32)
    q = _project_q(cfg, p, x, zero_pos, use_rope=False)
    mem_pos = jnp.zeros((B, memory.shape[1]), jnp.int32)
    k, v = _project_kv(cfg, p, memory, mem_pos, use_rope=False)
    o = chunked_attention(cfg, q, k, v, causal=False)
    return _out_proj(cfg, p, o, B, L)


def decode_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,               # [B, 1, d] current token
    k_cache: jnp.ndarray,         # [B, S_max, KV, hd]
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,             # [] current position (scalar int32)
    *,
    window: Optional[int] = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step: insert this token's K/V, attend over the cache.
    For SWA archs the cache is a ring buffer of size `window` and `pos`
    indexes it modulo the window. Returns (y, k_cache, v_cache)."""
    B, _, _ = x.shape
    S_max = k_cache.shape[1]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q = _project_q(cfg, p, x, positions)                       # [B,1,H,hd]
    k_new, v_new = _project_kv(cfg, p, x, positions)           # [B,1,KV,hd]
    slot = (pos % S_max).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                           (0, slot, 0, 0))
    k_cache = shard_act(k_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    v_cache = shard_act(v_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))

    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    # Decode keeps the GROUPED GQA einsum (q reshaped to [B, KV, G, hd]):
    # unlike training, the decode rules never shard the KV-head dim of the
    # cache on a non-dividing axis (kv_seq carries the model axis instead),
    # so there is no padding hazard — and broadcasting K/V to all H heads
    # would multiply the HBM traffic of this *memory-bound* path by G
    # (12x for command-r; §Perf iteration 7).
    qh = q.reshape(B, KV, G, hd) * hd ** -0.5
    logits = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache.astype(qh.dtype),
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.attn_logit_softcap)

    # which cache slots are valid at position `pos`?
    slots = jnp.arange(S_max)
    if window is None:
        valid = slots <= pos          # linear cache: slot == absolute position
    else:
        # ring buffer: all slots written in the last `window` steps are valid
        age = (pos - slots) % S_max   # steps since slot was written
        valid = (age < jnp.minimum(pos + 1, window))
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    att = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", att.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    y = _out_proj(cfg, p, o, B, 1)
    return y, k_cache, v_cache


def decode_cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,               # [B, 1, d]
    mem_k: jnp.ndarray,           # [B, S, KV, hd] precomputed at prefill
    mem_v: jnp.ndarray,
) -> jnp.ndarray:
    B = x.shape[0]
    zero_pos = jnp.zeros((B, 1), jnp.int32)
    q = _project_q(cfg, p, x, zero_pos, use_rope=False)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    qh = q.reshape(B, KV, G, hd) * hd ** -0.5       # grouped: see decode note
    logits = jnp.einsum("bkgd,bskd->bkgs", qh, mem_k.astype(qh.dtype),
                        preferred_element_type=jnp.float32)
    att = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", att.astype(mem_v.dtype), mem_v,
                   preferred_element_type=jnp.float32)
    return _out_proj(cfg, p, o.reshape(B, 1, H, hd).astype(x.dtype), B, 1)
