"""Span tracing on wall time *and* reactor virtual time.

The reactor emulates device latency on a virtual timeline (``io_busy_until``
deadlines measured on ``time.monotonic()``), while the Python host threads —
dispatcher, gather pool, reactor pump — burn real wall time on the same
clock. A profile of the array fan-out is only legible if both kinds of
activity land on ONE timeline, so every event here carries
``time.monotonic()`` timestamps: host spans sample the clock around their
body; device-side "virtual" events are emitted post-hoc from the claimed
``(start, service)`` windows via :func:`event_complete`.

Design constraints from the hot path:

  * **near-zero disabled cost** — ``span()`` checks one module-level bool
    and returns a shared no-op singleton whose ``__enter__``/``__exit__``
    do nothing; no allocation, no lock, no clock read.
  * **lock-light enabled path** — each thread appends into its own
    preallocated ring buffer (a plain-list ring; the only global lock is
    taken once per thread at buffer registration). Overflow overwrites the
    oldest events and counts drops — tracing must never stall the reactor.
  * **nesting without frames** — a contextvar stack carries the parent
    span's tags, so a ``stage.read_wait`` span inside ``offload.execute``
    inherits tenant/device tags it never set; contextvars also follow the
    code into coroutine-style callbacks better than thread-locals would.

Export is Chrome ``trace_event`` JSON (``{"traceEvents": [...]}``) with
complete ("ph": "X") events: load it in Perfetto / chrome://tracing. Host
threads render as pid 1 (one row per thread); device virtual tracks as
pid 2 (one row per ``track=`` name, e.g. ``dev0/zone3``).
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Optional

__all__ = [
    "set_enabled",
    "enabled",
    "tracing",
    "span",
    "instant",
    "event_complete",
    "drain",
    "clear",
    "dropped",
    "export_chrome",
    "to_chrome_events",
    "RING_CAPACITY",
]

RING_CAPACITY = 65536  # events per thread before overwrite

_enabled = False

# Every registered per-thread ring, so drain() can see them all. Entries are
# _Ring objects; rings of dead threads stay until clear() — their events are
# part of the trace.
_rings_lock = threading.Lock()
_rings: list["_Ring"] = []
_local = threading.local()

# (name, tags) of the innermost live span — children inherit tags from it.
_span_ctx: ContextVar[Optional[tuple[str, dict]]] = ContextVar(
    "repro_trace_span", default=None)


def set_enabled(on: bool) -> None:
    global _enabled
    _enabled = bool(on)


def enabled() -> bool:
    return _enabled


@contextmanager
def tracing(on: bool = True):
    """Temporarily flip tracing (benchmarks wrap their measured region)."""
    global _enabled
    prev = _enabled
    _enabled = bool(on)
    try:
        yield
    finally:
        _enabled = prev


class _Ring:
    """Single-writer event ring. Only its owning thread appends; drain()
    reads concurrently, which is safe for a stats ring (a torn read of the
    slot being overwritten is the worst case, and drain is a debugging/export
    operation, not a correctness path)."""

    __slots__ = ("tid", "tname", "buf", "head", "dropped")

    def __init__(self, tid: int, tname: str):
        self.tid = tid
        self.tname = tname
        self.buf: list = [None] * RING_CAPACITY
        self.head = 0      # next write index (monotonic, wraps via modulo)
        self.dropped = 0   # events overwritten after the ring first filled

    def append(self, ev: tuple) -> None:
        h = self.head
        if h >= RING_CAPACITY and self.buf[h % RING_CAPACITY] is not None:
            self.dropped += 1
            if self.dropped == 1:
                # cold path, once per ring lifetime: tell the event log the
                # exported trace will be incomplete for this thread. Imported
                # lazily — the hot append path must stay import-free.
                from .events import Severity, publish
                publish("trace.ring_drop", severity=Severity.WARNING,
                        message=f"trace ring for thread {self.tname!r} "
                                f"wrapped (capacity {RING_CAPACITY})",
                        thread=self.tname, capacity=RING_CAPACITY)
        self.buf[h % RING_CAPACITY] = ev
        self.head = h + 1

    def events(self) -> list:
        h = self.head
        if h <= RING_CAPACITY:
            return [e for e in self.buf[:h] if e is not None]
        i = h % RING_CAPACITY
        return [e for e in self.buf[i:] + self.buf[:i] if e is not None]


def _ring() -> _Ring:
    r = getattr(_local, "ring", None)
    if r is None:
        t = threading.current_thread()
        r = _Ring(t.ident or 0, t.name)
        _local.ring = r
        with _rings_lock:
            _rings.append(r)
    return r


# Event tuples: ("X", name, ts, dur, tid_or_track, tags) for complete events
# (tid_or_track is None → host thread row; a string → device virtual track),
# ("I", name, ts, tags) for instants.


class _Span:
    """A live span: records (ts, dur) around its body and pushes itself as
    the contextvar parent so children inherit its tags."""

    __slots__ = ("name", "tags", "_t0", "_token")

    def __init__(self, name: str, tags: dict):
        self.name = name
        self.tags = tags
        self._t0 = 0.0
        self._token = None

    def __enter__(self):
        self._token = _span_ctx.set((self.name, self.tags))
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dur = time.monotonic() - self._t0
        if self._token is not None:
            _span_ctx.reset(self._token)
        _ring().append(("X", self.name, self._t0, dur, None, self.tags))
        return False


class _NoopSpan:
    """Shared singleton returned when tracing is off — the entire disabled
    cost of ``with span(...)`` is one bool test plus two empty methods."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


def span(name: str, **tags):
    """Context manager timing its body. Tags (tenant/device/zone/tier/op)
    merge over the enclosing span's tags."""
    if not _enabled:
        return _NOOP
    parent = _span_ctx.get()
    if parent is not None and parent[1]:
        merged = dict(parent[1])
        merged.update(tags)
        tags = merged
    return _Span(name, tags)


def instant(name: str, **tags) -> None:
    """Zero-duration marker at now."""
    if not _enabled:
        return
    _ring().append(("I", name, time.monotonic(), tags))


def event_complete(name: str, ts: float, dur: float,
                   track: Optional[str] = None, **tags) -> None:
    """Record a complete event with EXPLICIT timestamps — how device virtual
    time enters the trace. The device model knows each transfer's claimed
    ``(start, service)`` window on the monotonic clock before it elapses;
    it calls this at submit time with ``track="dev0/zone3"`` and the event
    lands on that device row rather than the submitting thread's row."""
    if not _enabled:
        return
    _ring().append(("X", name, ts, dur, track, tags))


def dropped() -> int:
    with _rings_lock:
        return sum(r.dropped for r in _rings)


def drain() -> list[dict]:
    """Snapshot all recorded events as dicts (wall seconds), oldest-first
    per thread. Does not clear — export after a run, then :func:`clear`."""
    with _rings_lock:
        rings = list(_rings)
    out = []
    for r in rings:
        for ev in r.events():
            if ev[0] == "X":
                _, name, ts, dur, track, tags = ev
                out.append({"type": "span", "name": name, "ts": ts,
                            "dur": dur, "track": track,
                            "tid": r.tid, "thread": r.tname, "tags": tags})
            else:
                _, name, ts, tags = ev
                out.append({"type": "instant", "name": name, "ts": ts,
                            "tid": r.tid, "thread": r.tname, "tags": tags})
    out.sort(key=lambda e: e["ts"])
    return out


def clear() -> None:
    """Drop all recorded events and rings (fresh trace)."""
    with _rings_lock:
        _rings.clear()
    # Threads re-register on next append; stale thread-local rings are
    # detached from _rings so their future events are invisible — replace
    # the current thread's ring eagerly since it is the common writer.
    _local.ring = None


_HOST_PID = 1
_DEVICE_PID = 2


def to_chrome_events(events: Optional[list[dict]] = None) -> list[dict]:
    """Convert drained events to Chrome ``trace_event`` dicts (ts/dur in µs,
    rebased so the trace starts near 0)."""
    if events is None:
        events = drain()
    if not events:
        return []
    t0 = min(e["ts"] for e in events)
    out: list[dict] = []
    # Metadata: name host threads; give each device track its own tid row.
    threads_seen: dict[int, str] = {}
    tracks: dict[str, int] = {}
    body: list[dict] = []
    for e in events:
        ts_us = (e["ts"] - t0) * 1e6
        args = dict(e["tags"]) if e["tags"] else {}
        if e["type"] == "span" or e.get("track"):
            track = e.get("track")
            if track is not None:
                tid = tracks.setdefault(track, len(tracks) + 1)
                pid = _DEVICE_PID
            else:
                tid = e["tid"]
                pid = _HOST_PID
                threads_seen.setdefault(tid, e["thread"])
            body.append({"name": e["name"], "ph": "X", "pid": pid,
                         "tid": tid, "ts": ts_us,
                         "dur": e.get("dur", 0.0) * 1e6, "args": args})
        else:
            tid = e["tid"]
            threads_seen.setdefault(tid, e["thread"])
            body.append({"name": e["name"], "ph": "i", "pid": _HOST_PID,
                         "tid": tid, "ts": ts_us, "s": "t", "args": args})
    out.append({"name": "process_name", "ph": "M", "pid": _HOST_PID,
                "args": {"name": "host threads"}})
    out.append({"name": "process_name", "ph": "M", "pid": _DEVICE_PID,
                "args": {"name": "device virtual time"}})
    for tid, tname in threads_seen.items():
        out.append({"name": "thread_name", "ph": "M", "pid": _HOST_PID,
                    "tid": tid, "args": {"name": tname}})
    for track, tid in tracks.items():
        out.append({"name": "thread_name", "ph": "M", "pid": _DEVICE_PID,
                    "tid": tid, "args": {"name": track}})
    out.extend(body)
    return out


def export_chrome(path: str, events: Optional[list[dict]] = None) -> int:
    """Write ``{"traceEvents": [...]}`` JSON loadable in Perfetto /
    chrome://tracing. Returns the number of trace events written."""
    evs = to_chrome_events(events)
    with open(path, "w") as f:
        json.dump({"traceEvents": evs,
                   "displayTimeUnit": "ms",
                   "otherData": {"dropped_events": dropped()}}, f)
    return len(evs)
