"""Zoned checkpoint store throughput: save / restore / recovery-scan / GC."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.params import abstract_params, init_params
from repro.train.checkpoint import ZonedCheckpointStore
from repro.train.step import train_state_specs


def main() -> list[str]:
    rows = []
    cfg = get_reduced("granite-8b")
    specs = train_state_specs(cfg)
    state = init_params(specs, jax.random.PRNGKey(0))
    nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(state))

    store = ZonedCheckpointStore(num_zones=8, zone_bytes=16 * 1024 * 1024,
                                 keep=2)
    t = time.perf_counter()
    store.save(1, state)
    save_s = time.perf_counter() - t

    t = time.perf_counter()
    got = store.restore(like=abstract_params(specs))
    restore_s = time.perf_counter() - t

    t = time.perf_counter()
    for s in (2, 3, 4):
        store.save(s, state)
    resets_before = store.device.stats["zone_resets"]
    gc_s = (time.perf_counter() - t) / 3

    rows.append(f"ckpt_save,{save_s * 1e6:.0f},"
                f"mb={nbytes / 1e6:.1f};mb_per_s={nbytes / 1e6 / save_s:.0f}")
    rows.append(f"ckpt_restore,{restore_s * 1e6:.0f},"
                f"mb_per_s={nbytes / 1e6 / restore_s:.0f}")
    rows.append(f"ckpt_save_gc,{gc_s * 1e6:.0f},"
                f"zone_resets={resets_before};kept={len(store.steps())}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
