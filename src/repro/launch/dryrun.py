import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the production 256/512-chip mesh
# out of host placeholder devices; .lower().compile() then proves every
# (arch x shape x mesh) cell's sharding is coherent without real hardware.

"""Multi-pod dry-run driver.

For each (architecture x input-shape x mesh) cell:

  1. build the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. resolve sharding rules (FSDP+TP for train, TP(+SP-KV) for decode);
  3. jit the step function with NamedSharding in/out shardings;
  4. ``.lower()`` against ShapeDtypeStruct inputs (zero allocation);
  5. ``.compile()`` — GSPMD partitioning must succeed;
  6. record ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs / bytes for the roofline), and the
     collective-op byte census parsed from the optimized HLO.

Results append to a JSONL file so the sweep is resumable per cell:

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import abstract_params, cache_specs
from repro.models.api import decode_input_specs, train_input_specs
from repro.models.config import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.serve.step import make_prefill_step, make_serve_step
from repro.sharding import (
    named_sharding_for, param_shardings, rules_for, use_rules,
)
from repro.train.step import TrainHyper, make_train_step, train_state_specs

# ------------------------------------------------------------ HLO parsing

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+([a-z\-]+)"
)


def _result_bytes(shape_str: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shape_str))


def parse_collectives(hlo_text: str) -> dict:
    """Sum *operand* bytes of every collective op in the (per-device SPMD)
    optimized HLO. Post-optimization HLO references operands by name only, so
    this is two-pass: (1) symbol table name -> result bytes; (2) for each
    collective instruction, sum its operands' bytes."""
    sizes: dict[str, int] = {}
    collective_lines: list[tuple[str, str]] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode = m.groups()
        sizes[name] = _result_bytes(shape_str)
        if opcode in _COLLECTIVES:
            collective_lines.append((opcode, line))

    out = {k: {"bytes": 0, "wire_bytes": 0, "count": 0} for k in _COLLECTIVES}
    opref = re.compile(r"[(,]\s*%?([\w.\-]+)")
    name_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
    for kind, line in collective_lines:
        call = line.split(f" {kind}(", 1)
        operands = []
        if len(call) == 2:
            args = call[1].split(")", 1)[0]
            operands = [o for o in opref.findall("(" + args)]
        nbytes = sum(sizes.get(o, 0) for o in operands)
        out[kind]["bytes"] += nbytes
        # wire bytes: all-gather RECEIVES the gathered result (operand is
        # only this device's shard); AR/RS/a2a/permute move ~operand bytes
        if kind == "all-gather":
            nm = name_re.match(line)
            out[kind]["wire_bytes"] += sizes.get(nm.group(1), 0) if nm else nbytes
        else:
            out[kind]["wire_bytes"] += nbytes
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_wire_bytes"] = sum(v["wire_bytes"] for k, v in out.items()
                                  if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# small dense archs train all-reduce-free as pure ZeRO-3 (§Perf iteration 6):
# per-layer TP all-reduces (~9 GB) dwarf their param all-gathers (~2.6 GB),
# and spreading batch over the model axis drops grad-accum to 1. Large archs
# keep TP: with grad accumulation, FSDP would re-gather params per microbatch.
PRESET_BY_ARCH = {
    "granite-8b": "fsdp",
    "h2o-danube-1.8b": "fsdp",
    "starcoder2-3b": "fsdp",
    "mamba2-780m": "fsdp",
    "seamless-m4t-large-v2": "fsdp",
}


# ----------------------------------------------------------- accum policy

def pick_grad_accum(cfg: ModelConfig, shape: ShapeSpec, n_batch_shards: int,
                    budget_bytes: float = 3.5e9) -> int:
    """Smallest power-of-two microbatch count keeping the rematerialized
    activation footprint (~ saved layer inputs) under budget."""
    b_loc = max(shape.global_batch // n_batch_shards, 1)
    per_layer = b_loc * shape.seq_len * cfg.d_model * 2  # bf16 layer input
    saved_factor = 3 if cfg.remat == "save_collectives" else 1
    approx = (cfg.num_layers * per_layer * saved_factor
              * (2 if cfg.family == "hybrid" else 1))
    accum = 1
    while approx / accum > budget_bytes and accum < shape.global_batch \
            and accum < 64:
        accum *= 2
    while shape.global_batch % (accum * n_batch_shards) and accum > 1:
        accum //= 2
    return accum


# ------------------------------------------------------------- cost probes
#
# XLA's HloCostAnalysis counts while-loop (lax.scan) bodies ONCE, independent
# of trip count — so the production (scanned) lowering under-reports FLOPs by
# ~num_layers x. The roofline therefore uses *cost probes*: the same cell
# lowered with 1-3 pattern-preserving layer counts, scans fully unrolled and
# grad_accum=1 (while-free => exact counts), then extrapolated linearly in
# depth:  c(L) = prologue + L x layer_body. probe_plan returns
# [(cfg_overrides, weight)] with  sum_i w_i * c(probe_i) = c(full).

def probe_plan(cfg: ModelConfig) -> list[tuple[dict, float]]:
    L = cfg.num_layers
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern) or 1
        full, rem = divmod(L, pat)
        plan = [({"num_layers": pat}, float(1 - (full - 1) - (1 if rem else 0))),
                ({"num_layers": 2 * pat}, float(full - 1))]
        if rem:
            plan.append(({"num_layers": pat + rem}, 1.0))
        return plan
    if cfg.family == "vlm" and cfg.cross_attn_stride:
        s = cfg.cross_attn_stride
        full, rem = divmod(L, s)
        plan = [({"num_layers": s}, float(1 - (full - 1) - (1 if rem else 0))),
                ({"num_layers": 2 * s}, float(full - 1))]
        if rem:
            plan.append(({"num_layers": s + rem}, 1.0))
        return plan
    if cfg.family == "moe" and cfg.first_layer_dense:
        return [({"num_layers": 2}, float(1 - (L - 2))),
                ({"num_layers": 3}, float(L - 2))]
    if cfg.is_encoder_decoder:
        return [({"num_layers": 1, "encoder_layers": 1}, float(1 - (L - 1))),
                ({"num_layers": 2, "encoder_layers": 2}, float(L - 1))]
    return [({"num_layers": 1}, float(1 - (L - 1))),
            ({"num_layers": 2}, float(L - 1))]


def run_probe_cells(arch: str, shape_name: str, preset=None) -> list[dict]:
    """Lower the cost probes for one (arch x shape) on the single-pod mesh."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return [{"arch": arch, "shape": shape_name, "mesh": "16x16",
                 "kind": "probe", "status": "skipped", "reason": why}]
    preset = preset or PRESET_BY_ARCH.get(arch, "tp")
    recs = []
    for i, (overrides, weight) in enumerate(probe_plan(cfg)):
        pcfg = cfg.replace(scan_unroll=True, **overrides)
        rec = {"arch": arch, "shape": shape_name, "mesh": "16x16",
               "kind": "probe", "probe_index": i, "weight": weight,
               "overrides": overrides, "preset": preset}
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=False)
            lowered, meta = build_cell(pcfg, shape, mesh, grad_accum=1,
                                       preset=preset)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k in ("transcendentals",))
            }
            rec["collectives"] = parse_collectives(compiled.as_text())
            rec["status"] = "ok"
        except Exception as e:
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
        rec["total_seconds"] = round(time.time() - t0, 2)
        recs.append(rec)
    return recs


# -------------------------------------------------------------- lowerings

def batch_shardings(specs: dict, mesh, batch_axes) -> dict:
    sh = {}
    for k, v in specs.items():
        parts = [batch_axes] + [None] * (len(v.shape) - 1)
        sh[k] = NamedSharding(mesh, P(*parts))
    return sh


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, grad_accum=None,
               preset: str = "tp"):
    """Returns (lowered, meta) for one cell.

    preset="tp"   — FSDP over data + Megatron TP over model (default);
    preset="fsdp" — pure ZeRO-3: batch AND parameters shard over every mesh
                    axis, no tensor parallelism (all-reduce-free; best for
                    small archs where per-layer TP all-reduces dominate).
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if preset == "fsdp" and shape.kind == "train":
        batch_ax_names = tuple(a for a in ("pod", "data", "model") if a in axes)
        overrides = {
            "q_heads": None, "kv_heads": None, "mlp": None, "vocab": None,
            "experts": None, "expert_mlp": None, "ssm_inner": None,
            "ssm_heads": None,
            "act_heads": None, "act_kv_heads": None, "act_mlp": None,
            "act_vocab": None, "act_experts": None, "act_expert_mlp": None,
            "act_ssm_inner": None, "act_ssm_heads": None,
            "embed": batch_ax_names,
        }
    else:
        preset = "tp"
        batch_ax_names = tuple(a for a in ("pod", "data") if a in axes)
        overrides = {}
    n_batch_shards = 1
    for a in batch_ax_names:
        n_batch_shards *= axes[a]
    batch_axes = batch_ax_names
    if shape.global_batch % max(n_batch_shards, 1):
        batch_axes = None  # tiny batches (long_500k): replicate batch dim
    overrides["act_batch"] = batch_axes

    if shape.kind == "train":
        rules = rules_for("train", cfg, mesh, overrides)
        accum = grad_accum if grad_accum is not None else pick_grad_accum(
            cfg, shape, n_batch_shards if batch_axes else 1)
        hyper = TrainHyper(grad_accum=accum)
        step = make_train_step(cfg, hyper)
        state_specs = train_state_specs(cfg)
        state_sh = param_shardings(state_specs, mesh, rules)
        in_specs = train_input_specs(cfg, shape)
        in_sh = batch_shardings(in_specs, mesh, batch_axes)
        with use_rules(rules), mesh:
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, in_sh),
                out_shardings=(state_sh, NamedSharding(mesh, P())),
            ).lower(abstract_params(state_specs), in_specs)
        return lowered, {"grad_accum": accum, "rules": rules.name,
                         "preset": preset, "step": "train_step"}

    if shape.kind == "prefill":
        rules = rules_for("prefill", cfg, mesh, overrides)
        step = make_prefill_step(cfg)
        state_specs = train_state_specs(cfg)["params"]
        p_sh = param_shardings(state_specs, mesh, rules)
        in_specs = train_input_specs(cfg, shape)
        in_specs.pop("labels")
        in_sh = batch_shardings(in_specs, mesh, batch_axes)
        c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
        c_sh = param_shardings(c_specs, mesh, rules)
        logits_sh = named_sharding_for(
            (shape.global_batch, cfg.vocab_size),
            ("act_batch", "act_vocab"), mesh, rules)
        with use_rules(rules), mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, in_sh),
                out_shardings=(logits_sh, c_sh),
            ).lower(abstract_params(state_specs), in_specs)
        return lowered, {"rules": rules.name, "step": "prefill_step"}

    # decode
    rules = rules_for("decode", cfg, mesh, overrides)
    step = make_serve_step(cfg)
    state_specs = train_state_specs(cfg)["params"]
    p_sh = param_shardings(state_specs, mesh, rules)
    c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = param_shardings(c_specs, mesh, rules)
    tok_sh = NamedSharding(mesh, P(batch_axes, None))
    pos_sh = NamedSharding(mesh, P())
    logits_sh = named_sharding_for(
        (shape.global_batch, cfg.vocab_size), ("act_batch", "act_vocab"),
        mesh, rules)
    inputs = decode_input_specs(cfg, shape)
    with use_rules(rules), mesh:
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(tok_sh, logits_sh, c_sh),
        ).lower(abstract_params(state_specs), inputs["cache"],
                inputs["tokens"], inputs["pos"])
    return lowered, {"rules": rules.name, "step": "serve_step"}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             grad_accum=None, keep_hlo_dir=None, preset=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    preset = preset or PRESET_BY_ARCH.get(arch, "tp")
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "family": cfg.family,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, meta = build_cell(cfg, shape, mesh, grad_accum, preset=preset)
        rec.update(meta)
        rec["lower_seconds"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
        cost = compiled.cost_analysis()
        rec["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or k in ("transcendentals",))
        }
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        if keep_hlo_dir:
            p = Path(keep_hlo_dir)
            p.mkdir(parents=True, exist_ok=True)
            (p / f"{arch}__{shape_name}__{rec['mesh']}.hlo.txt").write_text(hlo)
        rec["status"] = "ok"
    except Exception as e:  # a failed cell is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["total_seconds"] = round(time.time() - t0, 2)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--keep-hlo", default=None,
                    help="directory to dump optimized HLO text per cell")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present with status=ok in --out")
    ap.add_argument("--probes", action="store_true",
                    help="run the unrolled cost probes (single-pod) instead "
                         "of the production lowerings")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if args.skip_done and out.exists():
        for line in out.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    failures = 0
    if args.probes:
        seen_probe = set()
        for arch, shape_name, _ in cells:
            if (arch, shape_name) in seen_probe:
                continue
            seen_probe.add((arch, shape_name))
            if args.skip_done and (arch, shape_name, "16x16") in done:
                print(f"[probes] SKIP (done) {arch} {shape_name}")
                continue
            print(f"[probes] {arch} x {shape_name} ...", flush=True)
            recs = run_probe_cells(arch, shape_name)
            with out.open("a") as f:
                for rec in recs:
                    f.write(json.dumps(rec) + "\n")
            for rec in recs:
                if rec["status"] == "error":
                    failures += 1
                    print(f"  ERROR probe {rec.get('probe_index')}: {rec['error']}")
                elif rec["status"] == "ok":
                    print(f"  probe {rec['probe_index']} ok "
                          f"({rec['total_seconds']}s, w={rec['weight']}, "
                          f"flops={rec['cost_analysis'].get('flops', 0):.3e})")
                else:
                    print(f"  skipped: {rec.get('reason')}")
        return 1 if failures else 0

    for arch, shape_name, multi in cells:
        mesh_name = "2x16x16" if multi else "16x16"
        if (arch, shape_name, mesh_name) in done:
            print(f"[dryrun] SKIP (done) {arch} {shape_name} {mesh_name}")
            continue
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ...", flush=True)
        rec = run_cell(arch, shape_name, multi, args.grad_accum, args.keep_hlo)
        with out.open("a") as f:
            f.write(json.dumps(rec) + "\n")
        if rec["status"] == "ok":
            ca = rec["cost_analysis"]
            print(f"  ok in {rec['total_seconds']}s  "
                  f"flops/dev={ca.get('flops', 0):.3e}  "
                  f"coll_bytes/dev={rec['collectives']['total_bytes']:.3e}")
        elif rec["status"] == "skipped":
            print(f"  skipped: {rec['reason']}")
        else:
            failures += 1
            print(f"  ERROR: {rec['error']}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
