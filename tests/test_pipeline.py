"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding.pipeline import bubble_fraction, pipeline_apply

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices")


def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def make(n_stages, n_micro, mb=2, d=16, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.5,
                         jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n_stages, d)) * 0.1,
                         jnp.float32),
    }
    xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)
    return params, xs


def sequential(params, xs, n_stages):
    out = xs
    for s in range(n_stages):
        p = jax.tree.map(lambda a: a[s], params)
        out = jnp.stack([stage_fn(p, out[i]) for i in range(out.shape[0])])
    return out


@pytest.mark.parametrize("n_stages,n_micro", [(4, 8), (8, 16), (2, 3)])
def test_pipeline_matches_sequential(n_stages, n_micro):
    mesh = jax.make_mesh((n_stages,), ("pipe",))
    params, xs = make(n_stages, n_micro, seed=n_stages)
    got = pipeline_apply(stage_fn, params, xs, mesh=mesh)
    want = sequential(params, xs, n_stages)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_composes_with_data_axis():
    """(pipe=4, data=2) mesh: pipeline inside, batch untouched."""
    mesh = jax.make_mesh((4, 2), ("pipe", "data"))
    params, xs = make(4, 8, mb=4, seed=9)
    got = pipeline_apply(stage_fn, params, xs, mesh=mesh)
    want = sequential(params, xs, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0
