"""Bounded, thread-safe structured event log — the fleet operator's journal.

Metrics answer "how much / how fast"; traces answer "where did the time go".
Neither answers "what *happened*": which zone went READ_ONLY, which member
died mid-append, which tenant's submissions stalled on a full SQ, which
checkpoint ticket failed and why. That is the event log's job — the
discrete, operator-facing record every layer publishes into:

  * zone state transitions to READ_ONLY / OFFLINE (:mod:`repro.zns.device`);
  * member death, torn-append fencing and degraded reads
    (:mod:`repro.array.striping`);
  * SQ admission stalls / rejections and WRR starvation
    (:mod:`repro.array.queues`);
  * trace-ring and completion-ring overwrite drops;
  * checkpoint ticket failures (:mod:`repro.train.checkpoint`);
  * health status changes and firing alerts
    (:mod:`repro.telemetry.health` / :mod:`repro.telemetry.alerts`).

Design constraints mirror the trace ring's: publishing must be cheap and can
never block or grow without bound — the log is a fixed-capacity ring (oldest
entries overwritten, counted in ``dropped``, exactly the CQ-overwrite
semantics the device layer already uses), one lock guards the ring, and
subscriber callbacks run OUTSIDE the lock with exceptions swallowed (a
consumer bug must not take down a publisher on the reactor or dispatcher
thread). Each event carries BOTH clocks the emulator runs on: ``t_mono``
(``time.monotonic()``, the virtual-time axis traces and device deadlines
share — events line up under a Chrome trace) and ``t_wall``
(``time.time()``, for humans and JSONL export).
"""
from __future__ import annotations

import enum
import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

__all__ = [
    "Severity",
    "Event",
    "EventLog",
    "event_log",
    "publish",
]


class Severity(enum.IntEnum):
    """Syslog-shaped levels; ordered so ``>=`` filters work."""

    DEBUG = 10
    INFO = 20
    WARNING = 30
    ERROR = 40
    CRITICAL = 50


_seq = 0
_seq_lock = threading.Lock()


def _next_seq() -> int:
    global _seq
    with _seq_lock:
        _seq += 1
        return _seq


@dataclass(frozen=True)
class Event:
    """One structured record: a dotted ``name`` (``zone.offline``,
    ``alert.slo_breach``), a severity, free-form ``tags`` (device/zone/
    tenant/...), and both timestamps."""

    name: str
    severity: Severity
    message: str
    t_mono: float
    t_wall: float
    seq: int
    tags: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "seq": self.seq,
            "name": self.name,
            "severity": self.severity.name,
            "message": self.message,
            "t_mono": self.t_mono,
            "t_wall": self.t_wall,
            "tags": self.tags,
        }


class EventLog:
    """Fixed-capacity ring of :class:`Event` records.

    ``publish`` is the single producer entry point (any thread);
    ``snapshot``/``tail`` read without consuming; ``export_jsonl`` writes one
    JSON object per line. ``subscribe`` registers a callback invoked with
    every published event — the alert engine's live feed — and returns an
    unsubscribe callable. Memory is bounded by construction: the ring
    overwrites oldest-first past ``capacity`` and counts the overwrites in
    ``dropped`` (asserted under sustained publishing by the telemetry tests).
    """

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError("event log capacity must be positive")
        self.capacity = capacity
        self._q: deque[Event] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._subscribers: list[Callable[[Event], None]] = []
        self.published = 0
        self.dropped = 0

    # -------------------------------------------------------------- produce
    def publish(self, name: str, *, severity: Severity = Severity.INFO,
                message: str = "", **tags) -> Event:
        ev = Event(name=name, severity=Severity(severity), message=message,
                   t_mono=time.monotonic(), t_wall=time.time(),
                   seq=_next_seq(), tags=tags)
        with self._lock:
            if len(self._q) == self._q.maxlen:
                self.dropped += 1
            self._q.append(ev)
            self.published += 1
            subs = list(self._subscribers)
        for fn in subs:                 # outside the lock, failures isolated
            try:
                fn(ev)
            except Exception:
                pass
        return ev

    # ------------------------------------------------------------- consume
    def subscribe(self, fn: Callable[[Event], None]) -> Callable[[], None]:
        """Register ``fn(event)`` for every future publish; returns an
        unsubscribe callable (idempotent)."""
        with self._lock:
            self._subscribers.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                if fn in self._subscribers:
                    self._subscribers.remove(fn)

        return unsubscribe

    def snapshot(self, *, min_severity: Severity = Severity.DEBUG,
                 name: Optional[str] = None,
                 since_seq: int = 0) -> list[Event]:
        """Non-consuming filtered view, oldest-first. ``name`` matches exact
        names or dotted prefixes (``"zone"`` matches ``"zone.offline"``);
        ``since_seq`` skips events at or below a previously-seen sequence
        number (the incremental-poll idiom the alert engine uses)."""
        with self._lock:
            evs = list(self._q)
        out = []
        for e in evs:
            if e.severity < min_severity or e.seq <= since_seq:
                continue
            if name is not None and e.name != name and \
                    not e.name.startswith(name + "."):
                continue
            out.append(e)
        return out

    def tail(self, n: int = 10) -> list[Event]:
        with self._lock:
            evs = list(self._q)
        return evs[-n:]

    def last_seq(self) -> int:
        with self._lock:
            return self._q[-1].seq if self._q else 0

    def clear(self) -> None:
        with self._lock:
            self._q.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    # -------------------------------------------------------------- export
    def export_jsonl(self, path: str) -> int:
        """Write the current ring as JSON Lines (one event object per line,
        oldest-first). Returns the number of events written."""
        evs = self.snapshot()
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e.to_dict()) + "\n")
        return len(evs)


_global: Optional[EventLog] = None
_global_lock = threading.Lock()


def event_log() -> EventLog:
    """The process-wide event log every instrumented layer publishes into
    (the analogue of :func:`repro.telemetry.metrics.registry`)."""
    global _global
    with _global_lock:
        if _global is None:
            _global = EventLog()
        return _global


def publish(name: str, *, severity: Severity = Severity.INFO,
            message: str = "", **tags) -> Event:
    """Publish to the global log — the one-liner instrumented layers use."""
    return event_log().publish(name, severity=severity, message=message,
                               **tags)
