"""Zone data pipeline: pushdown filtering, movement-saved accounting,
hedged prefetch straggler mitigation."""
import time

import numpy as np
import pytest

from repro.core import CsdTier
from repro.data import PrefetchLoader, ZoneDataPipeline, ZoneDataStore
from repro.zns import ZonedDevice


def make_store(seq_len=127, zones=2, zone_kib=512):
    dev = ZonedDevice(num_zones=zones, zone_bytes=zone_kib * 1024,
                      block_bytes=4096)
    return ZoneDataStore(dev, seq_len)


def fill(store, zone_id, n, seed=0, q_lo=0, q_hi=100):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 50000, (n, store.seq_len), dtype=np.int32)
    quality = rng.integers(q_lo, q_hi, n, dtype=np.int32)
    store.append_records(zone_id, toks, quality)
    return toks, quality


def test_stride_alignment():
    s1 = make_store(seq_len=127)     # 128 divides 1024
    assert s1.stride == 128 and s1.pages_per_record_unit == 1
    s2 = make_store(seq_len=4096)    # padded to whole pages
    assert s2.stride % 1024 == 0 and s2.stride >= 4097
    assert s2.pages_per_record_unit == s2.stride // 1024


def test_pushdown_filters_by_quality():
    store = make_store()
    toks, quality = fill(store, 0, 100, seed=1)
    pipe = ZoneDataPipeline(store, batch=4, min_quality=50)
    recs = pipe._zone_records(0)
    want = (quality >= 50).sum()
    assert recs.shape[0] == want
    # surviving records carry the right tokens
    survivors = toks[quality >= 50]
    np.testing.assert_array_equal(recs[:, 1 : 1 + store.seq_len], survivors)
    # padding sentinel records (quality -1) never leak
    assert (recs[:, 0] >= 50).all()


def test_movement_saved_accounting():
    store = make_store()
    fill(store, 0, 200, seed=2, q_lo=0, q_hi=100)
    pipe = ZoneDataPipeline(store, batch=4, min_quality=90)  # ~10% selectivity
    pipe._zone_records(0)
    st = pipe.stats
    assert st.records_seen >= 200
    assert st.records_kept < st.records_seen * 0.3
    assert st.movement_saved > 0
    # low selectivity => large reduction
    assert st.bytes_to_host < st.bytes_read_device * 0.5


def test_batches_shapes_and_epochs():
    store = make_store()
    fill(store, 0, 64, seed=3)
    fill(store, 1, 64, seed=4)
    pipe = ZoneDataPipeline(store, batch=8, min_quality=0)
    batches = list(pipe.batches([0, 1], epochs=2))
    assert len(batches) == 2 * (128 // 8)
    for b in batches:
        assert b["tokens"].shape == (8, store.seq_len - 1)
        assert b["labels"].shape == (8, store.seq_len - 1)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_deterministic_replay():
    """Same seed -> identical batch stream (required for resume replay)."""
    store = make_store()
    fill(store, 0, 64, seed=5)
    p1 = ZoneDataPipeline(store, batch=8)
    p2 = ZoneDataPipeline(store, batch=8)
    for b1, b2 in zip(p1.batches([0], seed=7), p2.batches([0], seed=7)):
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_interp_and_jit_tier_agree_on_pipeline():
    store = make_store()
    toks, quality = fill(store, 0, 50, seed=6)
    a = ZoneDataPipeline(store, batch=4, min_quality=30, tier=CsdTier.JIT)
    b = ZoneDataPipeline(store, batch=4, min_quality=30, tier=CsdTier.INTERP)
    np.testing.assert_array_equal(a._zone_records(0), b._zone_records(0))


def test_prefetch_loader_hedges_stragglers():
    """A slow producer triggers hedged fetches instead of stalling."""
    def slow_gen():
        for i in range(6):
            if i == 2:
                time.sleep(0.35)        # straggling zone read
            yield {"i": np.asarray([i])}

    loader = PrefetchLoader(slow_gen(), depth=1, hedge_seconds=0.05)
    got = [int(b["i"][0]) for b in loader]
    assert sorted(got) == list(range(6))   # nothing lost, order preserved-ish
    assert loader.hedged_fetches >= 1


def test_prefetch_loader_clean_exhaustion():
    loader = PrefetchLoader(iter([{"i": np.zeros(1)}] * 3), depth=2,
                            hedge_seconds=0.2)
    assert len(list(loader)) == 3
