"""Health-telemetry pipeline: fault injection through counters → events →
alerts → per-tenant accounting, plus the event-log overhead budget.

The observability stack is only trustworthy if the whole chain fires when a
member actually dies. This benchmark kills member zones on a live raid1
array mid-offload-stream and asserts every stage end to end (loud in CI):

  * **health counters move** — the dead member's ``zone_offline_transitions``
    and (after a host probe of the dead zone) ``read_errors`` SMART counters
    advance;
  * **SUSPECT event logged** — the :class:`DeviceHealthMonitor` samples the
    member into SUSPECT and publishes the ``health.status`` transition into
    the global event log (alongside the device's own ``zone.offline`` and
    the array's ``array.member_offline`` / ``array.degraded_read`` events);
  * **alert raised** — killing past the degraded-zone threshold promotes the
    member to DEGRADED: the :class:`HealthPromotionRule` fires, the alert
    lands in the event log AND invokes the registered callback (the
    spare-promotion trigger's seat), and the probe's error growth fires the
    :class:`ErrorRateRule` off the registry collectors;
  * **degraded reads accounted per tenant** — the degraded offloads stay
    bit-identical while ``tenant.<t>.degraded_reads`` advances, and
    ``ArrayOffloadStats.tenant_totals`` reports the tenant's cumulative
    bytes/ops/p50/p99;
  * **per-tenant SLO rule** — tightening the p99 SLO to an impossible value
    fires one ``tenant_p99_slo`` alert per active tenant.

The overhead row bounds the cost of having the event log at all: each
disabled-path primitive (publish with and without a subscriber) is timed,
the hot path is charged DOUBLE its plausible per-offload event count (the
steady-state hot path publishes ZERO events — events fire on faults), and
the total must stay under 3% of a measured single-device JIT offload row —
the same deterministic budget shape as ``bench_profile.measure_overhead``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.core.csd import CsdTier, NvmCsd
from repro.telemetry import (
    AlertEngine,
    ArrayHealthMonitor,
    ErrorRateRule,
    EventLog,
    HealthPromotionRule,
    HealthStatus,
    Severity,
    TenantLatencySLORule,
    event_log,
    registry,
)
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096
MAX_EVENT_OVERHEAD = 0.03


def run_health(*, data_mib: int = 4, read_us_per_block: float = 2.0,
               runs: int = 3, seed: int = 0) -> dict:
    """Drive the injected-fault pipeline; returns the asserted evidence."""
    data_bytes = data_mib * 1024 * 1024
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)

    # 2-member raid1, 4 zones per member: zone 0 carries the data; killing
    # member 1's zones one at a time walks it HEALTHY -> SUSPECT (1/4
    # offline) -> DEGRADED (3/4 >= the 0.5 zone-fraction threshold)
    devices = [
        ZonedDevice(num_zones=4, zone_bytes=data_bytes, block_bytes=BLOCK,
                    read_us_per_block=read_us_per_block)
        for _ in range(2)
    ]
    array = StripedZoneArray(devices, stripe_blocks=64, redundancy="raid1")
    array.zone_append(0, data)

    log = event_log()
    seq0 = log.last_seq()          # only count events this run publishes
    monitor = ArrayHealthMonitor(array)
    monitor.register_on(registry())
    promoted: list = []            # the spare-promotion callback's inbox
    engine = AlertEngine(rules=[
        HealthPromotionRule(monitor),
        # the monitors' registry collectors surface each member's SMART
        # error counters as health.<member>.read_errors etc.
        ErrorRateRule(pattern="health.*_errors", name="error_rate"),
    ])
    engine.on_alert(promoted.append)

    t_start = time.perf_counter()
    with OffloadScheduler(array) as sched:
        sched.register_tenant("alice")
        sched.register_tenant("bob")

        # -------- healthy phase: two tenants share the array
        for tenant in ("alice", "bob"):
            for _ in range(runs):
                sched.nvm_cmd_bpf_run(program, 0, tenant=tenant)
                assert int(sched.nvm_cmd_bpf_result()) == expected
        assert engine.evaluate() == [], "healthy array fired an alert"
        assert monitor.worst() is HealthStatus.HEALTHY
        ts = sched.tenant_stats()
        for tenant in ("alice", "bob"):
            t = ts[tenant]
            assert t["ops"] >= runs and t["bytes"] > 0, t
            assert t["p99_s"] >= t["p50_s"] > 0.0, t

        # -------- fault injection mid-offload-stream: one zone dies
        snap0 = devices[1].metrics.snapshot()
        array.set_offline(0, device=1)
        snap1 = devices[1].metrics.snapshot()
        assert snap1["zone_offline_transitions"] == \
            snap0.get("zone_offline_transitions", 0) + 1, \
            "health counter did not move on zone death"

        # degraded offloads: bit-identical, accounted per tenant
        for _ in range(runs):
            stats = sched.nvm_cmd_bpf_run(program, 0, tenant="alice")
            assert int(sched.nvm_cmd_bpf_result()) == expected, \
                "degraded offload result differs"
        assert stats.degraded_reads > 0, "degraded fan-out not counted"
        assert stats.tenant == "alice"
        tot = stats.tenant_totals
        assert tot["degraded_reads"] > 0 and tot["ops"] > 0 and \
            tot["bytes"] > 0 and tot["p99_s"] >= tot["p50_s"] > 0.0, tot
        assert sched.tenant_stats()["bob"]["degraded_reads"] == 0, \
            "degraded reads misattributed across tenants"

        # SUSPECT: sampled by the engine's promotion rule (below threshold,
        # so nothing fires yet) and published as a health.status event
        fired = engine.evaluate()
        assert fired == [], f"SUSPECT member fired a DEGRADED alert: {fired}"
        assert monitor.statuses()[1] is HealthStatus.SUSPECT
        suspects = [e for e in log.snapshot(name="health.status",
                                            since_seq=seq0)
                    if e.tags.get("to_status") == "SUSPECT"]
        assert suspects, "no SUSPECT health.status event logged"

        # SMART error counters: a host probe of the dead zone errors out
        try:
            devices[1].read_blocks(0, 0, 1)
        except Exception:
            pass
        assert devices[1].stats["read_errors"] >= 1, \
            "probe of dead zone did not advance read_errors"

        # -------- promotion: past the zone-fraction threshold
        array.set_offline(1, device=1)
        array.set_offline(2, device=1)
        fired = engine.evaluate()
        assert any(a.rule == "member_degraded" for a in fired), fired
        assert any(a.rule == "member_degraded" for a in promoted), \
            "alert callback (spare-promotion trigger) not invoked"
        assert any(a.rule == "error_rate" for a in fired), \
            "probe error growth did not fire the error-rate rule"
        assert monitor.statuses()[1] >= HealthStatus.DEGRADED
        assert log.snapshot(name="alert.member_degraded", since_seq=seq0)
        assert log.snapshot(name="array.member_offline", since_seq=seq0)
        assert log.snapshot(name="zone.offline", since_seq=seq0)
        assert log.snapshot(name="array.degraded_read", since_seq=seq0)

        # -------- per-tenant p99 SLO rule: an impossible SLO fires per tenant
        engine.add_rule(TenantLatencySLORule(1e-9))
        slo = [a for a in engine.evaluate() if a.rule == "tenant_p99_slo"]
        assert {a.tags["tenant"] for a in slo} >= {"alice", "bob"}, slo

        pipeline_s = time.perf_counter() - t_start
        alice = sched.tenant_stats()["alice"]
    return {
        "pipeline_seconds": pipeline_s,
        "suspect_events": len(suspects),
        "alerts_fired": len(promoted) + len(slo),
        "slo_alerts": len(slo),
        "events_logged": len(log.snapshot(since_seq=seq0)),
        "alice": alice,
        "bob": sched.tenant_stats()["bob"],
        "member1_smart": monitor.members[1].smart_log(),
    }


def measure_event_overhead(data_mib: int = 4, runs: int = 3) -> dict:
    """Event-log cost budget vs a measured single-device offload row.

    Times the publish primitive bare and with a subscriber attached (the
    alert engine's live-feed shape), charges the hot path DOUBLE a
    worst-case two events per offload — the actual steady-state count is
    zero — and requires the total under 3% of the single-device read row.
    """
    n = 200_000

    log = EventLog(capacity=1024)
    t0 = time.perf_counter()
    for _ in range(n):
        log.publish("bench.noop", severity=Severity.DEBUG)
    publish_s = (time.perf_counter() - t0) / n

    log_sub = EventLog(capacity=1024)
    log_sub.subscribe(lambda e: None)
    t0 = time.perf_counter()
    for _ in range(n):
        log_sub.publish("bench.noop", severity=Severity.DEBUG)
    publish_sub_s = (time.perf_counter() - t0) / n

    per_offload = 2 * (publish_s + publish_sub_s)

    data_bytes = data_mib * 1024 * 1024
    dev = ZonedDevice(num_zones=1, zone_bytes=data_bytes, block_bytes=BLOCK)
    rng = np.random.default_rng(0)
    dev.zone_append(0, rng.integers(0, RAND_MAX, data_bytes // 4,
                                    dtype=np.int32))
    csd = NvmCsd(dev)
    program = filter_count("int32", "gt", RAND_MAX // 2)
    csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)   # warm-up
    times = []
    for _ in range(runs):
        t = time.perf_counter()
        csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        times.append(time.perf_counter() - t)
    read_row_s = float(np.mean(times))
    ratio = per_offload / read_row_s
    assert ratio < MAX_EVENT_OVERHEAD, (
        f"event-log overhead {ratio:.2%} of the single-device read row "
        f"exceeds the {MAX_EVENT_OVERHEAD:.0%} budget (publish "
        f"{publish_s * 1e9:.0f}ns, with subscriber "
        f"{publish_sub_s * 1e9:.0f}ns)")
    return {"publish_ns": publish_s * 1e9,
            "publish_sub_ns": publish_sub_s * 1e9,
            "per_offload_overhead_us": per_offload * 1e6,
            "read_row_us": read_row_s * 1e6, "ratio": ratio}


def main(data_mib: int = 4, runs: int = 3) -> list[str]:
    rows = []
    r = run_health(data_mib=data_mib, runs=runs)
    alice, bob = r["alice"], r["bob"]
    rows.append(
        f"health_pipeline,{r['pipeline_seconds'] * 1e6:.0f},"
        f"suspect_events={r['suspect_events']};"
        f"alerts_fired={r['alerts_fired']};"
        f"events_logged={r['events_logged']};"
        f"member1_zones_offline={r['member1_smart']['zones_offline']};"
        f"member1_read_errors={r['member1_smart']['read_errors']}"
    )
    rows.append(
        f"health_tenant_accounting,{alice['p99_s'] * 1e6:.0f},"
        f"alice_ops={alice['ops']};"
        f"alice_mib={alice['bytes'] / 2**20:.1f};"
        f"alice_p50_us={alice['p50_s'] * 1e6:.0f};"
        f"alice_p99_us={alice['p99_s'] * 1e6:.0f};"
        f"alice_degraded={alice['degraded_reads']};"
        f"bob_ops={bob['ops']};bob_degraded={bob['degraded_reads']}"
    )
    o = measure_event_overhead(data_mib=data_mib, runs=runs)
    rows.append(
        f"health_event_overhead,{o['per_offload_overhead_us']:.2f},"
        f"publish_ns={o['publish_ns']:.0f};"
        f"publish_sub_ns={o['publish_sub_ns']:.0f};"
        f"read_row_us={o['read_row_us']:.0f};"
        f"ratio={o['ratio']:.4f}"
    )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
