"""Self-healing array: rebuild-to-spare protocol, ArrayManager loops, scrub.

Pins the ISSUE 8 contracts: member_shard address math against real device
contents, per-zone cutover (rebuilt zones take appends while later zones
still copy), full-lifecycle bit-identity across raid1/xor at 2/4/8 members
(including a member killed mid-rebuild), idempotent alert-path promotion
with incident resolution, xor double-fault degrading OFFLINE without
corruption or hangs, scrub catching injected bit rot and feeding the
health monitor, and checkpoint restore riding a mid-rebuild array.
"""
import threading

import numpy as np
import pytest

from repro.array import ArrayManager, OffloadScheduler, StripedZoneArray
from repro.core.programs import filter_sum
from repro.telemetry.alerts import AlertEngine, HealthPromotionRule
from repro.telemetry.events import event_log
from repro.telemetry.health import ArrayHealthMonitor, HealthStatus
from repro.train.checkpoint import ZonedCheckpointStore
from repro.zns import ZNSError, ZonedDevice, ZoneState, ZoneStateError

BLOCK = 4096
STRIPE = 4


def make_device(num_zones=4, zone_kib=256):
    return ZonedDevice(num_zones=num_zones, zone_bytes=zone_kib * 1024,
                       block_bytes=BLOCK)


def make_array(n_devices, *, num_zones=4, zone_kib=256, stripe=STRIPE,
               redundancy="raid0"):
    devs = [make_device(num_zones, zone_kib) for _ in range(n_devices)]
    return StripedZoneArray(devs, stripe_blocks=stripe, redundancy=redundancy)


def int32_blocks(n_blocks, seed=0, lo=-1000, hi=1000):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, n_blocks * BLOCK // 4, dtype=np.int32)


def kill_member(arr, member, zones=None):
    for z in (range(arr.num_zones) if zones is None else zones):
        arr.set_offline(z, device=member)


def corrupt_block(dev, zone_id, block, offset=17):
    """Flip one byte of a landed block directly in the device's backing
    buffer — silent bit rot no read error will ever report."""
    z = dev.zone(zone_id)
    dev._buf[(z.start_lba + block) * dev.block_bytes + offset] ^= 0xFF


# ------------------------------------------------------- member_shard math
class TestMemberShard:
    @pytest.mark.parametrize("redundancy,n", [
        ("raid0", 3), ("raid1", 2), ("raid1", 4), ("xor", 4), ("xor", 5)])
    @pytest.mark.parametrize("fill", ["row", "rows_partial", "full"])
    def test_shard_matches_device_contents(self, redundancy, n, fill):
        """member_shard over the logical stream must reproduce, byte for
        byte, what each member actually stored — data chunks, mirror
        copies, and rotated parity (tail parity excluded: it never
        landed)."""
        arr = make_array(n, num_zones=2, zone_kib=128, redundancy=redundancy)
        row = STRIPE * arr.data_columns
        wp = {"row": row, "rows_partial": 3 * row + STRIPE + 2,
              "full": arr.zone_blocks}[fill]
        arr.zone_append(0, int32_blocks(wp, seed=wp))
        logical = arr.read_zone(0).reshape(-1, BLOCK)
        wps = arr._member_write_pointers(wp)
        for m, dev in enumerate(arr.devices):
            assert dev.zone(0).write_pointer == wps[m]
            shard = arr.member_shard(m, logical)
            stored = dev.read_blocks(0, 0, wps[m]).reshape(-1, BLOCK)
            assert shard.shape == stored.shape, (m, shard.shape, stored.shape)
            assert np.array_equal(shard, stored), f"member {m} shard differs"

    def test_batched_shards_concatenate(self):
        """Row-aligned batches with the right base_block must concatenate
        to the whole-zone shard — the invariant the rebuild copy loop
        relies on."""
        arr = make_array(4, num_zones=2, zone_kib=128, redundancy="xor")
        row = STRIPE * arr.data_columns
        wp = 5 * row + 3
        arr.zone_append(0, int32_blocks(wp, seed=9))
        logical = arr.read_zone(0).reshape(-1, BLOCK)
        for m in range(arr.n_devices):
            whole = arr.member_shard(m, logical)
            parts = [arr.member_shard(m, logical[b: b + 2 * row],
                                      base_block=b)
                     for b in range(0, wp, 2 * row)]
            assert np.array_equal(np.concatenate(parts), whole)

    def test_unaligned_base_block_rejected(self):
        arr = make_array(4, redundancy="xor")
        with pytest.raises(ValueError, match="aligned"):
            arr.member_shard(0, np.zeros((4, BLOCK), np.uint8), base_block=2)


# ----------------------------------------------- append-refusal diagnostics
class TestRefusalDetail:
    def test_degraded_refusal_names_members_and_mode(self):
        arr = make_array(4, redundancy="xor")
        arr.zone_append(0, int32_blocks(STRIPE * arr.data_columns))
        kill_member(arr, 2, zones=[0])
        with pytest.raises(ZoneStateError) as ei:
            arr.zone_append(0, int32_blocks(1))
        msg = str(ei.value)
        assert "not writable" in msg
        assert "offline members=[2]" in msg
        assert "redundancy=xor" in msg
        assert "array.member_offline" in msg

    def test_rebuilding_refusal_names_the_rebuild(self):
        arr = make_array(2, redundancy="raid1")
        arr.zone_append(0, int32_blocks(STRIPE))
        kill_member(arr, 1)
        arr.replace_member(1, make_device())
        with pytest.raises(ZoneStateError) as ei:
            arr.zone_append(0, int32_blocks(1))
        assert "member 1 rebuilding onto spare" in str(ei.value)


# ------------------------------------------------------- rebuild protocol
class TestRebuildProtocol:
    def test_per_zone_cutover_under_manual_protocol(self):
        """Committing zone 0 makes it writable again while zone 1 is still
        marked — the online property, pinned without thread timing."""
        arr = make_array(4, redundancy="xor")
        row = STRIPE * arr.data_columns
        for z in (0, 1):
            arr.zone_append(z, int32_blocks(2 * row + 3, seed=z))
        logical0 = arr.read_zone(0).reshape(-1, BLOCK)
        kill_member(arr, 1, zones=[0, 1])
        pending = arr.replace_member(1, make_device())
        assert pending == [0, 1]
        member, wp = arr.begin_member_rebuild(0)
        assert (member, wp) == (1, 2 * row + 3)
        shard = arr.member_shard(1, logical0)
        arr.devices[1].submit_append(0, shard).result()
        arr.commit_member_rebuild(0)
        assert arr.zone(0).is_writable
        assert arr.zone(1).state is ZoneState.READ_ONLY
        arr.zone_append(0, int32_blocks(2, seed=77))     # appends resume
        assert arr.rebuilding_zones() == {1: 1}

    def test_commit_refuses_short_copy(self):
        arr = make_array(2, redundancy="raid1")
        arr.zone_append(0, int32_blocks(3 * STRIPE))
        kill_member(arr, 0)
        arr.replace_member(0, make_device())
        arr.begin_member_rebuild(0)
        arr.devices[0].submit_append(0, np.zeros(STRIPE * BLOCK,
                                                 np.uint8)).result()
        with pytest.raises(ZoneStateError, match="cutover.*refused"):
            arr.commit_member_rebuild(0)

    def test_begin_restarts_partial_copy_from_zero(self):
        arr = make_array(2, redundancy="raid1")
        arr.zone_append(0, int32_blocks(2 * STRIPE, seed=5))
        kill_member(arr, 1)
        arr.replace_member(1, make_device())
        arr.begin_member_rebuild(0)
        arr.devices[1].submit_append(0, np.zeros(STRIPE * BLOCK,
                                                 np.uint8)).result()
        member, wp = arr.begin_member_rebuild(0)     # restart: re-parked
        assert arr.devices[1].zone(0).write_pointer == 0
        shard = arr.member_shard(1, arr.read_zone(0).reshape(-1, BLOCK))
        arr.devices[1].submit_append(0, shard).result()
        arr.commit_member_rebuild(0)
        assert arr.zone(0).is_writable

    def test_replace_refuses_pulling_live_data(self):
        """Swapping out a member that still holds the only copy (another
        member already offline under xor) must refuse atomically."""
        arr = make_array(4, redundancy="xor")
        arr.zone_append(0, int32_blocks(STRIPE * arr.data_columns))
        kill_member(arr, 0, zones=[0])
        with pytest.raises(ZoneStateError, match="unrecoverable"):
            arr.replace_member(2, make_device())
        assert arr.rebuilding_zones() == {}

    def test_replace_skips_already_lost_zones(self):
        arr = make_array(4, redundancy="xor")
        for z in (0, 1):
            arr.zone_append(z, int32_blocks(STRIPE * arr.data_columns))
        kill_member(arr, 0, zones=[0])
        kill_member(arr, 1, zones=[0, 1])      # zone 0 is now double-faulted
        pending = arr.replace_member(1, make_device())
        assert pending == [1]                  # zone 0 is gone, not pending
        assert arr.zone(0).state is ZoneState.OFFLINE

    def test_write_pointer_frozen_mid_rebuild(self):
        arr = make_array(2, redundancy="raid1")
        arr.zone_append(0, int32_blocks(STRIPE))
        kill_member(arr, 1)
        arr.replace_member(1, make_device())
        with pytest.raises(ZoneStateError, match="frozen"):
            arr.zone(0).write_pointer = 0


# ------------------------------------------------------ full lifecycle
LIFECYCLE_GRID = [("raid1", 2), ("raid1", 4), ("raid1", 8),
                  ("xor", 4), ("xor", 8)]


class TestFullLifecycle:
    @pytest.mark.parametrize("redundancy,n", LIFECYCLE_GRID)
    def test_kill_promote_rebuild_bit_identical(self, redundancy, n):
        """append → kill member → auto-promote via the alert path → rebuild
        → reads and offloads bit-identical, zones writable, scrub clean."""
        arr = make_array(n, num_zones=3, zone_kib=128, redundancy=redundancy)
        fills = [arr.zone_blocks, arr.zone_blocks // 2 + 3,
                 STRIPE * arr.data_columns + 1]
        for z, fill in enumerate(fills):
            arr.zone_append(z, int32_blocks(fill, seed=z))
        before = [arr.read_zone(z).copy() for z in range(3)]
        mon = ArrayHealthMonitor(arr)
        engine = AlertEngine(rules=[HealthPromotionRule(mon)])
        mgr = ArrayManager(arr, spares=[make_device(3, 128)], monitor=mon)
        mgr.attach(engine)
        mon.sample()
        victim = n - 1
        kill_member(arr, victim)
        fired = engine.evaluate()
        assert any(a.rule == "member_degraded" for a in fired)
        assert mgr.wait(timeout=60)
        st = mgr.status()[victim]
        assert st["state"] == "complete", st
        assert arr.rebuilding_zones() == {}
        for z in range(3):
            assert arr.zone(z).state is not ZoneState.READ_ONLY
            assert np.array_equal(arr.read_zone(z), before[z])
        assert arr.zone(1).is_writable
        arr.zone_append(1, int32_blocks(2, seed=42))
        res = mgr.scrub()
        assert res["mismatches"] == 0
        assert res["zones_scrubbed"] == 3
        # the incident resolves on the next evaluation (monitor rebound)
        engine.evaluate()
        keys = engine.active("member_degraded")["member_degraded"]
        assert not any(k.startswith(f"member{victim}/") for k in keys)

    def test_offload_bit_identity_through_scheduler_with_metering(self):
        """Offloads running concurrently with the rebuild return the healthy
        answer bit-identically, and the copy traffic is metered on the
        'rebuild' tenant (scrub on 'scrub')."""
        arr = make_array(4, num_zones=3, zone_kib=128, redundancy="xor")
        for z in range(3):
            arr.zone_append(z, int32_blocks(arr.zone_blocks - 5, seed=z))
        sched = OffloadScheduler(arr, default_tier="interp")
        sched.start()
        try:
            prog = filter_sum("int32", "ge", 0)
            healthy = [sched.run_and_fetch(prog, z)[0] for z in range(3)]
            mgr = ArrayManager(arr, scheduler=sched,
                               spares=[make_device(3, 128)])
            kill_member(arr, 2)
            assert mgr.promote_spare(2, reason="test")
            # live offloads while the rebuild copies
            during = [sched.run_and_fetch(prog, z)[0] for z in range(3)]
            assert mgr.wait(timeout=60)
            assert mgr.status()[2]["state"] == "complete"
            after = [sched.run_and_fetch(prog, z)[0] for z in range(3)]
            assert during == healthy
            assert after == healthy
            res = mgr.scrub()
            assert res["mismatches"] == 0
            ts = sched.tenant_stats()
            assert ts["rebuild"]["ops"] > 0
            assert ts["rebuild"]["bytes"] > 0
            assert ts["scrub"]["ops"] > 0
        finally:
            sched.close()

    def test_promotion_is_idempotent(self):
        arr = make_array(2, num_zones=2, zone_kib=128, redundancy="raid1")
        arr.zone_append(0, int32_blocks(arr.zone_blocks, seed=1))
        mon = ArrayHealthMonitor(arr)
        engine = AlertEngine(rules=[HealthPromotionRule(mon)])
        mgr = ArrayManager(arr, spares=[make_device(2, 128),
                                        make_device(2, 128)], monitor=mon)
        mgr.attach(engine)
        mon.sample()
        kill_member(arr, 0)
        engine.evaluate()
        # alert re-fire / duplicated evaluation: no double promotion
        engine.evaluate()
        assert mgr.promote_spare(0) is False      # live rebuild: refused
        assert mgr.wait(timeout=60)
        assert mgr.status()[0]["state"] == "complete"
        assert mgr.spare_count == 1               # exactly ONE spare consumed

    def test_promotion_without_spares_reports_exhaustion(self):
        arr = make_array(2, num_zones=2, zone_kib=128, redundancy="raid1")
        arr.zone_append(0, int32_blocks(STRIPE))
        kill_member(arr, 0)
        mgr = ArrayManager(arr)
        assert mgr.promote_spare(0) is False
        assert event_log().snapshot(name="spare.exhausted")


# ----------------------------------------------------- faults mid-rebuild
class TestFaultsMidRebuild:
    def test_spare_death_mid_rebuild_restarts_onto_next_spare(self):
        """The spare dies after the first zone commits: the rebuild swaps
        in the next spare (committed zones re-enter the pending set) and
        still converges to bit-identical, fully writable zones."""
        arr = make_array(2, num_zones=3, zone_kib=128, redundancy="raid1")
        for z in range(3):
            arr.zone_append(z, int32_blocks(arr.zone_blocks // 2, seed=z))
        before = [arr.read_zone(z).copy() for z in range(3)]
        victim = 1
        kill_member(arr, victim)
        spare1 = make_device(3, 128)
        mgr = ArrayManager(arr, spares=[spare1, make_device(3, 128)])
        killed = threading.Event()
        # deterministic injection point: the moment the FIRST zone cuts
        # over, every further write to the spare fails (it died)
        orig_append = spare1.submit_append

        def dying_append(zone_id, data):
            if killed.is_set():
                raise ZNSError("injected: spare lost power mid-rebuild")
            return orig_append(zone_id, data)

        spare1.submit_append = dying_append

        def on_event(e):
            if e.name == "array.zone_rebuilt":
                killed.set()

        unsub = event_log().subscribe(on_event)
        try:
            assert mgr.promote_spare(victim)
            assert mgr.wait(timeout=60)
        finally:
            unsub()
        st = mgr.status()[victim]
        assert killed.is_set()
        assert st["restarts"] == 1, st
        assert st["state"] == "complete", st
        assert mgr.spare_count == 0
        for z in range(3):
            assert arr.zone(z).is_writable
            assert np.array_equal(arr.read_zone(z), before[z])
        assert event_log().snapshot(name="rebuild.restarted")

    def test_spare_death_with_empty_pool_degrades_cleanly(self):
        arr = make_array(2, num_zones=2, zone_kib=128, redundancy="raid1")
        for z in range(2):
            arr.zone_append(z, int32_blocks(arr.zone_blocks // 2, seed=z))
        before = [arr.read_zone(z).copy() for z in range(2)]
        kill_member(arr, 0)
        spare = make_device(2, 128)
        mgr = ArrayManager(arr, spares=[spare])
        killed = threading.Event()
        orig_append = spare.submit_append

        def dying_append(zone_id, data):
            if killed.is_set():
                raise ZNSError("injected: spare lost power mid-rebuild")
            return orig_append(zone_id, data)

        spare.submit_append = dying_append

        def on_event(e):
            if e.name == "array.zone_rebuilt":
                killed.set()

        unsub = event_log().subscribe(on_event)
        try:
            assert mgr.promote_spare(0)
            assert mgr.wait(timeout=60)
        finally:
            unsub()
        st = mgr.status()[0]
        assert st["state"] == "failed", st
        assert event_log().snapshot(name="rebuild.failed")
        # survivors still serve every committed byte
        for z in range(2):
            assert np.array_equal(arr.read_zone(z), before[z])

    def test_xor_double_fault_mid_rebuild_goes_offline_not_corrupt(self):
        """A survivor dies while a zone's rebuild still needs it: that zone
        is abandoned OFFLINE (never half-rebuilt data), other zones keep
        rebuilding, and the worker terminates — no hang."""
        arr = make_array(4, num_zones=3, zone_kib=128, redundancy="xor")
        for z in range(3):
            arr.zone_append(z, int32_blocks(arr.zone_blocks // 2, seed=z))
        before = [arr.read_zone(z).copy() for z in range(3)]
        victim, survivor = 1, 3
        kill_member(arr, victim)
        mgr = ArrayManager(arr, spares=[make_device(3, 128)])
        tripped = threading.Event()

        def on_event(e):
            if e.name == "array.zone_rebuilt" and not tripped.is_set():
                tripped.set()
                nxt = sorted(arr.rebuilding_zones())[0]
                arr.devices[survivor].set_offline(nxt)

        unsub = event_log().subscribe(on_event)
        try:
            assert mgr.promote_spare(victim)
            assert mgr.wait(timeout=60)      # bounded: no hang
        finally:
            unsub()
        st = mgr.status()[victim]
        assert tripped.is_set()
        assert st["state"] == "degraded", st
        assert len(st["zones_failed"]) == 1
        dead = st["zones_failed"][0]
        assert arr.zone(dead).state is ZoneState.OFFLINE
        with pytest.raises(ZoneStateError):
            arr.read_zone(dead)              # clean error, not garbage
        for z in range(3):
            if z != dead:
                assert arr.zone(z).is_writable
                assert np.array_equal(arr.read_zone(z), before[z])
        assert event_log().snapshot(name="rebuild.zone_failed")

    def test_checkpoint_restores_mid_rebuild(self, tmp_path):
        """A striped checkpoint restore riding a mid-rebuild array (zones
        marked, reads degraded) is bit-identical — and again after the
        rebuild commits."""
        rng = np.random.default_rng(3)
        tree = {"w": rng.standard_normal((64, 64)).astype(np.float32),
                "b": rng.integers(-5, 5, 4096, dtype=np.int64)}
        like = {"w": np.zeros((64, 64), np.float32),
                "b": np.zeros(4096, np.int64)}
        store = ZonedCheckpointStore.striped(
            tmp_path, num_devices=3, num_zones=6,
            member_zone_bytes=64 * 4096, stripe_blocks=4, redundancy="xor")
        store.save(1, tree)
        store.flush()
        arr = store.device
        kill_member(arr, 1)
        mgr = ArrayManager(arr, spares=[ZonedDevice(
            num_zones=6, zone_bytes=64 * 4096, block_bytes=BLOCK)])
        assert mgr.promote_spare(1)
        got = store.restore(like=like)       # races the rebuild by design
        assert np.array_equal(got["w"], tree["w"])
        assert np.array_equal(got["b"], tree["b"])
        assert mgr.wait(timeout=60)
        assert mgr.status()[1]["state"] == "complete"
        got2 = store.restore(like=like)
        assert np.array_equal(got2["w"], tree["w"])
        assert np.array_equal(got2["b"], tree["b"])
        assert mgr.scrub()["mismatches"] == 0


# ----------------------------------------------------------------- scrub
class TestScrub:
    def test_clean_array_scrubs_clean(self):
        arr = make_array(4, num_zones=2, zone_kib=128, redundancy="xor")
        arr.zone_append(0, int32_blocks(arr.zone_blocks, seed=1))
        mgr = ArrayManager(arr)
        res = mgr.scrub()
        assert res["mismatches"] == 0 and res["rows_verified"] > 0

    def test_raid1_mirror_divergence_detected_and_feeds_health(self):
        arr = make_array(2, num_zones=2, zone_kib=128, redundancy="raid1")
        arr.zone_append(0, int32_blocks(arr.zone_blocks // 2, seed=2))
        mon = ArrayHealthMonitor(arr)
        mon.sample()
        corrupt_block(arr.devices[1], 0, 5)
        mgr = ArrayManager(arr, monitor=mon)
        res = mgr.scrub()
        assert res["mismatches"] == 1
        assert arr.devices[1].metrics.counter("scrub_mismatches").value == 1
        ev = event_log().snapshot(name="scrub.mismatch")
        assert ev and ev[-1].tags["zone"] == 0
        assert mon.members[1].sample() >= HealthStatus.SUSPECT
        assert mon.members[1].smart_log()["scrub_mismatches"] == 1

    def test_xor_parity_rot_detected_on_full_row(self):
        arr = make_array(4, num_zones=2, zone_kib=128, redundancy="xor")
        row = STRIPE * arr.data_columns
        arr.zone_append(0, int32_blocks(3 * row, seed=3))
        # corrupt the rotating parity member of row 1
        _data, parity = arr._row_devices(1)
        corrupt_block(arr.devices[parity], 0, STRIPE + 1)
        res = ArrayManager(arr).scrub()
        assert res["mismatches"] == 1
        assert event_log().snapshot(name="scrub.mismatch")[-1].tags["row"] == 1

    def test_xor_tail_row_checked_against_accumulator(self):
        arr = make_array(4, num_zones=2, zone_kib=128, redundancy="xor")
        row = STRIPE * arr.data_columns
        arr.zone_append(0, int32_blocks(2 * row + STRIPE + 2, seed=4))
        data_devs, _parity = arr._row_devices(2)
        corrupt_block(arr.devices[data_devs[0]], 0, 2 * STRIPE)   # tail chunk
        res = ArrayManager(arr).scrub()
        assert res["mismatches"] == 1
        assert "tail" in event_log().snapshot(name="scrub.mismatch")[-1].message

    def test_scrub_skips_degraded_and_rebuilding_zones(self):
        arr = make_array(2, num_zones=3, zone_kib=128, redundancy="raid1")
        for z in range(2):
            arr.zone_append(z, int32_blocks(STRIPE, seed=z))
        kill_member(arr, 0, zones=[0])
        res = ArrayManager(arr).scrub()
        assert res["zones_skipped"] == 1
        assert res["zones_scrubbed"] == 1

    def test_raid0_has_nothing_to_scrub(self):
        arr = make_array(2, num_zones=2, zone_kib=128, redundancy="raid0")
        res = ArrayManager(arr).scrub()
        assert res["zones_scrubbed"] == 0 and res["zones_skipped"] == 2
