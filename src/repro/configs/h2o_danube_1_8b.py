"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

SWA makes this arch sub-quadratic: the decode KV cache is a ring buffer of
the window, so ``long_500k`` runs with bounded state.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=64, attn_chunk=32,
    )
