"""Pure-jnp oracle for the zone_filter kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["zone_filter_count_ref", "zone_reduce_ref"]


def zone_filter_count_ref(pages: jnp.ndarray, threshold) -> jnp.ndarray:
    """Count elements strictly greater than threshold (paper Fig.2 op).
    pages: [n_pages, page_elems]."""
    return (pages > jnp.asarray(threshold, pages.dtype)).sum(dtype=jnp.int32)


def zone_reduce_ref(pages: jnp.ndarray, kind: str, threshold=None) -> jnp.ndarray:
    """Filtered reduction oracle. kind in {count,sum,min,max}; elements
    participate iff > threshold (or all, when threshold is None)."""
    x = pages
    if threshold is not None:
        mask = x > jnp.asarray(threshold, x.dtype)
    else:
        mask = jnp.ones(x.shape, bool)
    if kind == "count":
        return mask.sum(dtype=jnp.int32)
    if kind == "sum":
        # integer sums stay integer: f32 accumulation is only exact to 2^24
        # (hypothesis found the divergence at ~2e8) — match the kernel's
        # exact i32 partials for int inputs
        if x.dtype.kind != "f":
            return jnp.where(mask, x, 0).sum(dtype=jnp.int32)
        return jnp.where(mask, x, 0).astype(jnp.float32).sum()
    if kind == "min":
        big = jnp.asarray(jnp.finfo(jnp.float32).max if x.dtype.kind == "f"
                          else jnp.iinfo(x.dtype).max, x.dtype)
        return jnp.where(mask, x, big).min()
    if kind == "max":
        small = jnp.asarray(jnp.finfo(jnp.float32).min if x.dtype.kind == "f"
                            else jnp.iinfo(x.dtype).min, x.dtype)
        return jnp.where(mask, x, small).max()
    raise ValueError(kind)
