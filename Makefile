# CI entry points. `make ci` is what the tier-1 gate runs: the full pytest
# suite plus a fast benchmark smoke (filter + array scaling + hot-path
# accounting) that also emits the machine-readable BENCH_hotpath.json.
PYTHONPATH := src:$(PYTHONPATH)
export PYTHONPATH

.PHONY: test smoke ci bench bench-smoke

test:
	python -m pytest -x -q

smoke:
	python benchmarks/run.py --only filter,array,hotpath --json

# hot-path regression tripwire: the CI-size filter+array suites must fit the
# wall-clock budget (measured ~7s on 2 cores incl. compiles; ~10x headroom so
# only a real regression, not scheduler noise, trips it)
bench-smoke:
	python benchmarks/run.py --only filter,array --budget 90

ci: test smoke

bench:
	python benchmarks/run.py
