"""Append-only benchmark trajectories.

``run.py --json`` used to overwrite each ``BENCH_*.json`` with the latest
run, so the perf history across PRs lived only in git archaeology. Each
file is now a trajectory document::

    {"trajectory": [ {..payload.., "timestamp": "..."}, ... ]}

Every ``--json`` run APPENDS a timestamped entry; a legacy single-object
file (the pre-trajectory format: a bare ``{"suites": ...}`` payload) is
migrated in place on first write by becoming the trajectory's first entry
(with ``timestamp: null`` — its run time was never recorded).
"""
from __future__ import annotations

import json
import os
import time

__all__ = ["append_entry", "MAX_ENTRIES"]

# bound the file size: benchmarks run per-PR, so 200 entries is years of
# history; the oldest entries fall off first
MAX_ENTRIES = 200


def _load_trajectory(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            old = json.load(f)
    except (json.JSONDecodeError, OSError):
        return []   # corrupt file: start a fresh trajectory, don't crash CI
    if isinstance(old, dict) and isinstance(old.get("trajectory"), list):
        return old["trajectory"]
    if isinstance(old, dict):
        # legacy single-object payload -> first trajectory entry
        old.setdefault("timestamp", None)
        return [old]
    return []


def append_entry(path: str, payload: dict) -> dict:
    """Append ``payload`` (timestamped now) to the trajectory at ``path``,
    migrating a legacy single-object file on first write. Returns the full
    document written."""
    entry = dict(payload)
    entry["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    trajectory = _load_trajectory(path)
    trajectory.append(entry)
    doc = {"trajectory": trajectory[-MAX_ENTRIES:]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return doc
