"""Emulated NVMe Zoned Namespace (ZNS) device.

Semantics mirror the NVMe ZNS command set the paper targets (TP 4053, ratified
June 2020):

  * the LBA space is divided into fixed-size zones;
  * writes within a zone are append-only at the zone's write pointer
    ("Zone Append" command);
  * no in-place updates -- rewriting requires a host-managed ``reset_zone``;
  * zones move through an explicit state machine
    EMPTY -> (IMPLICITLY) OPEN -> FULL, with FINISH and RESET transitions
    driven by the host;
  * reads are block (LBA) granular and bounds-checked against the write
    pointer.

The device is backed either by host memory (default; fast, used by tests and
the data/KV substrates) or by a memory-mapped file (persistence for the
checkpoint store). Emulation knobs (``read_us_per_block``/``append_us_per_block``)
let benchmarks model device bandwidth, as QEMU does for the paper; transfer
timing runs through per-zone virtual-time queues retired by a shared
:class:`~repro.zns.ring.IoReactor`, so ``submit_read``/``submit_append`` keep
arbitrarily many transfers in flight without a thread per transfer (the
NVMe-style asynchronous interface the paper's device sits behind).
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
import weakref
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import numpy as np

from repro.faults.errors import (IoTimeoutError, TornAppendError,
                                 TransientIOError)
from repro.faults.injector import FaultDecision
from repro.faults.retry import RetryPolicy, drive_retries
from repro.telemetry import trace as _trace
from repro.telemetry.events import Severity as _Sev, publish as _publish_event
from repro.telemetry.metrics import MetricsRegistry, StatsView
from repro.zns.ring import CompletionRing, IoFuture, IoReactor

# the "no injector attached" decision and the "no policy set" policy: a
# single attempt, no backoff, no timeout — byte-for-byte the legacy behavior
_NO_FAULT = FaultDecision()
_SINGLE_ATTEMPT = RetryPolicy(max_attempts=1, backoff_base_s=0.0,
                              timeout_s=None)

__all__ = [
    "ZoneState",
    "Zone",
    "ZonedDevice",
    "ZNSError",
    "ZoneFullError",
    "ZoneStateError",
    "OutOfBoundsError",
    "payload_as_uint8",
]


def payload_as_uint8(data: np.ndarray | bytes | bytearray) -> np.ndarray:
    """Coerce an append payload to a flat uint8 stream.

    The ONE coercion shared by :meth:`ZonedDevice.zone_append` and the striped
    array's logical append — a drift between the two would silently corrupt
    stripe interleaving, so it lives here once.
    """
    if isinstance(data, (bytes, bytearray, memoryview)):
        return np.frombuffer(data, dtype=np.uint8)
    return np.ascontiguousarray(data).view(np.uint8).reshape(-1)


def block_aligned_dtype(block_bytes: int, dtype: np.dtype | str) -> np.dtype:
    """Validate that ``dtype`` elements tile a block exactly and return the
    normalized dtype — the ONE alignment rule behind every typed read
    (sync/async, device/array); a drift between those paths would silently
    retype extents differently."""
    dtype = np.dtype(dtype)
    if block_bytes % dtype.itemsize:
        raise ValueError(
            f"block size {block_bytes} not a multiple of "
            f"{dtype} itemsize {dtype.itemsize}")
    return dtype


class ZNSError(Exception):
    """Base error for ZNS protocol violations."""


class ZoneFullError(ZNSError):
    """Append past the end of a zone."""


class ZoneStateError(ZNSError):
    """Operation illegal in the zone's current state."""


class OutOfBoundsError(ZNSError):
    """Read beyond the write pointer / zone capacity."""


class ZoneState(enum.Enum):
    EMPTY = "empty"
    OPEN = "open"           # implicitly opened by a first append
    FULL = "full"           # write pointer reached capacity or host FINISHed
    READ_ONLY = "read_only" # host transitioned (e.g. sealed checkpoint zone)
    OFFLINE = "offline"     # dead zone (injected for fault-tolerance tests)


@dataclass
class Zone:
    """Descriptor for one zone (mirrors the ZNS Zone Descriptor)."""

    zone_id: int
    start_lba: int            # first block of the zone in device LBA space
    capacity_blocks: int      # writable blocks in the zone
    write_pointer: int = 0    # next writable block, relative to start_lba
    state: ZoneState = ZoneState.EMPTY
    # Number of times this zone has been reset (wear proxy; the paper's GC
    # statistics build on host-visible reset counts).
    reset_count: int = 0
    cond: threading.Condition = field(
        default_factory=threading.Condition, repr=False, compare=False
    )
    # Virtual-time I/O queue at ZONE granularity: transfers against one zone
    # retire behind each other (one flash die), transfers against different
    # zones of the same device overlap — the intra-device parallelism real
    # ZNS hardware exposes (arXiv:2310.19094). ``io_busy_until`` is the
    # monotonic instant the zone's die goes idle; a new transfer's completion
    # deadline is max(now, io_busy_until) + service, and the clock advances
    # to that deadline — the old ``io_gate`` sleep-under-lock semantics with
    # no thread parked per transfer.
    io_busy_until: float = 0.0
    io_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )
    # tail of the zone's timed-transfer chain (see IoFuture._prev): keeps
    # already-due submissions from retiring ahead of an in-flight predecessor.
    # A WEAK reference — in-flight futures are strongly held by the reactor
    # heap/submitter, and a retired tail must not pin its payload value until
    # the zone's next transfer arrives.
    io_tail: Optional[weakref.ref] = field(
        default=None, repr=False, compare=False
    )

    @property
    def remaining_blocks(self) -> int:
        return self.capacity_blocks - self.write_pointer

    @property
    def is_writable(self) -> bool:
        return self.state in (ZoneState.EMPTY, ZoneState.OPEN)


_DEV_SEQ = itertools.count()  # stable per-process device ordinals for traces


class ZonedDevice:
    """An emulated ZNS SSD: ``num_zones`` zones of ``zone_blocks`` blocks of
    ``block_bytes`` bytes.

    Defaults follow the paper's evaluation: 4 KiB blocks and 256 MiB zones
    (65536 blocks/zone).
    """

    def __init__(
        self,
        num_zones: int = 8,
        zone_bytes: int = 256 * 1024 * 1024,
        block_bytes: int = 4096,
        backing_file: Optional[Path | str] = None,
        read_us_per_block: float = 0.0,
        append_us_per_block: float = 0.0,
        max_open_zones: int = 0,  # 0 = unlimited (QEMU default)
        reactor: Optional[IoReactor] = None,
        fault_injector=None,
        fault_key=None,
        retry_policy: Optional[RetryPolicy] = None,
    ):
        if zone_bytes % block_bytes != 0:
            raise ValueError("zone_bytes must be a multiple of block_bytes")
        self.num_zones = int(num_zones)
        self.block_bytes = int(block_bytes)
        self.zone_blocks = int(zone_bytes // block_bytes)
        self.zone_bytes = int(zone_bytes)
        self.read_us_per_block = float(read_us_per_block)
        self.append_us_per_block = float(append_us_per_block)
        self.max_open_zones = int(max_open_zones)
        # all devices share one process-wide reactor by default: a single
        # thread retires every emulated in-flight transfer, like an NVMe CQ
        self.reactor = reactor if reactor is not None else IoReactor.default()
        self._lock = threading.RLock()

        total_bytes = self.num_zones * self.zone_bytes
        if backing_file is not None:
            path = Path(backing_file)
            mode = "r+" if path.exists() and path.stat().st_size == total_bytes else "w+"
            self._buf = np.memmap(path, dtype=np.uint8, mode=mode, shape=(total_bytes,))
            self._backing_file = path
        else:
            self._buf = np.zeros(total_bytes, dtype=np.uint8)
            self._backing_file = None

        self.zones = [
            Zone(zone_id=z, start_lba=z * self.zone_blocks,
                 capacity_blocks=self.zone_blocks)
            for z in range(self.num_zones)
        ]
        # device-level statistics (host-visible, like NVMe log pages);
        # bytes_copied/bytes_viewed account host-side data movement: the copy
        # path duplicates the extent into host memory, the view path hands out
        # an alias of the backing buffer (zero host copies). Backed by the
        # telemetry registry — devices exist in unbounded numbers (tests make
        # thousands), so each owns a PRIVATE registry rather than polluting
        # the process-global one; ``stats`` keeps the legacy dict shape.
        self.dev_ordinal = next(_DEV_SEQ)
        self._devname = f"dev{self.dev_ordinal}"
        self.metrics = MetricsRegistry(f"dev{self.dev_ordinal}")
        self._c_blocks_read = self.metrics.counter("blocks_read")
        self._c_blocks_appended = self.metrics.counter("blocks_appended")
        self._c_zone_resets = self.metrics.counter("zone_resets")
        self._c_zone_finishes = self.metrics.counter("zone_finishes")
        self._c_bytes_copied = self.metrics.counter("bytes_copied")
        self._c_bytes_viewed = self.metrics.counter("bytes_viewed")
        # SMART-style error/transition counters: protocol+media errors per
        # direction and host-visible zone degradations — the raw attributes
        # DeviceHealthMonitor reads to compute the composite status.
        self._c_read_errors = self.metrics.counter("read_errors")
        self._c_append_errors = self.metrics.counter("append_errors")
        self._c_zone_ro_transitions = self.metrics.counter(
            "zone_readonly_transitions")
        self._c_zone_off_transitions = self.metrics.counter(
            "zone_offline_transitions")
        # Transient-fault accounting, deliberately SEPARATE from the hard
        # read/append error counters: an injected media error that a retry
        # absorbs is a soft signal (SUSPECT at worst), only an exhausted
        # retry budget escalates into read_errors/append_errors and the
        # degraded/rebuild pipeline.
        self._c_retries = self.metrics.counter("retries")
        self._c_io_timeouts = self.metrics.counter("io_timeouts")
        self._c_faults_injected = self.metrics.counter("faults_injected")
        self.stats = StatsView({
            "blocks_read": self._c_blocks_read,
            "blocks_appended": self._c_blocks_appended,
            "zone_resets": self._c_zone_resets,
            "zone_finishes": self._c_zone_finishes,
            "bytes_copied": self._c_bytes_copied,
            "bytes_viewed": self._c_bytes_viewed,
            "read_errors": self._c_read_errors,
            "append_errors": self._c_append_errors,
            "retries": self._c_retries,
            "io_timeouts": self._c_io_timeouts,
            "faults_injected": self._c_faults_injected,
        })
        # Service/queue-wait distributions for emulated (timed) transfers
        # only — the zero-service fast path stays metric-free.
        self._h_read_service = self.metrics.histogram("read.service_seconds")
        self._h_read_wait = self.metrics.histogram("read.wait_seconds")
        self._h_append_service = self.metrics.histogram("append.service_seconds")
        self._h_append_wait = self.metrics.histogram("append.wait_seconds")
        # Fault-injection wiring (see repro.faults): when either knob is set
        # the submit paths take the retrying/faulty branch; with both unset
        # every path below is byte-for-byte the legacy fast path.
        self.fault_injector = fault_injector
        self.fault_key = fault_key if fault_key is not None else self.dev_ordinal
        self.retry_policy = retry_policy
        # append listeners: ``fn(device, zone_id, start_rel, nblocks, fut)``
        # called at submission, BEFORE the future can retire — the crash
        # harness journals durable appends by attaching done-callbacks here.
        self._append_listeners: list = []

    def add_append_listener(self, fn) -> None:
        """Observe every async append submission: ``fn(device, zone_id,
        start_rel, nblocks, fut)`` runs after the data effect lands and
        before the completion can retire, so a listener's done-callback on
        ``fut`` fires ahead of any caller-attached callback."""
        self._append_listeners.append(fn)

    @property
    def _faulty(self) -> bool:
        return self.fault_injector is not None or self.retry_policy is not None

    # ------------------------------------------------------------------ zones
    def zone(self, zone_id: int) -> Zone:
        if not 0 <= zone_id < self.num_zones:
            raise OutOfBoundsError(f"zone {zone_id} out of range [0,{self.num_zones})")
        return self.zones[zone_id]

    def report_zones(self) -> list[Zone]:
        """ZNS 'Zone Management Receive / Report Zones'."""
        return list(self.zones)

    def open_zones(self) -> list[Zone]:
        return [z for z in self.zones if z.state == ZoneState.OPEN]

    # ----------------------------------------------------------------- append
    def _do_append(self, zone_id: int, data: np.ndarray | bytes) -> tuple[Zone, int, int]:
        """The append data effect under the device lock: state machine checks,
        buffer write, write-pointer advance. Returns (zone, start_rel, nblocks).
        Timing (the emulated transfer) is layered on by the callers."""
        raw = payload_as_uint8(data)
        nblocks = -(-raw.size // self.block_bytes)  # ceil
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.EMPTY:
                if self.max_open_zones and len(self.open_zones()) >= self.max_open_zones:
                    self._c_append_errors.inc()
                    raise ZoneStateError("max open zones exceeded")
                z.state = ZoneState.OPEN
            if not z.is_writable:
                self._c_append_errors.inc()
                raise ZoneStateError(f"zone {zone_id} not writable (state={z.state})")
            if nblocks > z.remaining_blocks:
                self._c_append_errors.inc()
                raise ZoneFullError(
                    f"append of {nblocks} blocks exceeds zone {zone_id} "
                    f"remaining {z.remaining_blocks}"
                )
            start_rel = z.write_pointer
            off = (z.start_lba + start_rel) * self.block_bytes
            self._buf[off : off + raw.size] = raw
            pad = nblocks * self.block_bytes - raw.size
            if pad:
                self._buf[off + raw.size : off + raw.size + pad] = 0
            z.write_pointer += nblocks
            if z.write_pointer == z.capacity_blocks:
                z.state = ZoneState.FULL
            self._c_blocks_appended.inc(nblocks)
            return z, start_rel, nblocks

    def _do_append_torn(self, zone_id: int, data: np.ndarray | bytes,
                        keep_frac: float) -> tuple[Zone, int, int, int]:
        """Torn-append data effect: the command claimed ``nblocks`` but only
        a prefix of ``kept`` blocks reached the media before it failed — the
        write pointer advances by ``kept`` and the zone is left
        host-indeterminate, exactly the anomaly the crash/fencing machinery
        exists to contain. Same protocol checks as :meth:`_do_append` (a
        torn append is a *media* fault layered on a legal command). Returns
        ``(zone, start_rel, nblocks, kept)``."""
        raw = payload_as_uint8(data)
        nblocks = -(-raw.size // self.block_bytes)  # ceil
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.EMPTY:
                if self.max_open_zones and len(self.open_zones()) >= self.max_open_zones:
                    self._c_append_errors.inc()
                    raise ZoneStateError("max open zones exceeded")
                z.state = ZoneState.OPEN
            if not z.is_writable:
                self._c_append_errors.inc()
                raise ZoneStateError(f"zone {zone_id} not writable (state={z.state})")
            if nblocks > z.remaining_blocks:
                self._c_append_errors.inc()
                raise ZoneFullError(
                    f"append of {nblocks} blocks exceeds zone {zone_id} "
                    f"remaining {z.remaining_blocks}"
                )
            kept = min(nblocks - 1, max(1, int(round(nblocks * keep_frac))))
            start_rel = z.write_pointer
            off = (z.start_lba + start_rel) * self.block_bytes
            nbytes = min(raw.size, kept * self.block_bytes)
            self._buf[off : off + nbytes] = raw[:nbytes]
            z.write_pointer += kept
            self._c_blocks_appended.inc(kept)
            return z, start_rel, nblocks, kept

    def zone_append(self, zone_id: int, data: np.ndarray | bytes) -> int:
        """ZNS 'Zone Append': write ``data`` at the zone's write pointer.

        ``data`` must be a whole number of blocks (the device pads the final
        block with zeros, as a ZNS host library would). Returns the starting
        block index *relative to the zone* at which data landed. Synchronous:
        blocks for the emulated transfer time; the async path is
        :meth:`submit_append`.
        """
        if self._faulty:
            # the sync shim over the faulty async path: same injector
            # consultation, same retry/timeout behavior as submit_append
            return self.submit_append(zone_id, data).result()
        with self._lock:
            z, start_rel, nblocks = self._do_append(zone_id, data)
            deadline, service = self._claim_slot(
                z, nblocks, self.append_us_per_block, op="append")
        self._sleep_until(deadline, service)
        return start_rel

    def submit_append(self, zone_id: int, data: np.ndarray | bytes, *,
                      ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous Zone Append: the write lands immediately (metadata and
        bytes, under the device lock), the returned future retires at the
        zone's emulated completion deadline with the landing block as its
        value — real ZNS Zone Append also reports the assigned LBA only in
        the completion entry. ``fut.submitted_block`` exposes the landing
        block synchronously for emulation-internal consumers (stripe desync
        checks)."""
        if self._faulty:
            return self._submit_append_faulty(zone_id, data, ring=ring)
        with self._lock:
            z, start_rel, nblocks = self._do_append(zone_id, data)
            fut = IoFuture(op="append", zone_id=zone_id, block_off=start_rel,
                           nblocks=nblocks, ring=ring)
            fut.submitted_block = start_rel
            fut._value = start_rel
            fut.device = self._devname
            deadline, service = self._claim_slot(
                z, nblocks, self.append_us_per_block, fut, op="append")
            fut.service_seconds = service
        for fn in self._append_listeners:
            fn(self, zone_id, start_rel, nblocks, fut)
        return self.reactor.schedule(fut, deadline)

    # ------------------------------------------------- fault-injected paths
    def _fault_hooks(self, op: str, zone_id: int, err_counter):
        """Build the retry controller's ``on_*`` hooks for one logical op:
        soft-counter increments plus ``io.*`` events tagged with the
        device's stable fault key (``member``), published outside any lock
        (the hooks run as completion/timer callbacks)."""
        dev = self._devname
        member = self.fault_key

        def on_retry(attempt, err):
            self._c_retries.inc()
            _publish_event(
                "io.retry", severity=_Sev.WARNING,
                message=f"{dev} {op} zone {zone_id} attempt {attempt} "
                        f"failed ({type(err).__name__}); retrying",
                device=dev, member=member, zone=zone_id, op=op,
                attempt=attempt, error=type(err).__name__)

        def on_timeout(attempt, err):
            self._c_io_timeouts.inc()
            _publish_event(
                "io.timeout", severity=_Sev.ERROR,
                message=f"{dev} {op} zone {zone_id} attempt {attempt} "
                        f"exceeded its timeout budget",
                device=dev, member=member, zone=zone_id, op=op,
                attempt=attempt)

        def on_exhausted(attempt, err):
            err_counter.inc()
            _publish_event(
                "io.retry_exhausted", severity=_Sev.ERROR,
                message=f"{dev} {op} zone {zone_id} gave up after "
                        f"{attempt} attempt(s): {type(err).__name__}",
                device=dev, member=member, zone=zone_id, op=op,
                attempt=attempt, error=type(err).__name__)

        def timeout_error(attempt):
            return IoTimeoutError(
                f"{op} on {dev} zone {zone_id} attempt {attempt} exceeded "
                f"its timeout budget", op=op, device=dev, zone_id=zone_id,
                attempt=attempt)

        return on_retry, on_timeout, on_exhausted, timeout_error

    def _submit_read_faulty(self, zone_id: int, block_off: int, nblocks: int,
                            *, dtype=None, copy: bool = False,
                            ring: Optional[CompletionRing] = None) -> IoFuture:
        """submit_read with the injector/retry machinery engaged: every
        attempt re-snapshots the span, consults the injector, and stages a
        value OR an error completion on its attempt future; the caller sees
        one aggregate future the retry controller resolves."""
        inj = self.fault_injector
        policy = self.retry_policy or _SINGLE_ATTEMPT
        dev = self._devname
        key = self.fault_key
        agg = IoFuture(op="read", zone_id=zone_id, block_off=block_off,
                       nblocks=nblocks, ring=ring)
        agg.device = dev

        def submit_attempt(attempt: int) -> Optional[IoFuture]:
            with self._lock:
                z, span = self._read_span(zone_id, block_off, nblocks,
                                          copy=copy)
                if dtype is not None:
                    span = span.view(dtype)
                d = inj.decide(key, "read", zone_id, nblocks,
                               retry=attempt > 1) if inj else _NO_FAULT
                if d.kind is not None or d.extra_latency_s:
                    self._c_faults_injected.inc()
                if d.kind == "hang":
                    return None       # completion lost; only a timeout helps
                fut = IoFuture(op="read", zone_id=zone_id,
                               block_off=block_off, nblocks=nblocks)
                fut.device = dev
                if d.kind is not None:
                    fut._error = TransientIOError(
                        f"injected media error: read {dev} zone {zone_id} "
                        f"attempt {attempt}", op="read", device=dev,
                        zone_id=zone_id, attempt=attempt)
                else:
                    fut._value = span
                deadline, service = self._claim_slot(
                    z, nblocks, self.read_us_per_block, fut,
                    extra_s=d.extra_latency_s)
                fut.service_seconds = service
            return self.reactor.schedule(fut, deadline)

        on_retry, on_timeout, on_exhausted, timeout_error = \
            self._fault_hooks("read", zone_id, self._c_read_errors)
        jitter = (lambda: inj.jitter01(key, "read")) if inj \
            else (lambda: 0.5)
        first = (submit_attempt(1),)  # protocol errors raise synchronously,
        return drive_retries(         # exactly like the fault-free path
            agg, policy=policy, reactor=self.reactor, submit=submit_attempt,
            jitter01=jitter, on_retry=on_retry, on_timeout=on_timeout,
            on_exhausted=on_exhausted, timeout_error=timeout_error,
            first=first)

    def _submit_append_faulty(self, zone_id: int, data, *,
                              ring: Optional[CompletionRing] = None) -> IoFuture:
        """submit_append with the injector/retry machinery engaged.

        The data effect happens exactly ONCE, at the first submission, under
        the device lock — an injected media error is a *completion status*
        (the payload landed; the device reported failure), so a retry only
        replays the completion for the same landing block. A torn append
        lands a prefix and fails with the non-retryable
        :class:`TornAppendError`; a hung append lands its payload but its
        completion never arrives."""
        inj = self.fault_injector
        policy = self.retry_policy or _SINGLE_ATTEMPT
        dev = self._devname
        key = self.fault_key
        raw = payload_as_uint8(data)
        est = -(-raw.size // self.block_bytes)  # ceil

        with self._lock:
            d = inj.decide(key, "append", zone_id, est) if inj else _NO_FAULT
            if d.kind is not None or d.extra_latency_s:
                self._c_faults_injected.inc()
            deadline = 0.0
            if d.kind == "torn":
                z, start_rel, nblocks, kept = self._do_append_torn(
                    zone_id, raw, d.torn_keep)
                first_fut = IoFuture(op="append", zone_id=zone_id,
                                     block_off=start_rel, nblocks=nblocks)
                first_fut.device = dev
                first_fut._error = TornAppendError(
                    f"injected torn append: {dev} zone {zone_id} landed "
                    f"{kept}/{nblocks} blocks", op="append", device=dev,
                    zone_id=zone_id)
                deadline, service = self._claim_slot(
                    z, kept, self.append_us_per_block, first_fut,
                    op="append", extra_s=d.extra_latency_s)
                first_fut.service_seconds = service
            else:
                z, start_rel, nblocks = self._do_append(zone_id, raw)
                if d.kind == "hang":
                    first_fut = None   # payload durable; completion lost
                else:
                    first_fut = IoFuture(op="append", zone_id=zone_id,
                                         block_off=start_rel, nblocks=nblocks)
                    first_fut.device = dev
                    if d.kind is not None:
                        first_fut._error = TransientIOError(
                            f"injected media error: append {dev} zone "
                            f"{zone_id} attempt 1", op="append", device=dev,
                            zone_id=zone_id, attempt=1)
                    else:
                        first_fut._value = start_rel
                    deadline, service = self._claim_slot(
                        z, nblocks, self.append_us_per_block, first_fut,
                        op="append", extra_s=d.extra_latency_s)
                    first_fut.service_seconds = service
            agg = IoFuture(op="append", zone_id=zone_id, block_off=start_rel,
                           nblocks=nblocks, ring=ring)
            agg.device = dev
            agg.submitted_block = start_rel
        for fn in self._append_listeners:
            fn(self, zone_id, start_rel, nblocks, agg)
        if first_fut is not None:
            self.reactor.schedule(first_fut, deadline)

        def submit_attempt(attempt: int) -> Optional[IoFuture]:
            # the payload is already durable at start_rel (the ZNS append
            # data effect is once-only); a retry replays the completion
            d = inj.decide(key, "append", zone_id, nblocks,
                           retry=True) if inj else _NO_FAULT
            if d.kind is not None or d.extra_latency_s:
                self._c_faults_injected.inc()
            if d.kind == "hang":
                return None
            z = self.zone(zone_id)
            fut = IoFuture(op="append", zone_id=zone_id, block_off=start_rel,
                           nblocks=nblocks)
            fut.device = dev
            if d.kind is not None:
                fut._error = TransientIOError(
                    f"injected media error: append {dev} zone {zone_id} "
                    f"attempt {attempt}", op="append", device=dev,
                    zone_id=zone_id, attempt=attempt)
            else:
                fut._value = start_rel
            deadline, service = self._claim_slot(
                z, nblocks, self.append_us_per_block, fut, op="append",
                extra_s=d.extra_latency_s)
            fut.service_seconds = service
            return self.reactor.schedule(fut, deadline)

        on_retry, on_timeout, on_exhausted, timeout_error = \
            self._fault_hooks("append", zone_id, self._c_append_errors)
        jitter = (lambda: inj.jitter01(key, "append")) if inj \
            else (lambda: 0.5)
        return drive_retries(
            agg, policy=policy, reactor=self.reactor, submit=submit_attempt,
            jitter01=jitter, on_retry=on_retry, on_timeout=on_timeout,
            on_exhausted=on_exhausted, timeout_error=timeout_error,
            first=(first_fut,))

    # ------------------------------------------------------------------- read
    def _claim_slot(self, z: Zone, nblocks: int, us_per_block: float,
                    fut: Optional[IoFuture] = None,
                    op: str = "read", extra_s: float = 0.0) -> tuple[float, float]:
        """Reserve this transfer's slot in the zone's virtual-time queue.

        Returns ``(completion_deadline, service_seconds)``. Same-zone
        transfers get non-decreasing deadlines (they queue behind one die);
        different zones advance independent clocks (they overlap). A
        zero-service transfer on an idle zone costs nothing and completes
        inline; on a busy zone it still queues behind the in-flight work.
        When ``fut`` is given it is linked behind the zone's previous timed
        transfer, so completions of one zone retire strictly in submission
        order even when the reactor lags wall-clock.

        Callers claim while still holding the device lock (the same critical
        section that landed the data / snapshotted the read span), so a
        zone's virtual-time order can never invert against its data order —
        two racing appends complete in the order their bytes landed.
        ``extra_s`` adds an injected latency spike to the service time (it
        occupies the zone's die like real slow media would).
        """
        service = nblocks * us_per_block * 1e-6 + extra_s
        if not service and not z.io_busy_until:
            return 0.0, 0.0            # non-emulated fast path: no lock
        now = time.monotonic()
        with z.io_lock:
            start = max(now, z.io_busy_until)
            deadline = start + service
            z.io_busy_until = deadline
            if fut is not None:
                fut._prev = z.io_tail() if z.io_tail is not None else None
                z.io_tail = weakref.ref(fut)
        if op == "read":
            self._h_read_service.observe(service)
            self._h_read_wait.observe(start - now)
        else:
            self._h_append_service.observe(service)
            self._h_append_wait.observe(start - now)
        if _trace.enabled():
            # Device VIRTUAL time: the transfer occupies the zone's die for
            # [start, start+service) on the monotonic clock — emit it now,
            # before it elapses, onto the device's own trace track.
            _trace.event_complete(
                f"dev.{op}", start, service,
                track=f"dev{self.dev_ordinal}/z{z.zone_id}",
                zone=z.zone_id, nblocks=nblocks,
                wait_us=round((start - now) * 1e6, 1))
        return deadline, service

    @staticmethod
    def _sleep_until(deadline: float, service: float) -> None:
        """Synchronous tail of a transfer: sleep (no lock held) until the
        claimed completion deadline — the blocking shim over the same clock
        the reactor-backed submit paths use, so sync and async transfers
        against one zone serialize with each other."""
        if service:
            delay = deadline - time.monotonic()
            if delay > 0:
                time.sleep(delay)

    def _read_span(self, zone_id: int, block_off: int, nblocks: int,
                   *, copy: bool) -> tuple[Zone, np.ndarray]:
        """Bounds-check a read and return (zone, buffer) under ONE lock
        acquisition: an owned copy (``copy=True``, atomic w.r.t. writers) or
        a read-only view of the backing buffer. Byte accounting happens here
        too, so the hot path never re-takes the lock."""
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.OFFLINE:
                self._c_read_errors.inc()
                raise ZoneStateError(f"zone {zone_id} is offline")
            if block_off < 0 or nblocks < 0 or block_off + nblocks > z.write_pointer:
                self._c_read_errors.inc()
                raise OutOfBoundsError(
                    f"read [{block_off},{block_off + nblocks}) beyond write pointer "
                    f"{z.write_pointer} of zone {zone_id}"
                )
            off = (z.start_lba + block_off) * self.block_bytes
            span = self._buf[off : off + nblocks * self.block_bytes]
            self._c_blocks_read.inc(nblocks)
            if copy:
                span = np.array(span)
                self._c_bytes_copied.inc(span.nbytes)
            else:
                span = span.view()
                span.flags.writeable = False
                self._c_bytes_viewed.inc(span.nbytes)
            return z, span

    def read_blocks(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Read ``nblocks`` blocks starting at ``block_off`` (zone-relative).

        Bounds-checked against the write pointer: reading unwritten blocks is
        a protocol error (this is the check the offloaded program's
        ``bpf_read`` hook relies on). Returns an owned COPY taken under the
        device lock (atomic even against a host that resets and rewrites the
        zone mid-read); the offload hot path uses :meth:`read_blocks_view` /
        :meth:`read_extent` instead.
        """
        if self._faulty:
            return self.submit_read(zone_id, block_off, nblocks,
                                    copy=True).result()
        with self._lock:
            z, out = self._read_span(zone_id, block_off, nblocks, copy=True)
            deadline, service = self._claim_slot(
                z, nblocks, self.read_us_per_block)
        self._sleep_until(deadline, service)
        return out

    def read_blocks_view(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Zero-copy variant of :meth:`read_blocks`: returns a read-only uint8
        VIEW of the device's backing buffer.

        The view stays valid as long as the extent is not rewritten (zones are
        append-only, so written blocks only change across a host-driven
        ``reset_zone`` — rewriting an extent while a reader holds it is a
        host protocol bug, exactly as it would be on real hardware).
        Consumers that feed XLA hand this view straight to the executable —
        the device-internal DMA the paper models, with at most the one copy
        XLA itself makes on device_put.
        """
        if self._faulty:
            return self.submit_read(zone_id, block_off, nblocks,
                                    copy=False).result()
        with self._lock:
            z, view = self._read_span(zone_id, block_off, nblocks, copy=False)
            deadline, service = self._claim_slot(
                z, nblocks, self.read_us_per_block)
        self._sleep_until(deadline, service)
        return view

    def submit_read(self, zone_id: int, block_off: int, nblocks: int, *,
                    dtype: Optional[np.dtype | str] = None, copy: bool = False,
                    ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous read: enqueue a transfer descriptor and return an
        :class:`~repro.zns.ring.IoFuture` that retires at the zone's emulated
        completion deadline with the extent as its value — a read-only view
        of the backing buffer by default (``copy=True`` for an owned copy),
        reinterpreted as ``dtype`` elements when given.

        The bounds check and buffer slice happen at submission under the
        device lock; zones are append-only, so the snapshot cannot change
        before the completion retires (rewriting an extent under an in-flight
        read is a host protocol bug, as on real hardware). One reactor thread
        drives any number of these in flight — in-flight depth is bounded by
        the emulated device, not by a thread pool.
        """
        if dtype is not None:
            dtype = block_aligned_dtype(self.block_bytes, dtype)
        if self._faulty:
            return self._submit_read_faulty(zone_id, block_off, nblocks,
                                            dtype=dtype, copy=copy, ring=ring)
        with self._lock:
            z, span = self._read_span(zone_id, block_off, nblocks, copy=copy)
            if dtype is not None:
                span = span.view(dtype)
            fut = IoFuture(op="read", zone_id=zone_id, block_off=block_off,
                           nblocks=nblocks, ring=ring)
            fut._value = span
            fut.device = self._devname
            deadline, service = self._claim_slot(
                z, nblocks, self.read_us_per_block, fut)
            fut.service_seconds = service
        return self.reactor.schedule(fut, deadline)

    def read_extent(self, zone_id: int, block_off: int, nblocks: int,
                    dtype: np.dtype | str) -> np.ndarray:
        """Dtype-typed zero-copy read: :meth:`read_blocks_view` reinterpreted
        as ``dtype`` elements. Block offsets are always block-aligned in the
        backing buffer, which is stricter than any supported element
        alignment, so the reinterpretation never copies."""
        dtype = block_aligned_dtype(self.block_bytes, dtype)
        return self.read_blocks_view(zone_id, block_off, nblocks).view(dtype)

    def read_zone(self, zone_id: int) -> np.ndarray:
        """Read every written block of a zone."""
        z = self.zone(zone_id)
        return self.read_blocks(zone_id, 0, z.write_pointer)

    # -------------------------------------------------------- zone management
    def finish_zone(self, zone_id: int) -> None:
        """ZNS 'Zone Management Send / Finish': host seals the zone."""
        with self._lock:
            z = self.zone(zone_id)
            if z.state not in (ZoneState.EMPTY, ZoneState.OPEN, ZoneState.FULL):
                raise ZoneStateError(f"cannot finish zone in state {z.state}")
            z.state = ZoneState.FULL
            self._c_zone_finishes.inc()

    def set_read_only(self, zone_id: int) -> None:
        with self._lock:
            z = self.zone(zone_id)
            changed = z.state is not ZoneState.READ_ONLY
            z.state = ZoneState.READ_ONLY
            if changed:
                self._c_zone_ro_transitions.inc()
        if changed:
            # outside the device lock: event subscribers may re-enter the
            # device (a dashboard polling report_zones must not deadlock)
            _publish_event(
                "zone.read_only", severity=_Sev.WARNING,
                message=f"dev{self.dev_ordinal} zone {zone_id} -> READ_ONLY",
                device=f"dev{self.dev_ordinal}", zone=zone_id)

    def reset_zone(self, zone_id: int) -> None:
        """ZNS 'Zone Management Send / Reset': host-managed GC.

        All data in the zone is discarded and the write pointer rewinds to 0.
        This is the paper's host-visible garbage-collection primitive.
        """
        with self._lock:
            z = self.zone(zone_id)
            if z.state == ZoneState.OFFLINE:
                raise ZoneStateError(f"zone {zone_id} is offline")
            z.write_pointer = 0
            z.state = ZoneState.EMPTY
            z.reset_count += 1
            self._c_zone_resets.inc()

    def set_offline(self, zone_id: int, *, quiet: bool = False) -> None:
        """Fault injection: mark a zone dead (used by fault-tolerance tests).

        ``quiet=True`` marks the zone OFFLINE as a *placeholder* — the array
        manager parks a hot spare's zones this way until rebuild delivers
        their data — so neither the SMART ``zone_offline_transitions``
        counter nor the ``zone.offline`` event fires: the spare did not
        fail, it just must not serve reads it does not hold yet."""
        with self._lock:
            z = self.zone(zone_id)
            changed = z.state is not ZoneState.OFFLINE
            z.state = ZoneState.OFFLINE
            if changed and not quiet:
                self._c_zone_off_transitions.inc()
        if changed and not quiet:
            _publish_event(
                "zone.offline", severity=_Sev.ERROR,
                message=f"dev{self.dev_ordinal} zone {zone_id} -> OFFLINE",
                device=f"dev{self.dev_ordinal}", zone=zone_id)

    def revive_zone(self, zone_id: int) -> None:
        """Bring an OFFLINE zone back as EMPTY with a rewound write pointer —
        the media-replacement primitive rebuild-to-spare needs (the spare's
        placeholder zones are revived one at a time as reconstruction
        reaches them). Only OFFLINE zones revive: any other state holds live
        protocol state a silent rewind would corrupt."""
        with self._lock:
            z = self.zone(zone_id)
            if z.state is not ZoneState.OFFLINE:
                raise ZoneStateError(
                    f"zone {zone_id} not offline (state={z.state}): only "
                    f"offline zones can be revived")
            z.write_pointer = 0
            z.state = ZoneState.EMPTY

    # ------------------------------------------------------------------ misc
    def flush(self) -> None:
        if self._backing_file is not None:
            self._buf.flush()

    @property
    def lba_size(self) -> int:
        """Block size in bytes (the ``bpf_get_lba_size`` hook's answer)."""
        return self.block_bytes

    def utilization(self) -> float:
        written = sum(z.write_pointer for z in self.zones)
        return written / float(self.num_zones * self.zone_blocks)
