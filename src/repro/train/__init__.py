from repro.train.optimizer import (
    AdamWHyper,
    adamw_state_specs,
    adamw_update,
)
from repro.train.step import TrainHyper, make_train_step, train_state_specs

__all__ = [
    "AdamWHyper", "adamw_state_specs", "adamw_update",
    "TrainHyper", "make_train_step", "train_state_specs",
]
