"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000; no biases, parallel attention+FFN blocks, LayerNorm.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    norm="layer",
    use_bias=False,
    parallel_block=True,
    rope_theta=75000000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, attn_chunk=32,
    )
