"""CSD array scaling: aggregate offload throughput from 1 to 8 devices.

A fixed logical dataset is striped across N member devices whose read
bandwidth is emulated (``read_us_per_block``, QEMU-style, as the paper does
for its single device). The :class:`~repro.array.OffloadScheduler` fans a
verified filter-count offload out across the members concurrently, so the
aggregate device bandwidth — the bottleneck of any real CSD array — scales
with N while the per-command result stays identical.

Reported per width: steady-state offload microseconds, aggregate throughput
in MiB/s of zone data scanned, and the speedup vs the 1-device array (the
degenerate ``NvmCsd`` path). The paper's thesis at fleet scale: bytes moved
to the host stay constant (8 per offload) while scan throughput multiplies.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1


def run_scaling(
    *,
    widths: tuple[int, ...] = (1, 2, 4, 8),
    data_mib: int = 16,
    stripe_blocks: int = 64,
    read_us_per_block: float = 2.0,
    runs: int = 3,
    seed: int = 0,
) -> list[dict]:
    """Same logical data on arrays of increasing width; offload throughput
    must rise monotonically with the member count."""
    data_bytes = data_mib * 1024 * 1024
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)

    out: list[dict] = []
    for n in widths:
        devices = [
            ZonedDevice(num_zones=1, zone_bytes=data_bytes,
                        block_bytes=4096,
                        read_us_per_block=read_us_per_block)
            for _ in range(n)
        ]
        with StripedZoneArray(devices, stripe_blocks=stripe_blocks) as array:
            array.zone_append(0, data)
            with OffloadScheduler(array) as sched:
                stats = sched.nvm_cmd_bpf_run(program, 0)  # warm-up pays the JIT
                jit_seconds = stats.jit_seconds
                times = []
                for _ in range(runs):
                    t = time.perf_counter()
                    stats = sched.nvm_cmd_bpf_run(program, 0)
                    times.append(time.perf_counter() - t)
                assert int(sched.nvm_cmd_bpf_result()) == expected
        seconds = float(np.mean(times))
        out.append({
            "devices": n,
            "seconds": seconds,
            "mib_per_s": data_mib / seconds,
            "jit_seconds": jit_seconds,
            "chunks": stats.n_chunks,
            "batched": stats.batched_chunks,
            "bytes_to_host": stats.bytes_returned,
        })
    return out


def main(data_mib: int = 16, runs: int = 3) -> list[str]:
    rows = []
    results = run_scaling(data_mib=data_mib, runs=runs)
    base = results[0]["seconds"]
    for r in results:
        rows.append(
            f"array_{r['devices']}dev,{r['seconds'] * 1e6:.0f},"
            f"mib_per_s={r['mib_per_s']:.1f};speedup={base / r['seconds']:.2f}x;"
            f"chunks={r['chunks']};batched={r['batched']};"
            f"bytes_to_host={r['bytes_to_host']}"
        )
    return rows


if __name__ == "__main__":
    for row in main(data_mib=64, runs=3):
        print(row)
