"""Serving steps: prefill (fill a cache from a prompt) and decode (one new
token against a seq_len-deep cache). ``serve_step`` is what the decode_* and
long_* dry-run cells lower."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward
from repro.models.config import ModelConfig

__all__ = ["make_serve_step", "make_prefill_step"]


def make_serve_step(cfg: ModelConfig, sample: str = "greedy"):
    def serve_step(params, cache, tokens, pos):
        """tokens: [B, 1] current token; pos: scalar position. Returns
        (next_token [B, 1], logits [B, V], new_cache)."""
        logits, cache = decode_step(cfg, params, cache, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        """Run the prompt through the model, returning (last_logits, cache)."""
        logits, _, caches = forward(cfg, params, batch, collect_cache=True,
                                    remat=False)
        return logits[:, -1, :], caches

    return prefill_step
