from repro.kernels.paged_attn.ops import paged_attention

__all__ = ["paged_attention"]
