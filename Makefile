# CI entry points. `make ci` is what the tier-1 gate runs: the FAST pytest
# tier — everything not marked `slow` (the emulation-sleep and big-model
# compile tests; run the complete suite with `make test-all`) — plus a fast
# benchmark smoke (filter + array scaling + hot-path accounting + async
# completion-ring scaling + redundancy/degraded reads) that emits the
# machine-readable BENCH_hotpath.json, BENCH_async.json and
# BENCH_degraded.json.
PYTHONPATH := src:$(PYTHONPATH)
export PYTHONPATH

.PHONY: test test-all smoke ci bench bench-smoke trace-smoke lint bench-report

test:
	python -m pytest -x -q -m "not slow"

# the complete suite, slow tier included (coverage identical to the
# pre-split `make test`)
test-all:
	python -m pytest -x -q

smoke:
	python benchmarks/run.py --only filter,array,hotpath,async,degraded,health,rebuild,faults --json

# hot-path regression tripwire: the CI-size suites must fit the wall-clock
# budget (measured ~10s on 2 cores incl. compiles; ~9x headroom so only a
# real regression, not scheduler noise, trips it). The async suite asserts
# its own queue-depth tripwire: depth-8 throughput must exceed depth-1 (and
# beat 4 thread-blocking workers), and the overlapped checkpoint save must
# beat the serialized sequence. The degraded suite asserts the redundancy
# tripwires: healthy raid1 reads beat the raid0 floor, degraded reads hold
# the single-device floor, degraded offload results stay bit-identical.
# The profile suite asserts the observability tripwires: >=90% wall-time
# attribution on the traced fan-out, and disabled-tracing instrumentation
# cost under 3% of the single-device offload row. The health suite asserts
# the injected-fault pipeline end to end (SMART counters -> SUSPECT event
# -> DEGRADED alert + callback -> per-tenant degraded-read accounting) and
# the event-log publish cost under 3% of the single-device read row. The
# rebuild suite asserts unattended recovery (member death -> alert-path
# spare promotion -> online rebuild concurrent with bit-identical offloads
# -> writable zones -> clean scrub) and the xor double-fault containment.
# The faults suite asserts the transient-error tripwires: 1%/5% injected
# read-error rates leave offload results bit-identical with bounded p99 and
# nobody ejected, the retry-storm rule pages, and the power-loss crash
# sweep recovers a committed checkpoint (or refuses cleanly) at every
# member append-completion boundary. The array suite is the scaling-cliff
# tripwire: bench_array ASSERTS monotonic 1->8-device offload throughput
# and near-linear 1->4 (so a change that re-serializes host work behind
# the staged read -> batched-compute -> combine pipeline fails bench-smoke,
# it does not just drift a JSON number).
bench-smoke:
	python benchmarks/run.py --only filter,array,async,degraded,profile,health,rebuild,faults --budget 120

# tiny traced offload, then validate the exported Chrome trace-event JSON
# (Perfetto-loadable): the end-to-end check that virtual device tracks and
# host spans land on one timeline
trace-smoke:
	python benchmarks/trace_smoke.py

# static checks when the linter is available; the container image does not
# guarantee ruff, so its absence skips (loudly) rather than failing CI
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src benchmarks tests; \
	else \
		echo "ruff not installed; skipping lint"; \
	fi

# latest-vs-best across every checked-in benchmark trajectory
bench-report:
	python benchmarks/trajectory.py

ci: lint test smoke trace-smoke

bench:
	python benchmarks/run.py
