"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM LMs; the
per-arch modules in ``repro.configs`` instantiate it with the exact published
numbers and provide a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_applicable"]


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attn-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention flavor
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # SWA (h2o-danube / mistral-style)
    attn_logit_softcap: Optional[float] = None

    # norms / act / bias
    norm: str = "rms"                 # rms | layer
    activation: str = "silu"          # silu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False      # GPT-J/command-r parallel attn+mlp
    scale_embeddings: bool = False    # gemma-style sqrt(d) embedding scale

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0              # per-expert hidden dim
    moe_groups: int = 32              # dispatch groups (GShard-style 'G')
    moe_capacity_factor: float = 1.25
    first_layer_dense: bool = False   # deepseek-moe: layer 0 is a dense MLP
    dense_layer_d_ff: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (recurrentgemma): block pattern repeated over depth
    block_pattern: tuple[str, ...] = ()   # e.g. ("rglru","rglru","local_attn")
    local_window: int = 2048
    rglru_c: float = 8.0

    # enc-dec
    encoder_layers: int = 0           # >0 => encoder-decoder
    encoder_seq_factor: float = 1.0   # encoder frames per decoder token

    # VLM: cross-attention layer stride (llama-3.2-vision: every 5th, offset 3)
    cross_attn_stride: int = 0
    cross_attn_offset: int = 3
    num_image_tokens: int = 0

    # precision / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"               # full | none | blocks:<k>
    attn_chunk: int = 1024            # KV/Q block size for chunked attention
    # fully unroll layer scans (cost-probe lowering: XLA's HloCostAnalysis
    # counts while-loop bodies ONCE, so the roofline probes unroll)
    scan_unroll: bool = False

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    @property
    def d_inner(self) -> int:  # ssm
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def kv_groups(self) -> int:
        return max(self.num_heads // max(self.num_kv_heads, 1), 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- param counting
    def param_count(self) -> int:
        """Total parameters (embedding included), matching the layer defs."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = V * d                                    # embed
        if not self.tie_embeddings:
            n += V * d                               # lm head
        def attn_params():
            return d * H * hd + 2 * d * KV * hd + H * hd * d
        def mlp_params(ff):
            # gated (swiglu/geglu): 3 matrices; plain MLP: 2
            k = 3 if self.activation in ("silu", "gelu_glu") else 2
            return k * d * ff
        def norms():
            return 2 * d
        if self.family == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per = (d * (2 * di + 2 * ns + nh)        # in_proj (z,x,B,C,dt)
                   + self.ssm_conv * (di + 2 * ns)   # conv
                   + nh * 2                          # A_log, D
                   + nh                              # dt bias
                   + di * d + d)                     # out_proj + norm
            return n + self.num_layers * per
        if self.family == "hybrid":
            per_attn = attn_params() + mlp_params(f) + 3 * d
            di = int(1.0 * d)                        # rglru width multiplier 1
            per_rec = (d * di * 2                    # in gates (x, gate branch)
                       + self.ssm_conv * di          # conv1d
                       + 2 * di                      # rg-lru input/rec gates diag-ish
                       + 2 * di * di // max(di // di, 1) * 0  # (block-diag approx 0)
                       + di * 2                      # a_param, (sqrt gate)
                       + di * d                      # out proj
                       + mlp_params(f) + 3 * d)
            pat = self.block_pattern or ("attn",)
            n_attn = sum(1 for i in range(self.num_layers)
                         if pat[i % len(pat)] == "local_attn")
            n_rec = self.num_layers - n_attn
            # rg-lru gates are full [di, di] block-diagonal with 1 block here
            per_rec += 0
            return n + n_attn * per_attn + n_rec * per_rec
        per = norms()
        if self.family in ("dense", "vlm", "encdec"):
            per += attn_params() + mlp_params(f)
        if self.family == "moe":
            per += attn_params()
            per += d * self.num_experts                       # router
            per += self.num_experts * 3 * d * self.expert_d_ff
            per += self.num_shared_experts * 3 * d * self.expert_d_ff
            per += d  # extra norm-ish
        total = n + self.num_layers * per
        if self.family == "vlm" and self.cross_attn_stride:
            n_cross = len([i for i in range(self.num_layers)
                           if i % self.cross_attn_stride == self.cross_attn_offset])
            total += n_cross * (attn_params() + 2 * d)
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted above
            total += self.encoder_layers * (attn_params() + mlp_params(f) + norms())
            # decoder cross-attention blocks
            total += self.num_layers * (attn_params() + d)
        if self.family == "moe" and self.first_layer_dense:
            total += 3 * d * self.dense_layer_d_ff - (
                d * self.num_experts
                + self.num_experts * 3 * d * self.expert_d_ff
                + self.num_shared_experts * 3 * d * self.expert_d_ff
            )
        return total

    def active_param_count(self) -> int:
        """Params active per token (= param_count for dense archs)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        routed_all = self.num_layers * self.num_experts * 3 * self.d_model * self.expert_d_ff
        routed_active = self.num_layers * self.moe_top_k * 3 * self.d_model * self.expert_d_ff
        return full - routed_all + routed_active


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(applicable, reason). long_500k only for sub-quadratic archs
    (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.is_sub_quadratic:
        return False, (
            f"{cfg.arch_id} is pure full-attention; 524288-token dense KV decode "
            "is quadratic — skipped per assignment"
        )
    return True, ""
