"""Pallas TPU kernel: streaming filtered reduction over a zone.

This is the paper's Figure 2 hot loop (predicate over 64Mi integers at page
granularity) re-tiled for the TPU memory hierarchy:

  * the zone lives in HBM as ``[n_pages, page_elems]``;
  * the grid streams fixed *blocks* of pages through VMEM
    (``BlockSpec((pages_per_block, page_elems))``) — the paper's
    "CSD DRAM is small, process per page" constraint becomes
    "the working set must fit the ~16 MiB VMEM";
  * each grid step reduces its block on the VPU and accumulates into a
    per-block partials vector; only partials (n_blocks values, not the
    zone) leave the kernel — near-data processing at the HBM boundary.

Program transforms (the eBPF-analogue ALU/CMP chain) are traced into the
kernel body as fused elementwise ops, so one kernel serves every verified
program with a reduce terminal.

Alignment: ``page_elems`` (1024 int32 for the paper's 4 KiB pages) is a
multiple of the 128-lane VPU width; ``pages_per_block`` is a multiple of 8
sublanes.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["filtered_reduce_pallas", "DEFAULT_BLOCK_PAGES"]

DEFAULT_BLOCK_PAGES = 512   # 512 pages x 4 KiB = 2 MiB block in VMEM


def _reduce_kernel(x_ref, out_ref, *, transform, kind, acc_dtype):
    """One grid step: reduce one VMEM block to one partial."""
    x = x_ref[...]
    vals, mask = transform(x)
    if kind == "count":
        out_ref[0] = jnp.sum(mask.astype(jnp.int32))
    elif kind == "sum":
        out_ref[0] = jnp.sum(jnp.where(mask, vals, 0).astype(acc_dtype))
    elif kind == "min":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).max
        out_ref[0] = jnp.min(jnp.where(mask, vals, ident))
    elif kind == "max":
        ident = (jnp.finfo if vals.dtype.kind == "f" else jnp.iinfo)(vals.dtype).min
        out_ref[0] = jnp.max(jnp.where(mask, vals, ident))
    else:
        raise ValueError(kind)


def filtered_reduce_pallas(
    pages: jnp.ndarray,
    *,
    kind: str = "count",
    transform: Optional[Callable] = None,
    block_pages: int = DEFAULT_BLOCK_PAGES,
    interpret: bool = True,
) -> jnp.ndarray:
    """Filtered reduction over a zone buffer [n_pages, page_elems].

    ``transform(x) -> (vals, mask)`` is the fused program chain (defaults to
    the identity with an all-true mask). Returns a scalar: int32 count,
    f32/i64-widened sum, or the dtype min/max.

    ``interpret=True`` runs the kernel body on CPU (validation); on TPU pass
    ``interpret=False``.
    """
    n_pages, page_elems = pages.shape
    bp = min(block_pages, n_pages)
    while n_pages % bp:
        bp -= 1
    n_blocks = n_pages // bp
    if transform is None:
        transform = lambda x: (x, jnp.ones(x.shape, bool))

    if kind == "count":
        acc_dtype = jnp.int32
    elif kind == "sum":
        acc_dtype = jnp.float32 if pages.dtype.kind == "f" else jnp.int32
    else:
        acc_dtype = pages.dtype

    kernel = functools.partial(_reduce_kernel, transform=transform, kind=kind,
                               acc_dtype=acc_dtype)
    partials = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((bp, page_elems), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), acc_dtype),
        interpret=interpret,
    )(pages)

    # final tree-reduce of the tiny partials vector (fused into the same jit)
    if kind == "count":
        return partials.sum(dtype=jnp.int32)
    if kind == "sum":
        return partials.astype(jnp.float32).sum() if acc_dtype == jnp.float32 \
            else partials.sum(dtype=jnp.int32)
    if kind == "min":
        return partials.min()
    return partials.max()
