"""Pure-jnp oracle for zoned-KV paged decode attention."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["paged_attention_ref"]


def paged_attention_ref(q, k_zones, v_zones, zone_table, lengths):
    """Flash-decode over a zoned KV cache — reference semantics.

    q:          [B, H, hd]           query for the current token
    k_zones:    [NZ, ZL, KV, hd]     global zone pool (append-only KV zones)
    v_zones:    [NZ, ZL, KV, hd]
    zone_table: [B, MZ] int32        zone ids per sequence (-1 = unused)
    lengths:    [B] int32            total valid tokens per sequence
    returns:    [B, H, hd]
    """
    B, H, hd = q.shape
    NZ, ZL, KV, _ = k_zones.shape
    MZ = zone_table.shape[1]
    G = H // KV

    # gather each sequence's zones -> a contiguous [B, MZ*ZL, KV, hd] view
    safe = jnp.maximum(zone_table, 0)                      # [B, MZ]
    k = k_zones[safe].reshape(B, MZ * ZL, KV, hd)
    v = v_zones[safe].reshape(B, MZ * ZL, KV, hd)
    pos = jnp.arange(MZ * ZL)[None, :]                     # [1, S]
    valid = (pos < lengths[:, None]) & jnp.repeat(
        zone_table >= 0, ZL, axis=1)

    qh = q.reshape(B, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("bkgh,bskh->bkgs", qh, k.astype(jnp.float32))
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    att = jnp.exp(logits - logits.max(-1, keepdims=True))
    att = att / att.sum(-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", att, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
