"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE, LayerNorm + biases, plain (non-gated) GELU MLP.
[arXiv:2402.19173; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    norm="layer",
    activation="gelu",
    use_bias=True,
    rope_theta=999999.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, attn_chunk=32,
    )
