"""Offload hot path: zero-copy reads, per-zone bandwidth-emulation locking,
the shared compile cache, prefetch overlap, the grid-batched Pallas tier, and
the Kahan float-SUM combiner's cross-width determinism."""
import threading
import time

import numpy as np
import pytest

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import (
    CompiledProgramCache,
    CsdTier,
    LookaheadReader,
    NvmCsd,
    filter_count,
    filter_sum,
    prefetched,
    run_oracle,
)
from repro.core.programs import SUPPORTED_DTYPES, Instruction, OpCode, Program
from repro.kernels.zone_filter import ops as zf_ops
from repro.zns import ZonedDevice

BLOCK = 4096


def make_device(n_blocks=16, num_zones=2, **kw):
    return ZonedDevice(num_zones=num_zones, zone_bytes=n_blocks * BLOCK,
                       block_bytes=BLOCK, **kw)


def typed_blocks(dtype, n_blocks, seed=0):
    rng = np.random.default_rng(seed)
    n = n_blocks * BLOCK // np.dtype(dtype).itemsize
    if np.dtype(dtype).kind == "f":
        return (rng.standard_normal(n) * 1000).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(max(info.min, -1000), min(info.max, 1000), n,
                        dtype=dtype)


# ------------------------------------------------------------- zero-copy reads

def test_read_blocks_view_is_zero_copy_and_read_only():
    dev = make_device()
    data = typed_blocks(np.int32, 4)
    dev.zone_append(0, data)
    view = dev.read_blocks_view(0, 0, 4)
    assert view.base is not None                 # aliases the backing buffer
    assert not view.flags.writeable
    with pytest.raises(ValueError):
        view[0] = 1
    assert dev.stats["bytes_copied"] == 0
    assert dev.stats["bytes_viewed"] == 4 * BLOCK
    # the copy path still copies (and says so)
    out = dev.read_blocks(0, 0, 4)
    out[0] = 255                                 # owned, mutable
    assert dev.stats["bytes_copied"] == 4 * BLOCK
    assert np.array_equal(np.asarray(view).view(np.int32), data)


@pytest.mark.parametrize("dtype", SUPPORTED_DTYPES)
@pytest.mark.parametrize("block_off,n_blocks", [(0, 8), (1, 4), (3, 5)])
def test_read_extent_matches_oracle_every_dtype(dtype, block_off, n_blocks):
    """The typed view must carry the exact bytes the copy path carries —
    checked against run_oracle over the same extent, including block offsets
    not aligned to the extent start."""
    dev = make_device()
    data = typed_blocks(dtype, 8, seed=3)
    dev.zone_append(0, data)
    view = dev.read_extent(0, block_off, n_blocks, dtype)
    per_block = BLOCK // np.dtype(dtype).itemsize
    want = data[block_off * per_block:(block_off + n_blocks) * per_block]
    assert np.array_equal(view, want)
    program = filter_count(dtype, "gt", 0)
    assert int(run_oracle(program, view)) == int(run_oracle(program, want))
    # and the CSD's JIT tier over the same extent agrees with the oracle
    csd = NvmCsd(dev)
    got, _ = csd.run_and_fetch(program, 0, block_off=block_off,
                               n_blocks=n_blocks, tier=CsdTier.JIT)
    assert int(got) == int(run_oracle(program, want))


def test_striped_array_read_extent_round_trip():
    devs = [make_device(n_blocks=8) for _ in range(3)]
    arr = StripedZoneArray(devs, stripe_blocks=2)
    data = typed_blocks(np.int64, 10, seed=7)
    arr.zone_append(0, data)
    view = arr.read_extent(0, 1, 7, np.int64)
    per_block = BLOCK // 8
    assert np.array_equal(view, data[per_block:8 * per_block])
    assert not view.flags.writeable
    # stripe gather is the single counted copy
    assert arr.stats["bytes_copied"] == 7 * BLOCK


def test_jit_offload_makes_zero_host_copies():
    dev = make_device()
    dev.zone_append(0, typed_blocks(np.int32, 8))
    csd = NvmCsd(dev)
    program = filter_count("int32", "lt", 0)
    csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
    assert dev.stats["bytes_copied"] == 0
    assert dev.stats["bytes_viewed"] == 8 * BLOCK


# ------------------------------------------- bandwidth emulation outside lock

def test_reads_of_different_zones_overlap():
    """Per-zone I/O gating: two threads reading different zones of ONE device
    must overlap their emulated transfer time; same-zone reads queue."""
    dev = make_device(n_blocks=8, num_zones=2,
                      read_us_per_block=20_000)     # 20 ms per block
    for z in (0, 1):
        dev.zone_append(z, typed_blocks(np.int32, 5, seed=z))

    def read(zone):
        dev.read_blocks_view(zone, 0, 5)            # 100 ms emulated

    t0 = time.perf_counter()
    threads = [threading.Thread(target=read, args=(z,)) for z in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    cross_zone = time.perf_counter() - t0
    assert cross_zone < 0.17, f"cross-zone reads serialized: {cross_zone:.3f}s"

    t0 = time.perf_counter()
    threads = [threading.Thread(target=read, args=(0,)) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    same_zone = time.perf_counter() - t0
    assert same_zone >= 0.19, f"same-zone reads overlapped: {same_zone:.3f}s"


# ------------------------------------------------------------- compile cache

def test_compile_cache_shared_across_csd_instances():
    shared = CompiledProgramCache()
    program = filter_sum("int32", "gt", 0)
    results, stats = [], []
    for seed in range(2):
        dev = make_device()
        dev.zone_append(0, typed_blocks(np.int32, 8, seed=1))
        csd = NvmCsd(dev, cache=shared)
        st = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.JIT)
        stats.append(st)
        results.append(int(csd.nvm_cmd_bpf_result()))
    assert results[0] == results[1]
    assert stats[0].jit_seconds > 0.0 and stats[0].cache_misses == 1
    assert stats[1].jit_seconds == 0.0 and stats[1].cache_hits == 1
    cs = shared.stats()
    assert cs.hits == 1 and cs.misses == 1


def test_compile_cache_covers_kernel_tier():
    shared = CompiledProgramCache()
    program = filter_count("int32", "ge", 10)
    sts = []
    for _ in range(2):
        dev = make_device()
        dev.zone_append(0, typed_blocks(np.int32, 8, seed=2))
        csd = NvmCsd(dev, cache=shared)
        sts.append(csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.KERNEL))
    assert sts[0].cache_misses == 1 and sts[0].jit_seconds > 0.0
    assert sts[1].cache_hits == 1 and sts[1].jit_seconds == 0.0


def test_compile_cache_bounded_with_eviction_stats():
    cache = CompiledProgramCache(capacity=2)

    class Fake:
        compile_seconds = 0.01

    for i in range(4):
        cache.get_or_build(("k", i), Fake)
    assert len(cache) == 2
    cs = cache.stats()
    assert cs.evictions == 2 and cs.misses == 4 and cs.size == 2
    # LRU: most recent keys survive
    assert ("k", 3) in cache and ("k", 0) not in cache


def test_cache_thread_safe_compile_once():
    cache = CompiledProgramCache()
    built = []

    class Slow:
        compile_seconds = 0.0

        def __init__(self):
            built.append(1)
            time.sleep(0.02)

    threads = [threading.Thread(
        target=lambda: cache.get_or_build("same", Slow)) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(built) == 1                       # compile-once under races
    assert cache.stats().hits == 7


# ------------------------------------------------------------------ prefetch

def test_prefetched_preserves_order_and_errors():
    import concurrent.futures
    items = list(range(10))

    def fetch(i):
        if i == 7:
            raise RuntimeError("boom")
        return i * i

    with concurrent.futures.ThreadPoolExecutor(2) as pool:
        it = prefetched(items, fetch, executor=pool, depth=2)
        got = [next(it) for _ in range(7)]
        assert got == [i * i for i in range(7)]
        with pytest.raises(RuntimeError, match="boom"):
            next(it)
    # degenerate: no executor -> sequential, still ordered
    assert list(prefetched([1, 2, 3], lambda x: x + 1)) == [2, 3, 4]


def test_lookahead_reader_sequential_contract():
    reads = []

    def fetch(p):
        reads.append(p)
        return np.full(4, p)

    with LookaheadReader(fetch, 5, depth=2) as reader:
        for p in range(5):
            assert np.array_equal(reader(p), np.full(4, p))
    assert reads == list(range(5))
    with LookaheadReader(fetch, 5, depth=2) as reader:
        reader(0)
        with pytest.raises(ValueError, match="sequential"):
            reader(2)


def test_interp_lookahead_with_emulated_latency_matches_oracle():
    dev = make_device(read_us_per_block=50.0)
    data = typed_blocks(np.int32, 8, seed=9)
    dev.zone_append(0, data)
    csd = NvmCsd(dev)
    program = filter_count("int32", "le", -100)
    stats = csd.nvm_cmd_bpf_run(program, 0, tier=CsdTier.INTERP)
    assert int(csd.nvm_cmd_bpf_result()) == int(run_oracle(program, data))
    assert stats.read_seconds > 0.0              # lookahead path engaged


# ------------------------------------------------- grid-batched Pallas tier

KERNEL_PROGRAMS = [
    filter_count("int32", "gt", 0),
    Program("int32", (Instruction(OpCode.ABS), Instruction(OpCode.RED_MAX)),
            name="abs_max"),
    Program("int32", (Instruction(OpCode.CMP_LT, 500),
                      Instruction(OpCode.RED_MIN)), name="lt_min"),
    Program("float32", (Instruction(OpCode.MUL, 2.0),
                        Instruction(OpCode.CMP_GE, 10.0),
                        Instruction(OpCode.RED_SUM)), name="scaled_fsum"),
]


@pytest.mark.parametrize("program", KERNEL_PROGRAMS,
                         ids=[p.name for p in KERNEL_PROGRAMS])
def test_batched_kernel_matches_per_chunk_kernel(program):
    """One grid-batched Pallas call == per-chunk kernel calls, bit for bit
    (same block tiling per chunk)."""
    dtype = np.dtype(program.input_dtype)
    pages = np.asarray(typed_blocks(dtype, 24, seed=4)).reshape(
        6, 4, BLOCK // dtype.itemsize)
    single = np.stack([np.asarray(zf_ops.run_program_kernel(program, c))
                       for c in pages])
    batched = np.asarray(zf_ops.run_program_kernel_batched(program, pages))
    assert batched.shape == (6,)
    assert np.array_equal(single, batched)


def test_scheduler_kernel_tier_batches_all_full_chunks():
    """Acceptance: a kernel-tier striped offload executes as ONE grid-batched
    Pallas call per device group (batched_chunks == n_chunks) and matches the
    single-device kernel result bit for bit."""
    data = typed_blocks(np.int32, 40, seed=5)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    devs = [ZonedDevice(num_zones=4, zone_bytes=256 * 1024, block_bytes=BLOCK)
            for _ in range(4)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    arr.zone_append(0, data)
    program = filter_count("int32", "gt", 0)
    want, want_stats = NvmCsd(dev).run_and_fetch(program, 0,
                                                 tier=CsdTier.KERNEL)
    with OffloadScheduler(arr) as sched:
        got, stats = sched.run_and_fetch(program, 0, tier=CsdTier.KERNEL)
    assert int(got) == int(want)
    assert stats.tier == CsdTier.KERNEL
    assert stats.n_chunks == 10
    assert stats.batched_chunks == stats.n_chunks
    assert want_stats.tier == CsdTier.KERNEL


# --------------------------------------------- float SUM width determinism

@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_float_sum_bitwise_identical_across_widths(dtype):
    """ROADMAP open item: the Kahan-compensated combiner makes a 4-wide
    array's float SUM bit-identical to a 1-wide array's over the same
    logical data (same stripe geometry => same chunk partials)."""
    data = typed_blocks(dtype, 40, seed=11)
    program = filter_sum(dtype, "gt", -1e6)      # sums ~everything
    results = []
    for width in (1, 2, 4):
        devs = [ZonedDevice(num_zones=1, zone_bytes=1024 * 1024,
                            block_bytes=BLOCK) for _ in range(width)]
        arr = StripedZoneArray(devs, stripe_blocks=4)
        arr.zone_append(0, data)
        with OffloadScheduler(arr) as sched:
            got, _ = sched.run_and_fetch(program, 0, tier=CsdTier.JIT)
        results.append(np.float64(got))
    assert results[0] == results[1] == results[2]   # bitwise, no tolerance
    # and the compensated result is at least as close to the exact sum as a
    # naive left-to-right partial re-add would be
    exact = float(np.sum(data[data > -1e6], dtype=np.longdouble))
    assert abs(float(results[0]) - exact) <= abs(
        float(np.sum(data[data > -1e6], dtype=np.float64)) - exact) + 1e-6


# ------------------------------------------------------------- stats surface

def test_offload_stats_surface_read_cache_and_overlap_fields():
    data = typed_blocks(np.int32, 40, seed=13)
    devs = [ZonedDevice(num_zones=1, zone_bytes=1024 * 1024, block_bytes=BLOCK,
                        read_us_per_block=5.0) for _ in range(4)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    arr.zone_append(0, data)
    with OffloadScheduler(arr) as sched:
        s1 = sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
        s2 = sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    assert s1.cache_misses > 0 and s1.jit_seconds > 0.0
    assert s2.cache_misses == 0 and s2.cache_hits > 0
    assert s2.jit_seconds == 0.0
    assert s2.read_seconds > 0.0 and s2.compute_seconds > 0.0
    assert 0.0 <= s2.overlap_ratio <= 1.0
    assert s2.cache_hit_rate == 1.0
