"""Mixture-of-Experts layer (grok-1: 8e top-2; deepseek-moe: 2 shared + 64e top-6).

Dispatch is **sort-based** (Megablocks-style gather/scatter), not GShard
dense-dispatch einsums: a one-hot ``[tokens, experts, capacity]`` dispatch
einsum costs ``T*E*C*d`` MACs — for deepseek-moe at train_4k that is ~7x the
useful expert FLOPs and would swamp the roofline's MODEL_FLOPS/HLO ratio.
Sorting costs ~0 FLOPs and lowers to gathers/scatters whose communication
(data-sharded tokens -> expert-sharded buffers) is the honest all-to-all of
expert parallelism.

Tokens are routed within fixed dispatch *groups* (``cfg.moe_groups``) so the
position-in-expert computation stays group-local; groups shard over the data
axes, experts over the model axis (or the expert FFN dim when the expert count
doesn't divide the model axis — grok's 8 experts on a 16-way axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cdtype
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import shard_act, use_param

__all__ = ["moe_specs", "apply_moe", "moe_capacity"]


def moe_specs(cfg: ModelConfig) -> dict:
    d, E, fe = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    specs = {
        "router": ParamSpec((d, E), ("embed", None), init="fan_in",
                            dtype=jnp.float32),
        "w_gate": ParamSpec((E, d, fe), ("experts", "embed", "expert_mlp"),
                            init="fan_in"),
        "w_up": ParamSpec((E, d, fe), ("experts", "embed", "expert_mlp"),
                          init="fan_in"),
        "w_down": ParamSpec((E, fe, d), ("experts", "expert_mlp", "embed"),
                            init="fan_in"),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * fe
        specs["shared"] = {
            "gate": ParamSpec((d, fs), ("embed", "mlp"), init="fan_in"),
            "up": ParamSpec((d, fs), ("embed", "mlp"), init="fan_in"),
            "down": ParamSpec((fs, d), ("mlp", "embed"), init="fan_in"),
        }
    return specs


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = -(-tokens_per_group * cfg.moe_top_k
          * cfg.moe_capacity_factor // cfg.num_experts)   # ceil
    return max(int(c), 1)


def apply_moe(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, L, d] -> (y, aux_loss). Routing in f32; experts in compute dtype."""
    dt = cdtype(cfg)
    B, L, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    T = B * L
    G = min(cfg.moe_groups, T)
    while T % G:
        G -= 1
    Tg = T // G
    C = moe_capacity(cfg, Tg)
    S = Tg * k                                   # routing slots per group

    xt = x.reshape(G, Tg, d)
    xt = shard_act(xt, ("act_groups", None, None))

    # ---- routing (f32)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, eid_k = jax.lax.top_k(probs, k)                     # [G, Tg, k]
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                                # mean prob per e
    ce = jnp.zeros((E,), jnp.float32).at[eid_k.reshape(-1)].add(
        1.0 / (G * Tg * k))
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch within each group.
    # Every gather/scatter below is vmapped over the group dim so it lowers
    # to a *batched* 1-D gather/scatter: GSPMD partitions those along G. The
    # 2-D-indexed form (`buf.at[jnp.arange(G)[:,None], dest]`) is opaque to
    # the partitioner and falls back to replicate+mask+all-reduce of
    # [G, Tg*k, d]-sized tensors (measured 51 GB per op at deepseek scale).
    flat_e = eid_k.reshape(G, S)
    flat_g = gate_k.reshape(G, S)
    tok_of = jnp.tile(jnp.repeat(jnp.arange(Tg), k)[None, :], (G, 1))  # [G, S]

    order = jnp.argsort(flat_e, axis=-1, stable=True)           # [G, S]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_g = jnp.take_along_axis(flat_g, order, axis=-1)
    sorted_t = jnp.take_along_axis(tok_of, order, axis=-1)

    counts = jax.vmap(
        lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(flat_e)
    starts = jnp.cumsum(counts, axis=-1) - counts               # [G, E]
    pos_in_e = jnp.arange(S)[None, :] - jnp.take_along_axis(starts, sorted_e, -1)
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)      # dump slot E*C

    src = jax.vmap(lambda xg, tg: xg[tg])(xt, sorted_t).astype(dt)
    buf = jax.vmap(
        lambda d_, s_: jnp.zeros((E * C + 1, d), dt).at[d_].set(s_))(dest, src)
    expert_in = buf[:, : E * C].reshape(G, E, C, d)
    expert_in = shard_act(expert_in, ("act_groups", "act_experts", None, None))

    # ---- expert FFNs (batched over E)
    w_gate = use_param(p["w_gate"], ("experts", "embed", "expert_mlp"))
    w_up = use_param(p["w_up"], ("experts", "embed", "expert_mlp"))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, w_gate.astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, w_up.astype(dt))
    h = shard_act(h, ("act_groups", "act_experts", None, "act_expert_mlp"))
    w_down = use_param(p["w_down"], ("experts", "expert_mlp", "embed"))
    y_e = jnp.einsum("gecf,efd->gecd", h, w_down.astype(dt))
    y_e = shard_act(y_e, ("act_groups", "act_experts", None, None))

    # ---- combine (gather back + weight by gates)
    flat_y = jnp.concatenate(
        [y_e.reshape(G, E * C, d), jnp.zeros((G, 1, d), dt)], axis=1)
    back = jax.vmap(lambda f, d_: f[d_])(flat_y, dest)          # [G, S, d]
    contrib = back * (sorted_g * keep).astype(dt)[..., None]
    out = jax.vmap(
        lambda t_, c_: jnp.zeros((Tg, d), dt).at[t_].add(c_))(sorted_t, contrib)

    if cfg.num_shared_experts:
        sh = p["shared"]
        sh_gate = use_param(sh["gate"], ("embed", "mlp"))
        sh_up = use_param(sh["up"], ("embed", "mlp"))
        sh_down = use_param(sh["down"], ("mlp", "embed"))
        hs = jax.nn.silu(xt.astype(dt) @ sh_gate.astype(dt)) * (
            xt.astype(dt) @ sh_up.astype(dt))
        out = out + hs @ sh_down.astype(dt)

    # pin the group->batch boundary: without this the backward pass resolves
    # the resharding as replicate + f32 all-reduce of the full activation
    out = shard_act(out, ("act_groups", None, None))
    out = out.reshape(B, L, d)
    out = shard_act(out, ("act_batch", "act_seq", "act_embed"))
    return out, aux
