"""Data-movement saved vs selectivity (the paper's headline CSD statistic,
measured in the training data pipeline's two-phase pushdown)."""
from __future__ import annotations

import numpy as np

from repro.data import ZoneDataPipeline, ZoneDataStore
from repro.zns import ZonedDevice


def main() -> list[str]:
    rows = []
    for min_q in (0, 50, 90, 99):
        dev = ZonedDevice(num_zones=1, zone_bytes=8 * 1024 * 1024,
                          block_bytes=4096)
        store = ZoneDataStore(dev, seq_len=255)
        rng = np.random.default_rng(1)
        n = 4000
        store.append_records(
            0, rng.integers(0, 50000, (n, 255), dtype=np.int32),
            rng.integers(0, 100, n, dtype=np.int32))
        pipe = ZoneDataPipeline(store, batch=8, min_quality=min_q)
        import time
        t = time.perf_counter()
        recs = pipe._zone_records(0)
        dt = time.perf_counter() - t
        st = pipe.stats
        sel = st.records_kept / max(st.records_seen, 1)
        rows.append(
            f"pushdown_q{min_q},{dt * 1e6:.0f},"
            f"selectivity={sel:.3f};read_device_mb={st.bytes_read_device / 1e6:.1f};"
            f"to_host_mb={st.bytes_to_host / 1e6:.2f};"
            f"movement_saved_mb={st.movement_saved / 1e6:.1f};"
            f"reduction={st.bytes_read_device / max(st.bytes_to_host, 1):.1f}x"
        )
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
