"""CSD array scaling: aggregate offload throughput from 1 to 8 devices.

A fixed logical dataset is striped across N member devices whose read
bandwidth is emulated (``read_us_per_block``, QEMU-style, as the paper does
for its single device). The :class:`~repro.array.OffloadScheduler` fans a
verified filter-count offload out across the members concurrently, so the
aggregate device bandwidth — the bottleneck of any real CSD array — scales
with N while the per-command result stays identical.

Reported per width: steady-state offload microseconds, aggregate throughput
in MiB/s of zone data scanned, and the speedup vs the 1-device array (the
degenerate ``NvmCsd`` path). The paper's thesis at fleet scale: bytes moved
to the host stay constant (8 per offload) while scan throughput multiplies.

Scaling is ASSERTED, not just reported (the ROADMAP acceptance bar): the
staged read -> batched-compute -> combine pipeline must deliver monotonic
throughput 1 -> 8 devices and near-linear 1 -> 4. Member bandwidth is
emulated at 16 us per 4 KiB block (~256 MB/s, a QEMU-emulated-ZNS-class
member as the paper uses), so the benchmark sits in the device-bound regime
where fan-out HAS to pay off — a scheduler that serializes host work behind
the reads re-introduces the cliff and trips the assert. Timing is
best-of-N: a background load spike on the host can double any single run's
wall clock, and the pipeline's steady state is the minimum, not the mean.
"""
from __future__ import annotations

import time

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.zns import ZonedDevice

RAND_MAX = 2**31 - 1


def run_scaling(
    *,
    widths: tuple[int, ...] = (1, 2, 4, 8),
    data_mib: int = 16,
    stripe_blocks: int = 64,
    read_us_per_block: float = 16.0,
    runs: int = 5,
    seed: int = 0,
) -> list[dict]:
    """Same logical data on arrays of increasing width; offload throughput
    must rise monotonically with the member count — asserted below."""
    data_bytes = data_mib * 1024 * 1024
    rng = np.random.default_rng(seed)
    data = rng.integers(0, RAND_MAX, data_bytes // 4, dtype=np.int32)
    expected = int((data > RAND_MAX // 2).sum())
    program = filter_count("int32", "gt", RAND_MAX // 2)

    out: list[dict] = []
    for n in widths:
        devices = [
            ZonedDevice(num_zones=1, zone_bytes=data_bytes,
                        block_bytes=4096,
                        read_us_per_block=read_us_per_block)
            for _ in range(n)
        ]
        with StripedZoneArray(devices, stripe_blocks=stripe_blocks) as array:
            array.zone_append(0, data)
            with OffloadScheduler(array) as sched:
                stats = sched.nvm_cmd_bpf_run(program, 0)  # warm-up pays the JIT
                jit_seconds = stats.jit_seconds
                times = []
                for _ in range(runs):
                    t = time.perf_counter()
                    stats = sched.nvm_cmd_bpf_run(program, 0)
                    times.append(time.perf_counter() - t)
                assert int(sched.nvm_cmd_bpf_result()) == expected
        seconds = float(min(times))
        out.append({
            "devices": n,
            "seconds": seconds,
            "mib_per_s": data_mib / seconds,
            "jit_seconds": jit_seconds,
            "chunks": stats.n_chunks,
            "batched": stats.batched_chunks,
            "bytes_to_host": stats.bytes_returned,
        })

    # The scaling-cliff tripwire (ROADMAP acceptance bar; also run by
    # `make bench-smoke`): the fan-out pipeline must never get SLOWER as
    # members are added, and 1 -> 4 must stay near-linear. 0.97 absorbs
    # timer jitter between adjacent widths, nothing more — the measured
    # margins are 40-80%.
    thr = {r["devices"]: r["mib_per_s"] for r in out}
    for lo, hi in zip(widths, widths[1:]):
        assert thr[hi] >= 0.97 * thr[lo], (
            f"scaling cliff is back: {hi}-device throughput "
            f"{thr[hi]:.0f} MiB/s < {lo}-device {thr[lo]:.0f} MiB/s")
    if 1 in thr and 4 in thr:
        assert thr[4] >= 2.5 * thr[1], (
            f"1->4 device scaling fell off near-linear: {thr[4]:.0f} vs "
            f"{thr[1]:.0f} MiB/s ({thr[4] / thr[1]:.2f}x, need >= 2.5x)")
    if 1 in thr and 8 in thr:
        assert thr[8] >= 2.0 * thr[1], (
            f"8-device throughput {thr[8]:.0f} MiB/s is not >= 2x the "
            f"single device's {thr[1]:.0f} MiB/s")
    return out


def main(data_mib: int = 16, runs: int = 3) -> list[str]:
    rows = []
    # scaling asserts want best-of-N stability even on the quick suite
    results = run_scaling(data_mib=data_mib, runs=max(runs, 5))
    base = results[0]["seconds"]
    for r in results:
        rows.append(
            f"array_{r['devices']}dev,{r['seconds'] * 1e6:.0f},"
            f"mib_per_s={r['mib_per_s']:.1f};speedup={base / r['seconds']:.2f}x;"
            f"chunks={r['chunks']};batched={r['batched']};"
            f"bytes_to_host={r['bytes_to_host']}"
        )
    return rows


if __name__ == "__main__":
    for row in main(data_mib=64, runs=3):
        print(row)
