"""Offload program IR — the framework's eBPF analogue.

The paper ships user code to the CSD as eBPF bytecode because eBPF is (a)
verifiable for bounded execution and memory safety, (b) JITable, and (c)
portable across device backends. A register-level BPF ISA is the wrong
abstraction for a TPU (there is no scalar per-record execution unit), so we
keep the three *properties* and swap the carrier: offload programs are a small,
typed, **linear dataflow instruction set** over the records of a zone. Linear
(jump-free) programs are trivially terminating, which gives the verifier the
same guarantee the eBPF verifier proves for restricted CFGs.

A program is a sequence of instructions applied to the element stream of a
zone (interpreted at page granularity, exactly like the paper's prototype):

  * ``FIELD``      project one field out of fixed-stride records (optional,
                   must come first);
  * ALU ops        elementwise arithmetic against an immediate;
  * ``CMP_*``      refine the selection mask (AND-composed);
  * one terminal   ``RED_COUNT | RED_SUM | RED_MIN | RED_MAX | RED_HIST |
                   SELECT`` producing the (reduced) result that travels back
                   to the host.

The same program object runs on all execution tiers (interpreter / XLA JIT /
Pallas kernel / numpy oracle), mirroring the paper's uBPF-interp vs uBPF-JIT
vs native comparison.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Union

import numpy as np

__all__ = [
    "OpCode",
    "Instruction",
    "Program",
    "SUPPORTED_DTYPES",
    "TERMINAL_OPS",
    "ALU_OPS",
    "CMP_OPS",
    "filter_count",
    "filter_sum",
    "filter_select",
    "histogram",
    "field_reduce",
]

SUPPORTED_DTYPES = ("int32", "int64", "uint32", "float32", "float64")


class OpCode(enum.Enum):
    # record projection
    FIELD = "field"          # imm = (stride, index): view stream as records
    # ALU (elementwise, against immediate)
    ADD = "add"
    SUB = "sub"
    MUL = "mul"
    AND = "and"
    OR = "or"
    XOR = "xor"
    SHL = "shl"
    SHR = "shr"
    MOD = "mod"
    ABS = "abs"              # no immediate
    NEG = "neg"              # no immediate
    # predicates (refine the selection mask; AND-composed)
    CMP_GT = "cmp_gt"
    CMP_GE = "cmp_ge"
    CMP_LT = "cmp_lt"
    CMP_LE = "cmp_le"
    CMP_EQ = "cmp_eq"
    CMP_NE = "cmp_ne"
    # terminals (exactly one, last)
    RED_COUNT = "red_count"
    RED_SUM = "red_sum"
    RED_MIN = "red_min"
    RED_MAX = "red_max"
    RED_HIST = "red_hist"    # imm = (lo, hi, bins)
    SELECT = "select"        # returns matching elements (bounded capacity)
    SELECT_REC = "select_rec"  # returns whole matching RECORDS (needs FIELD)


ALU_OPS = frozenset({
    OpCode.ADD, OpCode.SUB, OpCode.MUL, OpCode.AND, OpCode.OR, OpCode.XOR,
    OpCode.SHL, OpCode.SHR, OpCode.MOD, OpCode.ABS, OpCode.NEG,
})
INT_ONLY_OPS = frozenset({OpCode.AND, OpCode.OR, OpCode.XOR, OpCode.SHL, OpCode.SHR})
CMP_OPS = frozenset({
    OpCode.CMP_GT, OpCode.CMP_GE, OpCode.CMP_LT, OpCode.CMP_LE,
    OpCode.CMP_EQ, OpCode.CMP_NE,
})
TERMINAL_OPS = frozenset({
    OpCode.RED_COUNT, OpCode.RED_SUM, OpCode.RED_MIN, OpCode.RED_MAX,
    OpCode.RED_HIST, OpCode.SELECT, OpCode.SELECT_REC,
})
NO_IMM_OPS = frozenset({
    OpCode.ABS, OpCode.NEG, OpCode.RED_COUNT, OpCode.RED_SUM,
    OpCode.RED_MIN, OpCode.RED_MAX,
})


@dataclass(frozen=True)
class Instruction:
    op: OpCode
    imm: Any = None

    def __repr__(self) -> str:  # compact for program dumps
        return f"{self.op.value}({self.imm})" if self.imm is not None else self.op.value


@dataclass(frozen=True)
class Program:
    """A verified-offloadable program over one zone's element stream."""

    input_dtype: str
    insns: tuple[Instruction, ...]
    # SELECT only: max elements returned (static shape for the XLA/Pallas tiers)
    select_capacity: Optional[int] = None
    name: str = "prog"

    @property
    def terminal(self) -> Instruction:
        return self.insns[-1]

    @property
    def n_insns(self) -> int:
        return len(self.insns)

    def result_dtype(self) -> np.dtype:
        t = self.terminal.op
        if t in (OpCode.RED_COUNT, OpCode.RED_HIST):
            return np.dtype(np.int64)
        if t == OpCode.RED_SUM:
            # widen to avoid overflow over a 256MiB zone (device-side policy)
            return np.dtype(np.int64) if np.issubdtype(np.dtype(self.input_dtype), np.integer) \
                else np.dtype(np.float64)
        return np.dtype(self.input_dtype)


# --------------------------------------------------------------------------
# builders for common offloads (the "built-in data structures / operators"
# the paper lists as ongoing work)
# --------------------------------------------------------------------------

_CMP_BY_NAME = {
    "gt": OpCode.CMP_GT, "ge": OpCode.CMP_GE, "lt": OpCode.CMP_LT,
    "le": OpCode.CMP_LE, "eq": OpCode.CMP_EQ, "ne": OpCode.CMP_NE,
}


def _cmp(cmp: str, threshold) -> Instruction:
    return Instruction(_CMP_BY_NAME[cmp], threshold)


def filter_count(dtype: str, cmp: str, threshold) -> Program:
    """The paper's Figure 2 workload: count elements where ``x <cmp> threshold``."""
    return Program(dtype, (_cmp(cmp, threshold), Instruction(OpCode.RED_COUNT)),
                   name=f"filter_count_{cmp}")


def filter_sum(dtype: str, cmp: str, threshold) -> Program:
    return Program(dtype, (_cmp(cmp, threshold), Instruction(OpCode.RED_SUM)),
                   name=f"filter_sum_{cmp}")


def filter_select(dtype: str, cmp: str, threshold, capacity: int) -> Program:
    """Pushdown select: return the matching elements themselves (bounded)."""
    return Program(dtype, (_cmp(cmp, threshold), Instruction(OpCode.SELECT)),
                   select_capacity=capacity, name=f"filter_select_{cmp}")


def histogram(dtype: str, lo, hi, bins: int) -> Program:
    return Program(dtype, (Instruction(OpCode.RED_HIST, (lo, hi, bins)),),
                   name=f"hist_{bins}")


def select_records(dtype: str, stride: int, index: int, cmp: str, threshold,
                   capacity: int) -> Program:
    """Record-granular pushdown: return whole records whose field ``index``
    satisfies the predicate (the paper's 'built-in data-structure operators'
    direction — what a CSD-aware data pipeline runs device-side)."""
    return Program(
        dtype,
        (Instruction(OpCode.FIELD, (stride, index)), _cmp(cmp, threshold),
         Instruction(OpCode.SELECT_REC)),
        select_capacity=capacity,
        name=f"select_rec_f{index}_{cmp}",
    )


def field_reduce(dtype: str, stride: int, index: int, kind: str = "sum",
                 cmp: Optional[str] = None, threshold=None) -> Program:
    """Project field ``index`` of ``stride``-wide records, filter, reduce."""
    insns: list[Instruction] = [Instruction(OpCode.FIELD, (stride, index))]
    if cmp is not None:
        insns.append(_cmp(cmp, threshold))
    insns.append(Instruction({
        "sum": OpCode.RED_SUM, "count": OpCode.RED_COUNT,
        "min": OpCode.RED_MIN, "max": OpCode.RED_MAX,
    }[kind]))
    return Program(dtype, tuple(insns), name=f"field{index}_{kind}")
