"""CSD array subsystem: stripe round-trips (all redundancy modes), queue
arbitration/backpressure, scheduler result-equivalence vs the single-device
NvmCsd oracle for every OpCode terminal, degraded-read reconstruction
bit-identity under raid1/xor, and the fault paths: mid-fan-out member death,
leaked-future regression, torn-append fencing, locked zone transitions."""
import threading
import time

import numpy as np
import pytest

from repro.array import (
    ArrayOffloadError,
    Completion,
    OffloadCommand,
    OffloadScheduler,
    QueueFullError,
    QueuePair,
    CompletionQueue,
    StripedZoneArray,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.core import CsdTier, NvmCsd, VerifyError
from repro.core.programs import (
    Instruction,
    OpCode,
    Program,
    field_reduce,
    filter_count,
    filter_select,
    filter_sum,
    histogram,
    select_records,
)
from repro.zns import (
    OutOfBoundsError,
    ZonedDevice,
    ZoneFullError,
    ZoneState,
    ZoneStateError,
)

BLOCK = 4096
STRIPE = 4


def make_array(n_devices, *, num_zones=4, zone_kib=256, stripe=STRIPE,
               redundancy="raid0", **device_kw):
    devs = [ZonedDevice(num_zones=num_zones, zone_bytes=zone_kib * 1024,
                        block_bytes=BLOCK, **device_kw)
            for _ in range(n_devices)]
    return StripedZoneArray(devs, stripe_blocks=stripe, redundancy=redundancy)


def int32_blocks(n_blocks, seed=0, lo=-1000, hi=1000):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, n_blocks * BLOCK // 4, dtype=np.int32)


# ------------------------------------------------------------------ striping

@pytest.mark.parametrize("n_devices", [1, 2, 3, 4])
def test_stripe_append_read_round_trip(n_devices):
    arr = make_array(n_devices)
    data = int32_blocks(4 * STRIPE * n_devices + 7)  # force a partial chunk
    arr.zone_append(0, data)
    back = np.frombuffer(arr.read_blocks(0, 0, arr.zone(0).write_pointer)
                         .tobytes(), np.int32)
    assert np.array_equal(back, data)


def test_stripe_partial_reads_any_offset():
    arr = make_array(3)
    data = int32_blocks(23)
    arr.zone_append(0, data)
    per_block = BLOCK // 4
    for off, n in [(0, 1), (1, 5), (3, 17), (7, 16), (22, 1), (0, 23)]:
        back = np.frombuffer(arr.read_blocks(0, off, n).tobytes(), np.int32)
        assert np.array_equal(back, data[off * per_block:(off + n) * per_block])


def test_stripe_incremental_appends_interleave_correctly():
    arr = make_array(2)
    parts = [int32_blocks(n, seed=n) for n in (3, 1, 6, 2)]
    for p in parts:
        arr.zone_append(0, p)
    want = np.concatenate(parts)
    back = np.frombuffer(arr.read_zone(0).tobytes(), np.int32)
    assert np.array_equal(back, want)
    # data really is spread over both members
    assert all(d.zone(0).write_pointer > 0 for d in arr.devices)


def test_stripe_reset_and_reuse():
    arr = make_array(2)
    arr.zone_append(1, int32_blocks(8))
    arr.reset_zone(1)
    assert arr.zone(1).write_pointer == 0
    assert all(d.zone(1).write_pointer == 0 for d in arr.devices)
    fresh = int32_blocks(4, seed=9)
    arr.zone_append(1, fresh)
    assert np.array_equal(
        np.frombuffer(arr.read_zone(1).tobytes(), np.int32), fresh)


def test_stripe_bounds_and_capacity_errors():
    arr = make_array(2, zone_kib=64)  # 16 blocks/member -> 32 logical
    arr.zone_append(0, int32_blocks(4))
    with pytest.raises(OutOfBoundsError):
        arr.read_blocks(0, 0, 5)   # beyond logical write pointer
    with pytest.raises(ZoneFullError):
        arr.zone_append(0, int32_blocks(29))  # exceeds logical capacity
    with pytest.raises(ValueError):
        StripedZoneArray([ZonedDevice(num_zones=2, zone_bytes=64 * 1024),
                          ZonedDevice(num_zones=4, zone_bytes=64 * 1024)])


def test_logical_write_pointer_setter_distributes():
    arr = make_array(3, stripe=4)
    z = arr.zone(0)
    z.write_pointer = 4 * 3 * 2 + 4 + 2   # 2 full rows + 1 full chunk + 2
    assert [d.zone(0).write_pointer for d in arr.devices] == [12, 10, 8]
    assert z.write_pointer == 30
    z.write_pointer = 0
    assert all(d.zone(0).write_pointer == 0 for d in arr.devices)


# -------------------------------------------------------------------- queues

def test_sq_backpressure_rejects_then_unblocks():
    sq = SubmissionQueue("t", depth=2)
    prog = filter_count("int32", "gt", 0)
    mk = lambda: OffloadCommand(prog, 0, 0, 4, None)
    sq.submit(mk()); sq.submit(mk())
    with pytest.raises(QueueFullError):
        sq.submit(mk())
    assert sq.rejected == 1
    # a blocked submitter proceeds once the arbiter pops a slot
    done = threading.Event()
    def blocked():
        sq.submit(mk(), block=True, timeout=5.0)
        done.set()
    t = threading.Thread(target=blocked); t.start()
    assert not done.wait(0.05)
    assert sq.pop() is not None
    assert done.wait(5.0)
    t.join()
    assert len(sq) == 2


def test_wrr_arbiter_respects_weights():
    prog = filter_count("int32", "gt", 0)
    pairs = {}
    arb = WeightedRoundRobinArbiter()
    for tenant, weight in [("a", 2), ("b", 1)]:
        pair = QueuePair(SubmissionQueue(tenant, depth=16, weight=weight),
                         CompletionQueue(tenant))
        for _ in range(6):
            pair.sq.submit(OffloadCommand(prog, 0, 0, 4, None, tenant=tenant))
        pairs[tenant] = pair
        arb.add(pair)
    order = []
    while (nxt := arb.next_command()) is not None:
        order.append(nxt[0].tenant)
    # 2:1 service mix while both queues are backlogged; once 'a' drains the
    # arbiter stays work-conserving and serves the remaining 'b' commands
    assert order == ["a", "a", "b"] * 3 + ["b", "b", "b"]


def test_wrr_arbiter_work_conserving_when_queue_empty():
    prog = filter_count("int32", "gt", 0)
    arb = WeightedRoundRobinArbiter()
    a = QueuePair(SubmissionQueue("a", depth=4, weight=3), CompletionQueue("a"))
    b = QueuePair(SubmissionQueue("b", depth=4, weight=1), CompletionQueue("b"))
    arb.add(a); arb.add(b)
    b.sq.submit(OffloadCommand(prog, 0, 0, 4, None, tenant="b"))
    nxt = arb.next_command()
    assert nxt is not None and nxt[0].tenant == "b"
    assert arb.next_command() is None


# ----------------------------------------------------------------- scheduler

def oracle_pair(n_blocks, seed=0):
    """(single-device NvmCsd, striped 4-wide scheduler) over identical data."""
    data = int32_blocks(n_blocks, seed=seed)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    arr = make_array(4)
    arr.zone_append(0, data)
    return NvmCsd(dev), OffloadScheduler(arr)


TERMINAL_PROGRAMS = [
    filter_count("int32", "gt", 0),
    filter_sum("int32", "lt", 100),
    field_reduce("int32", 8, 1, "min"),
    field_reduce("int32", 8, 2, "max"),
    histogram("int32", -1000, 1000, 32),
    filter_select("int32", "gt", 900, 64),
    select_records("int32", 8, 0, "gt", 500, 32),
]


@pytest.mark.parametrize("program", TERMINAL_PROGRAMS,
                         ids=[p.name for p in TERMINAL_PROGRAMS])
def test_scheduler_matches_single_device_oracle(program):
    csd, sched = oracle_pair(40)
    want, _ = csd.run_and_fetch(program, 0)
    got, stats = sched.run_and_fetch(program, 0)
    if isinstance(want, tuple):
        assert np.array_equal(np.asarray(want[0]), np.asarray(got[0]))
        assert int(want[1]) == int(got[1])
    else:
        assert np.asarray(want).dtype == np.asarray(got).dtype
        assert np.array_equal(np.asarray(want), np.asarray(got))
    assert stats.n_devices == 4
    assert stats.n_chunks == 10
    assert stats.bytes_read == 40 * BLOCK


@pytest.mark.parametrize("tier", [CsdTier.INTERP, CsdTier.JIT, CsdTier.KERNEL])
def test_scheduler_tiers_agree_with_tail_chunk(tier):
    csd, sched = oracle_pair(37, seed=3)  # 37 blocks -> partial tail chunk
    program = filter_count("int32", "gt", 0)
    want, _ = csd.run_and_fetch(program, 0, tier=tier)
    got, _ = sched.run_and_fetch(program, 0, tier=tier)
    assert int(want) == int(got)


def test_scheduler_batches_full_chunks_on_jit_tier():
    _, sched = oracle_pair(40)
    stats = sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    # 10 chunks over 4 devices: the 2-chunk devices batch via vmap
    assert stats.batched_chunks > 0
    assert stats.tier == CsdTier.JIT


def test_scheduler_partial_extent_matches_oracle():
    csd, sched = oracle_pair(40, seed=7)
    program = filter_sum("int32", "ge", -50)
    want, _ = csd.run_and_fetch(program, 0, block_off=4, n_blocks=24)
    got, _ = sched.run_and_fetch(program, 0, block_off=4, n_blocks=24)
    assert int(want) == int(got)


def test_scheduler_verifies_before_enqueue():
    _, sched = oracle_pair(8)
    bad = Program("int32", (Instruction(OpCode.CMP_GT, 0),), name="no_terminal")
    with pytest.raises(VerifyError):
        sched.submit(bad, 0)
    assert len(sched.queue_pair().sq) == 0  # rejected work never queues


def test_scheduler_single_device_degenerate_path():
    data = int32_blocks(12, seed=5)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    arr = StripedZoneArray(
        [ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)],
        stripe_blocks=STRIPE)
    arr.zone_append(0, data)
    program = filter_count("int32", "le", 250)
    want, _ = NvmCsd(dev).run_and_fetch(program, 0)
    got, stats = OffloadScheduler(arr).run_and_fetch(program, 0)
    assert int(want) == int(got)
    assert stats.n_devices == 1


def test_scheduler_offline_member_degrades_with_clear_error():
    _, sched = oracle_pair(40)
    sched.array.set_offline(0, device=2)
    with pytest.raises(ArrayOffloadError, match="member device 2"):
        sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    # the failure is also visible on the completion queue, not just raised
    comps = sched.queue_pair().cq.drain()
    assert comps and not comps[-1].ok


def test_scheduler_async_dispatcher_and_wait():
    csd, sched = oracle_pair(40, seed=11)
    program = filter_sum("int32", "lt", 0)
    want, _ = csd.run_and_fetch(program, 0)
    sched.start()
    try:
        cmd_ids = [sched.submit(program, 0) for _ in range(3)]
        comps = [sched.wait(cid, timeout=60) for cid in cmd_ids]
    finally:
        sched.stop()
    assert all(c.ok for c in comps)
    assert all(int(c.value) == int(want) for c in comps)


# ------------------------------------------------ redundancy & fault paths

REDUNDANT = [("raid1", 2), ("raid1", 4), ("xor", 3), ("xor", 4)]


@pytest.mark.parametrize("mode,n", REDUNDANT)
def test_redundant_append_read_round_trip(mode, n):
    arr = make_array(n, redundancy=mode)
    data = int32_blocks(4 * STRIPE * arr.data_columns + 7)  # partial chunk
    arr.zone_append(0, data)
    back = np.frombuffer(arr.read_zone(0).tobytes(), np.int32)
    assert np.array_equal(back, data)
    # incremental appends interleave correctly too (exercises the xor
    # tail-row parity accumulator across append boundaries)
    arr2 = make_array(n, redundancy=mode)
    parts = [int32_blocks(k, seed=10 + k) for k in (3, 1, 6, 2, 11)]
    for p in parts:
        arr2.zone_append(0, p)
    want = np.concatenate(parts)
    assert np.array_equal(
        np.frombuffer(arr2.read_zone(0).tobytes(), np.int32), want)


def test_redundancy_geometry_validation():
    mk = lambda n: [ZonedDevice(num_zones=2, zone_bytes=64 * 1024,
                                block_bytes=BLOCK) for _ in range(n)]
    with pytest.raises(ValueError, match="even member count"):
        StripedZoneArray(mk(3), stripe_blocks=4, redundancy="raid1")
    with pytest.raises(ValueError, match=">= 3 members"):
        StripedZoneArray(mk(2), stripe_blocks=4, redundancy="xor")
    with pytest.raises(ValueError, match="redundancy"):
        StripedZoneArray(mk(2), stripe_blocks=4, redundancy="raid6")
    # capacity: raid1 halves, xor spends one member on parity
    assert StripedZoneArray(mk(4), stripe_blocks=4,
                            redundancy="raid1").zone_blocks == 2 * 16
    assert StripedZoneArray(mk(4), stripe_blocks=4,
                            redundancy="xor").zone_blocks == 3 * 16


@pytest.mark.parametrize("mode,n", REDUNDANT)
def test_degraded_read_bit_identical_for_every_dead_member(mode, n):
    data = int32_blocks(37, seed=1)
    per_block = BLOCK // 4
    for dead in range(n):
        arr = make_array(n, redundancy=mode)
        arr.zone_append(0, data)
        arr.set_offline(0, device=dead)
        assert arr.zone(0).degraded
        got = np.frombuffer(arr.read_blocks(0, 0, 37).tobytes(), np.int32)
        assert np.array_equal(got, data), f"{mode} dead member {dead}"
        for off, k in [(0, 1), (1, 5), (3, 17), (7, 16), (36, 1), (5, 32)]:
            g = np.frombuffer(arr.read_blocks(0, off, k).tobytes(), np.int32)
            assert np.array_equal(
                g, data[off * per_block:(off + k) * per_block])
        assert arr.stats["degraded_reads"] > 0, f"{mode} dead member {dead}"


def test_raid0_offline_member_stays_fatal():
    arr = make_array(3)
    arr.zone_append(0, int32_blocks(12))
    arr.set_offline(0, device=1)
    assert arr.zone(0).state == ZoneState.OFFLINE
    with pytest.raises(ZoneStateError):
        arr.read_blocks(0, 0, 12)


@pytest.mark.parametrize("mode,n", [("raid1", 2), ("xor", 3)])
def test_degraded_reconstruction_rides_the_ring(mode, n):
    """Emulated members: reconstruction reads are reactor-retired transfers
    (no extra threads), and the reconstructed bytes stay bit-identical."""
    arr = make_array(n, redundancy=mode, read_us_per_block=5.0)
    data = int32_blocks(45, seed=2)
    arr.zone_append(0, data)
    arr.set_offline(0, device=0)
    fut = arr.submit_read(0, 0, 45, dtype=np.int32)
    assert np.array_equal(np.asarray(fut.result(timeout=20)), data)
    assert arr.stats["degraded_reads"] > 0


@pytest.mark.parametrize("mode,n", [("raid0", 2), ("raid1", 2), ("xor", 3)])
def test_member_death_between_submit_and_completion(mode, n):
    """A member going OFFLINE while its transfers are in flight must not
    corrupt or hang them: the extent was snapshotted at submission (the ZNS
    contract), so the aggregate retires with the correct bytes."""
    arr = make_array(n, redundancy=mode, read_us_per_block=20.0)
    data = int32_blocks(32, seed=3)
    arr.zone_append(0, data)
    fut = arr.submit_read(0, 0, 32, dtype=np.int32)
    arr.set_offline(0, device=n - 1)          # dies mid-flight
    assert np.array_equal(np.asarray(fut.result(timeout=20)), data)


def test_raid1_round_robin_spreads_healthy_reads():
    arr = make_array(2, redundancy="raid1")
    arr.zone_append(0, int32_blocks(8 * STRIPE))
    for d in arr.devices:
        d.stats["blocks_read"] = 0
    arr.read_zone(0)
    reads = [d.stats["blocks_read"] for d in arr.devices]
    assert all(r > 0 for r in reads), f"mirror pair not round-robined: {reads}"
    assert sum(reads) == 8 * STRIPE           # each block read exactly once


def test_xor_parity_chunk_is_xor_of_row_data():
    """White-box: after full stripe rows land, the rotating parity member
    holds the XOR of the row's data chunks."""
    arr = make_array(3, redundancy="xor")
    s, C = arr.stripe_blocks, arr.data_columns
    data = int32_blocks(3 * C * s, seed=4)     # 3 complete rows
    arr.zone_append(0, data)
    blocks = np.frombuffer(data.tobytes(), np.uint8).reshape(-1, BLOCK)
    for row in range(3):
        data_devs, parity = arr._row_devices(row)
        want = np.zeros((s, BLOCK), np.uint8)
        for col, d in enumerate(data_devs):
            chunk = row * C + col
            want ^= blocks[chunk * s:(chunk + 1) * s]
        got = arr.devices[parity].read_blocks(0, row * s, s)
        assert np.array_equal(got.reshape(-1, BLOCK), want), f"row {row}"


def test_unrecoverable_member_loss_goes_offline():
    arr = make_array(4, redundancy="raid1")
    arr.zone_append(0, int32_blocks(16))
    arr.set_offline(0, device=0)
    arr.set_offline(0, device=1)               # both partners of column 0
    assert arr.zone(0).state == ZoneState.OFFLINE
    with pytest.raises(ZoneStateError):
        arr.read_blocks(0, 0, 16)
    # offloads keep the PR 2 clean-error contract even past the redundancy
    # limit: ArrayOffloadError, not a raw ZoneStateError
    with pytest.raises(ArrayOffloadError, match="unrecoverable"):
        OffloadScheduler(arr).nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    arr2 = make_array(3, redundancy="xor")
    arr2.zone_append(0, int32_blocks(16))
    arr2.set_offline(0, device=0)
    arr2.set_offline(0, device=2)              # two dead under single parity
    assert arr2.zone(0).state == ZoneState.OFFLINE


def test_degraded_zone_is_read_only():
    arr = make_array(2, redundancy="raid1")
    data = int32_blocks(12, seed=5)
    arr.zone_append(0, data)
    arr.set_offline(0, device=1)
    assert arr.zone(0).state == ZoneState.READ_ONLY
    with pytest.raises(ZoneStateError):
        arr.zone_append(0, int32_blocks(4))
    with pytest.raises(ZoneStateError, match="rebuild"):
        arr.reset_zone(0)
    assert np.array_equal(
        np.frombuffer(arr.read_zone(0).tobytes(), np.int32), data)


def test_submit_read_mid_fanout_failure_fails_aggregate_not_hangs():
    """Regression (leaked member futures): a member submit raising partway
    through the fan-out must retire the aggregate with the error — never
    orphan it."""
    arr = make_array(3, read_us_per_block=10.0)
    data = int32_blocks(24, seed=6)
    arr.zone_append(0, data)

    def boom(*a, **kw):
        raise ZoneStateError("injected: member died between check and submit")

    arr.devices[1].submit_read = boom
    fut = arr.submit_read(0, 0, 24)
    with pytest.raises(ZoneStateError, match="injected"):
        fut.result(timeout=10)                 # retires with the error


def test_submit_append_mid_fanout_failure_fails_and_fences():
    arr = make_array(3, append_us_per_block=10.0)

    def boom(*a, **kw):
        raise ZoneStateError("injected append death")

    arr.devices[1].submit_append = boom
    fut = arr.submit_append(0, int32_blocks(24, seed=7))
    with pytest.raises(ZoneStateError, match="injected"):
        fut.result(timeout=10)
    # member 0 landed its share, member 1 did not: the zone is torn — fenced
    # READ_ONLY until reset, and the logical write pointer never advanced
    assert arr.zone(0).write_pointer == 0
    assert arr.zone(0).state == ZoneState.READ_ONLY
    with pytest.raises(ZoneStateError):
        arr.zone_append(0, int32_blocks(4))
    del arr.devices[1].submit_append           # un-patch: reset recovers
    arr.reset_zone(0)
    assert arr.zone(0).is_writable
    data = int32_blocks(8, seed=8)
    arr.zone_append(0, data)
    assert np.array_equal(
        np.frombuffer(arr.read_zone(0).tobytes(), np.int32), data)


def test_finish_zone_partial_transition_raises_zone_state_error():
    """Regression (unlocked transitions): a member refusing a transition
    mid-loop surfaces as ZoneStateError instead of silently leaving the
    members in mixed states."""
    arr = make_array(3)
    arr.zone_append(0, int32_blocks(8))
    arr.devices[2].set_read_only(0)            # member 2 will refuse FINISH
    with pytest.raises(ZoneStateError, match="partial finish"):
        arr.finish_zone(0)
    # offline LOGICAL zone is guarded up front, like reset_zone
    arr.set_offline(1)
    with pytest.raises(ZoneStateError, match="offline"):
        arr.finish_zone(1)
    with pytest.raises(ZoneStateError, match="offline"):
        arr.set_read_only(1)


def test_finish_zone_on_degraded_array_transitions_survivors():
    arr = make_array(2, redundancy="raid1")
    arr.zone_append(0, int32_blocks(8, seed=9))
    arr.set_offline(0, device=0)
    arr.finish_zone(0)                         # survivors seal; no raise
    assert arr.devices[1].zone(0).state == ZoneState.FULL
    assert arr.devices[0].zone(0).state == ZoneState.OFFLINE


def test_xor_recovery_with_dead_member_never_fabricates_tail_bytes():
    """Regression: write-pointer recovery on an already-degraded xor array
    cannot rebuild the tail-row parity accumulator (the dead member's tail
    data is gone and its parity never landed) — tail reads must RAISE, not
    return zero bytes; complete rows still reconstruct bit-identically."""
    arr = make_array(3, redundancy="xor")
    s, C = arr.stripe_blocks, arr.data_columns
    data = int32_blocks(2 * C * s + 3, seed=14)     # 2 full rows + 3-block tail
    arr.zone_append(0, data)
    wp = arr.zone(0).write_pointer
    # the tail row's first data chunk lives on a data member — kill it, then
    # run the documented checkpoint-recovery path (write_pointer setter)
    tail_dev = arr._row_devices(2)[0][0]
    arr.set_offline(0, device=tail_dev)
    arr.zone(0).write_pointer = wp
    # complete rows: still exact
    got = np.frombuffer(arr.read_blocks(0, 0, 2 * C * s).tobytes(), np.int32)
    assert np.array_equal(got, data[: 2 * C * s * (BLOCK // 4)])
    # tail row: lost for the dead member — loud error, never zeros
    with pytest.raises(ZoneStateError, match="unrecoverable"):
        arr.read_blocks(0, 0, wp)
    # recovery while HEALTHY then losing the member stays exact (the
    # accumulator was rebuilt from live members before the failure)
    arr2 = make_array(3, redundancy="xor")
    arr2.zone_append(0, data)
    arr2.zone(0).write_pointer = wp
    arr2.set_offline(0, device=arr2._row_devices(2)[0][0])
    got = np.frombuffer(arr2.read_blocks(0, 0, wp).tobytes(), np.int32)
    assert np.array_equal(got, data)


def test_gather_pool_threads_are_daemonic():
    arr = make_array(2, read_us_per_block=5.0)
    arr.zone_append(0, int32_blocks(16))
    arr.read_zone(0)                           # routes through the pool
    gather = [t for t in threading.enumerate()
              if t.name.startswith("stripe-gather")]
    assert all(t.daemon for t in gather)


# --------------------------------------- scheduler over degraded arrays

@pytest.mark.parametrize("mode,n", [("raid1", 2), ("raid1", 4), ("xor", 3)])
def test_scheduler_degraded_offload_bit_identical(mode, n):
    """Acceptance: with one member zone OFFLINE, an offload over the
    degraded array returns the same result as over a single device, and the
    degraded fan-out is counted."""
    data = int32_blocks(40, seed=11)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    csd = NvmCsd(dev)
    arr = make_array(n, redundancy=mode, zone_kib=1024)
    arr.zone_append(0, data)
    sched = OffloadScheduler(arr)
    for program in (filter_count("int32", "gt", 0),
                    filter_sum("int32", "lt", 100),
                    filter_select("int32", "gt", 900, 64)):
        want, _ = csd.run_and_fetch(program, 0)
        healthy, h_stats = sched.run_and_fetch(program, 0)
        assert h_stats.degraded_reads == 0
        arr.set_offline(0, device=0)
        degraded, d_stats = sched.run_and_fetch(program, 0)
        assert d_stats.degraded_reads > 0
        for got in (healthy, degraded):
            if isinstance(want, tuple):
                assert np.array_equal(np.asarray(want[0]), np.asarray(got[0]))
                assert int(want[1]) == int(got[1])
            else:
                assert np.array_equal(np.asarray(want), np.asarray(got))
        # back to healthy for the next program's healthy pass
        for z in range(arr.num_zones):
            arr.devices[0].zones[z].state = ZoneState.OPEN \
                if arr.devices[0].zones[z].write_pointer else ZoneState.EMPTY


@pytest.mark.parametrize("tier", [CsdTier.INTERP, CsdTier.JIT, CsdTier.KERNEL])
def test_scheduler_degraded_offload_all_tiers(tier):
    data = int32_blocks(37, seed=12)           # partial tail chunk too
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    program = filter_count("int32", "gt", 0)
    want, _ = NvmCsd(dev).run_and_fetch(program, 0, tier=tier)
    arr = make_array(3, redundancy="xor", zone_kib=1024)
    arr.zone_append(0, data)
    arr.set_offline(0, device=1)
    got, stats = OffloadScheduler(arr).run_and_fetch(program, 0, tier=tier)
    assert int(want) == int(got)
    assert stats.degraded_reads > 0


def test_scheduler_member_death_mid_command_recovers_on_redundant_array():
    """Member dies while the fan-out is executing: redundant arrays redirect
    or reconstruct the affected chunks and still return the exact result."""
    data = int32_blocks(40, seed=13)
    expected = int((data > 0).sum())
    arr = make_array(2, redundancy="raid1", zone_kib=1024,
                     read_us_per_block=50.0)
    arr.zone_append(0, data)
    sched = OffloadScheduler(arr)
    program = filter_count("int32", "gt", 0)
    sched.nvm_cmd_bpf_run(program, 0)          # warm: pays JIT
    killer = threading.Timer(0.002, lambda: arr.set_offline(0, device=1))
    killer.start()
    try:
        got, _ = sched.run_and_fetch(program, 0)
    finally:
        killer.join()
    assert int(got) == expected


def test_scheduler_multi_tenant_stats_history():
    _, sched = oracle_pair(40)
    sched.register_tenant("analytics", weight=2)
    sched.submit(filter_count("int32", "gt", 0), 0, tenant="analytics")
    sched.submit(filter_count("int32", "lt", 0), 0)
    assert sched.drain() == 2
    assert len(sched.history) == 2
    assert {s.program for s in sched.history} == {
        "filter_count_gt", "filter_count_lt"}


# ------------------------- staged-pipeline stats + fault seams (ISSUE 10)

def test_offload_stats_report_per_stage_figures():
    """The pipelined path decomposes its wall time per STAGE (read wait /
    staging / combine) and counts batched dispatches — the per-worker
    fanout/overlap accounting is gone."""
    _, sched = oracle_pair(40)
    stats = sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    assert stats.n_dispatches >= 1
    assert stats.read_wait_seconds >= 0.0
    assert stats.stage_seconds >= 0.0
    assert stats.combine_seconds >= 0.0
    assert 0.0 <= stats.overlap_ratio <= 1.0
    # fanout names the array-wide batched dispatches, not a worker pool
    assert "dispatches" in stats.fanout
    assert f"{stats.n_chunks} chunks" in stats.fanout
    assert f"{stats.n_devices} devices" in stats.fanout


def test_array_statsview_dict_api_unchanged_by_pipeline():
    """Regression: the dict-shaped stats surfaces survive the staged
    refactor — same keys before/after an offload, mapping semantics on the
    device-level StatsView, integer values throughout."""
    arr = make_array(4)
    arr.zone_append(0, int32_blocks(40))
    keys_before = set(arr.stats)
    with OffloadScheduler(arr) as sched:
        sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    after = arr.stats
    assert set(after) == keys_before
    for key in ("blocks_read", "bytes_copied", "bytes_viewed",
                "degraded_reads", "read_errors"):
        assert key in after
    assert all(isinstance(v, (int, np.integer)) for v in after.values())
    view = arr.devices[0].stats
    assert view["blocks_read"] == dict(view)["blocks_read"]
    assert len(view) == len(list(view))


@pytest.mark.parametrize("mode,n", [("raid0", 4), ("raid1", 4), ("xor", 3)])
@pytest.mark.parametrize("tier", [CsdTier.JIT, CsdTier.KERNEL])
def test_batched_dispatch_bit_identical_across_tiers_and_modes(mode, n, tier):
    """The array-wide batched dispatch must return byte-identical answers
    to the single-device oracle at every redundancy mode and compiled tier,
    healthy AND with a member down (degraded chunks ride the same staged
    path) — raid0 has no redundancy, so only the healthy half applies."""
    data = int32_blocks(64, seed=21)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    csd = NvmCsd(dev)
    arr = make_array(n, redundancy=mode, zone_kib=1024)
    arr.zone_append(0, data)
    sched = OffloadScheduler(arr)
    for program in (filter_count("int32", "gt", 0),
                    filter_sum("int32", "lt", 100)):
        want, _ = csd.run_and_fetch(program, 0, tier=tier)
        got, stats = sched.run_and_fetch(program, 0, tier=tier)
        assert np.array_equal(np.asarray(want), np.asarray(got))
        assert stats.batched_chunks > 0
        if mode != "raid0":
            arr.set_offline(0, device=0)
            degraded, d_stats = sched.run_and_fetch(program, 0, tier=tier)
            assert np.array_equal(np.asarray(want), np.asarray(degraded))
            assert d_stats.degraded_reads > 0
            for z in range(arr.num_zones):
                arr.devices[0].zones[z].state = ZoneState.OPEN \
                    if arr.devices[0].zones[z].write_pointer \
                    else ZoneState.EMPTY
    assert all(s.movement_saved_bytes > 0 for s in sched.history)
