"""Offload scheduler: verified programs fanned out across a striped array.

The single-device ``NvmCsd`` verifies and executes one extent synchronously.
The :class:`OffloadScheduler` scales that contract to a
:class:`~repro.array.striping.StripedZoneArray`:

  1. **verify once** — the program is checked by the same static verifier
     against the whole logical extent *before* it enters a submission queue;
     everything past the SQ is admitted work;
  2. **queue + arbitrate** — commands sit in per-tenant NVMe-style SQs with
     depth limits and are dispatched by weighted round-robin (see
     :mod:`repro.array.queues`);
  3. **staged fan-out** — execution is an explicit three-stage pipeline
     rather than a thread per member. The READ stage submits every member
     transfer the plan needs to the completion ring UP FRONT — coalesced
     chunk-group reads per member (:func:`repro.array.striping.
     coalesce_member_runs`), tail-chunk reads, xor survivor reconstructions
     — so in-flight depth is bounded by the emulated devices, not a thread
     pool (:mod:`repro.zns.ring`). The COMPUTE stage is ONE dispatcher that
     consumes staged groups in logical order and issues ONE array-wide
     batched compiled call per group over the chunks of ALL members: a
     vmapped XLA call on the JIT tier
     (:func:`repro.core.vm.jit_program_batched`) or a grid-batched Pallas
     call on the kernel tier
     (:func:`repro.kernels.zone_filter.ops.kernel_program_batched`) —
     never N GIL-contending per-worker dispatches;
  4. **combine stage** — per-chunk results fold in logical stripe order on
     the striping gather pool AS THEY LAND, off the straggler's critical
     path, by a program-aware combiner: SUM/COUNT re-add (float SUM via
     Kahan compensated f64 accumulation, so results are identical for
     every array width over the same logical data), MIN/MAX re-reduce, HIST
     re-accumulates, SELECT/SELECT_REC concatenate the first ``capacity``
     matches in logical order — bit-identical to the single-device result
     for COUNT/MIN/MAX/SELECT and for SUM over integer streams (float SUM
     may differ from a chunk-free single device by summation order, exactly
     as the tiers already may);
  5. **aggregate stats** — one :class:`ArrayOffloadStats` per command rolls
     up bytes read on every member, bytes returned to the host, verify/JIT/
     read/exec time, compile-cache hits, and the fan-out shape.

A 1-device array degrades to the ``NvmCsd`` semantics — the degenerate path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cache import CompiledProgramCache
from repro.telemetry import trace as _trace
from repro.telemetry.metrics import registry as _registry
from repro.core.csd import (
    CsdTier,
    OffloadStats,
    execute_extent,
    extent_geometry,
    resolve_tier,
)
from repro.core.programs import OpCode, Program
from repro.core.verifier import VerifierLimits, verify_program, verify_zone_access
from repro.core.vm import _SUM_WIDEN, jit_program_batched
from repro.array.queues import (
    Completion,
    OffloadCommand,
    QueuePair,
    CompletionQueue,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.array.striping import (
    StripeChunk,
    StripedZoneArray,
    _gather_executor,
    _off_reactor,
    coalesce_member_runs,
)
from repro.faults.errors import TransientIOError
from repro.zns.device import ZNSError, block_aligned_dtype

__all__ = ["OffloadScheduler", "ArrayOffloadStats", "ArrayOffloadError"]


class ArrayOffloadError(Exception):
    """A member device failed mid-offload (e.g. an OFFLINE zone). The message
    names the member so the operator can degrade/repair explicitly."""


@dataclass
class ArrayOffloadStats(OffloadStats):
    """Per-command statistics aggregated over the staged fan-out pipeline.

    ``read_seconds`` sums emulated transfer time across every member read;
    all of those transfers are in flight on the completion ring up front, so
    it may far exceed the ``exec_seconds`` wall time — the surplus IS the
    overlap. The per-stage figures (``read_wait_seconds`` /
    ``stage_seconds`` / ``compute_seconds`` / ``combine_seconds``) decompose
    where the dispatcher's wall time actually went.
    """

    n_devices: int = 1
    n_chunks: int = 1
    batched_chunks: int = 0        # chunks executed via a batched compiled call
    n_dispatches: int = 0          # array-wide batched compiled calls issued
    # chunks served without their preferred member: raid1 mirror redirects
    # plus xor reconstructions (degraded offloads stay bit-identical; this
    # counter is how an operator notices the array is running degraded)
    degraded_reads: int = 0
    compute_seconds: float = 0.0   # time inside compiled/interp execution only
    read_wait_seconds: float = 0.0 # wall the compute stage BLOCKED on reads
    stage_seconds: float = 0.0     # staging memcpys into the batch buffer
    combine_seconds: float = 0.0   # combiner folds (run on the gather pool)
    # max(read_seconds - read_wait_seconds, 0): member transfer time the
    # pipeline hid — under compute, and under other members' transfers
    # elapsing concurrently on the ring
    overlap_seconds: float = 0.0
    # which tenant's SQ carried the command, plus that tenant's cumulative
    # accounting (bytes/ops/p50/p99/degraded_reads from the global registry)
    # as of this command's completion — the QoS view the ROADMAP asks for
    tenant: str = "default"
    tenant_totals: dict = field(default_factory=dict)

    @property
    def fanout(self) -> str:
        return (f"{self.n_chunks} chunks / {self.n_devices} devices / "
                f"{self.n_dispatches} dispatches")

    @property
    def overlap_ratio(self) -> float:
        """Fraction of member-transfer time the pipeline hid (1.0 = the
        compute stage never blocked on the ring)."""
        return min(self.overlap_seconds / self.read_seconds, 1.0) \
            if self.read_seconds > 0 else 0.0


@dataclass
class _StageAgg:
    """Accumulator for one command's pipeline counters, filled by the
    compute stage (per-chunk serving paths park values in ``vals`` until
    they are handed to the combiner)."""

    vals: dict    # chunk index -> value
    compile_s: float = 0.0
    insns: int = 0
    batched: int = 0
    dispatches: int = 0
    degraded: int = 0
    read_s: float = 0.0
    read_wait_s: float = 0.0
    stage_s: float = 0.0
    compute_s: float = 0.0
    combine_s: float = 0.0
    hits: int = 0
    misses: int = 0

    def fold_result(self, result) -> None:
        """Merge one per-chunk :func:`execute_extent` result's counters."""
        self.compile_s += result.compile_seconds
        self.insns += result.insns_executed
        self.read_s += result.read_seconds
        self.compute_s += result.exec_seconds
        self.hits += result.cache_hits
        self.misses += result.cache_misses


@dataclass
class _MemberRun:
    """One coalesced member read of a batch group: ``items`` are
    ``(row, chunk)`` pairs (row = slot in the group's batch buffer),
    ascending and contiguous in member-local space — ONE ring transfer."""

    device: int
    items: list
    fut: object


@dataclass
class _StageGroup:
    """One batch group: the chunks that share one array-wide dispatch.

    Member runs land into the shared ``pages`` staging buffer from their
    ring completions (on the gather pool) — ``staged`` flips once every
    surviving run has scattered its rows. A group whose single run already
    covers every batch row in member order skips the buffer entirely
    (``zero_copy``) and dispatches the device view directly."""

    chunks: list
    runs: list
    pages: object = None           # staging buffer (None => zero-copy)
    zero_copy: bool = False
    pending: int = 0               # runs not yet landed
    stage_s: float = 0.0           # memcpy time spent landing (gather pool)
    lock: threading.Lock = field(default_factory=threading.Lock)
    staged: threading.Event = field(default_factory=threading.Event)


@dataclass
class _StagedReads:
    """Everything the READ stage put in flight, for the compute stage to
    consume: batch groups (one array-wide dispatch each), per-chunk tail
    reads, xor-reconstruction reads, and chunks whose member failed at
    submission time (re-served through the degraded path)."""

    groups: list = field(default_factory=list)
    m_b: int = 0                   # padded batch width shared by all groups
    rest: list = field(default_factory=list)      # (chunk, member fut)
    recon: list = field(default_factory=list)     # (chunk, array fut)
    fallback: list = field(default_factory=list)  # chunks to re-serve


class _StagedCombiner:
    """Order-preserving incremental combiner — the COMBINE stage.

    Folds per-chunk partials strictly in logical stripe order as they land
    (a cursor over the ready prefix), so the re-reduction is EXACTLY the
    sequential fold the per-command combiner always did — Kahan float-SUM
    compensation order included — keeping results bit-identical for every
    array width and degraded mode. :meth:`feed` schedules folding on the
    striping gather pool so combining overlaps the compute stage's next
    dispatch; :meth:`result` is the final rendezvous.
    """

    def __init__(self, program: Program, n_parts: int):
        self._program = program
        self._n = n_parts
        self._dtype = np.dtype(program.input_dtype)
        self._pending: dict[int, object] = {}
        self._next = 0
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self.fold_seconds = 0.0
        term = program.terminal.op
        if term == OpCode.RED_COUNT:
            self._count = 0
        elif term == OpCode.RED_SUM:
            self._widen = _SUM_WIDEN[self._dtype]
            self._acc = self._widen(0)
            self._comp = self._widen(0)   # Kahan compensation (float SUM)
        elif term in (OpCode.RED_MIN, OpCode.RED_MAX):
            self._acc = None
        elif term == OpCode.RED_HIST:
            self._acc = np.zeros(program.terminal.imm[2], np.int64)
        elif term in (OpCode.SELECT, OpCode.SELECT_REC):
            self._parts: list[np.ndarray] = []
            self._filled = 0
            self._total = 0
        else:
            raise AssertionError(term)
        if n_parts == 0:
            self._done.set()

    def feed(self, parts: dict[int, object], *, inline: bool = False) -> None:
        """Hand over ``{logical position: partial}``; the ready prefix folds
        on the gather pool (or inline) as soon as it grows."""
        with self._lock:
            self._pending.update(parts)
            runnable = self._next in self._pending
        if not runnable:
            return
        if inline:
            self._fold()
        else:
            _gather_executor().submit(self._fold)

    def fail(self, e: BaseException) -> None:
        """Poison the combine: a deferred batch materialization died before
        it could feed its rows, so the rendezvous must raise, not hang."""
        self._error = e
        self._done.set()

    def _fold(self) -> None:
        done = True
        t0 = time.perf_counter()
        try:
            with self._lock:
                while self._next in self._pending:
                    self._fold_one(self._pending.pop(self._next))
                    self._next += 1
                done = self._next == self._n
                self.fold_seconds += time.perf_counter() - t0
        except BaseException as e:  # surfaced at result(), never swallowed
            self._error = e
        if done:
            self._done.set()

    def _fold_one(self, v: object) -> None:
        term = self._program.terminal.op
        if term == OpCode.RED_COUNT:
            self._count += int(v)
        elif term == OpCode.RED_SUM:
            widen = self._widen
            if np.issubdtype(widen, np.floating):
                # Kahan compensated accumulation over the per-chunk partials,
                # in logical stripe order. The partials depend only on the
                # chunk decomposition (stripe_blocks), not on how many
                # devices the chunks landed on — so with compensation the
                # re-reduction is bit-identical for every array width over
                # the same logical data.
                y = widen(np.asarray(v)[()]) - self._comp
                t = widen(self._acc + y)
                self._comp = widen((t - self._acc) - y)
                self._acc = t
            else:
                self._acc = widen(self._acc + widen(np.asarray(v)[()]))
        elif term == OpCode.RED_MIN:
            x = np.asarray(v, self._dtype)[()]
            self._acc = x if self._acc is None else np.minimum(self._acc, x)
        elif term == OpCode.RED_MAX:
            x = np.asarray(v, self._dtype)[()]
            self._acc = x if self._acc is None else np.maximum(self._acc, x)
        elif term == OpCode.RED_HIST:
            self._acc += np.asarray(v, np.int64)
        else:                       # SELECT / SELECT_REC
            cap = self._program.select_capacity
            buf, n = np.asarray(v[0]), int(v[1])
            self._total += n
            if self._filled < cap and n > 0:
                take = min(n, cap, cap - self._filled)
                self._parts.append(buf[:take])
                self._filled += take

    def result(self) -> object:
        """Block for the last fold and return the combined terminal value."""
        self._done.wait()
        if self._error is not None:
            raise self._error
        term = self._program.terminal.op
        if term == OpCode.RED_COUNT:
            return np.int64(self._count)
        if term == OpCode.RED_SUM:
            return self._acc
        if term in (OpCode.RED_MIN, OpCode.RED_MAX):
            return self._dtype.type(self._acc)
        if term == OpCode.RED_HIST:
            return self._acc
        cap = self._program.select_capacity
        if term == OpCode.SELECT_REC:
            stride = self._program.insns[0].imm[0]
            out = np.zeros((cap, stride), self._dtype)
        else:
            out = np.zeros((cap,), self._dtype)
        if self._parts:
            cat = np.concatenate(self._parts, axis=0)
            out[: cat.shape[0]] = cat
        return out, np.int64(self._total)


class _ExtentSource:
    """Duck-typed ``ZonedDevice`` over ONE reconstructed stripe chunk held in
    host memory, addressed at the chunk's member-local offsets.

    Degraded xor chunks have no single member to read from; the array's
    reconstruction (:meth:`StripedZoneArray.submit_read`) produces the bytes,
    and this adapter lets :func:`repro.core.csd.execute_extent` run the SAME
    interp/jit/kernel tier code over them — so a degraded offload is
    bit-identical to the healthy one by construction, not by a parallel
    re-implementation of the tiers.
    """

    read_us_per_block = 0.0   # no emulation: the survivor reads already paid

    def __init__(self, block_bytes: int, base_block: int, flat: np.ndarray):
        self.block_bytes = block_bytes
        self._base = base_block
        self._flat = flat          # uint8, len == n_blocks * block_bytes

    def read_blocks_view(self, zone_id: int, block_off: int,
                         n_blocks: int) -> np.ndarray:
        lo = (block_off - self._base) * self.block_bytes
        view = self._flat[lo: lo + n_blocks * self.block_bytes].view()
        view.flags.writeable = False
        return view

    def read_extent(self, zone_id: int, block_off: int, n_blocks: int,
                    dtype) -> np.ndarray:
        dtype = block_aligned_dtype(self.block_bytes, dtype)
        return self.read_blocks_view(zone_id, block_off, n_blocks).view(dtype)


class OffloadScheduler:
    """NVMe-style scheduler over a striped zone array.

    Exposes the same part-i API as :class:`~repro.core.csd.NvmCsd`
    (``nvm_cmd_bpf_run`` / ``nvm_cmd_bpf_result`` / ``run_and_fetch``) so the
    data pipeline and checkpoint store can treat a whole array as one CSD,
    plus the queued API (``submit`` / ``drain`` / ``start`` / ``wait``).
    """

    def __init__(
        self,
        array: StripedZoneArray,
        *,
        default_tier: str = CsdTier.JIT,
        pages_per_read: int = 1,
        limits: VerifierLimits = VerifierLimits(),
        max_workers: Optional[int] = None,
        queue_depth: int = 64,
        completion_backlog: int = 1024,
        cache: Optional[CompiledProgramCache] = None,
        prefetch_depth: int = 2,
        io_timeout_s: Optional[float] = None,
    ):
        if array.stripe_blocks % pages_per_read:
            raise ValueError(
                f"stripe_blocks {array.stripe_blocks} must be a multiple of "
                f"pages_per_read {pages_per_read} (chunks must tile into pages)"
            )
        self.array = array
        self.default_tier = default_tier
        self.pages_per_read = int(pages_per_read)
        self.limits = limits
        self.queue_depth = queue_depth
        self.completion_backlog = completion_backlog
        self.prefetch_depth = int(prefetch_depth)
        # per-op join patience for chunk reads: a hung member completion
        # surfaces as a diagnostic TimeoutError naming the stuck transfer
        # instead of stranding a worker forever (None = wait indefinitely)
        self.io_timeout_s = io_timeout_s
        # ``max_workers`` is the legacy thread-per-member fan-out knob,
        # accepted for compatibility but no longer sized to the array: reads
        # are ring-driven, compute is ONE dispatcher issuing array-wide
        # batched calls, and combining rides the striping gather pool — the
        # measured useful host parallelism, independent of member count
        self.max_workers = max_workers
        # ONE cache for every tier and batch shape; programs are
        # device-agnostic so sharing (also across schedulers/CSDs, via the
        # ``cache`` argument) maximizes compile reuse
        self.cache = cache if cache is not None else CompiledProgramCache()
        self._pairs: dict[str, QueuePair] = {}
        self._arbiter = WeightedRoundRobinArbiter()
        self._completions: dict[int, Completion] = {}
        self._watched: set[int] = set()   # cmd_ids a sync caller will wait() on
        self._pending: set[int] = set()   # submitted, not yet completed
        self._comp_cond = threading.Condition()
        self._result: Optional[Completion] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self.history: list[ArrayOffloadStats] = []
        self.register_tenant("default")

    # ------------------------------------------------------------ tenants
    def register_tenant(self, tenant: str, *, weight: int = 1,
                        depth: Optional[int] = None) -> QueuePair:
        """Create an SQ/CQ pair for ``tenant`` with a WRR ``weight``."""
        if tenant in self._pairs:
            raise ValueError(f"tenant {tenant!r} already registered")
        pair = QueuePair(
            SubmissionQueue(tenant, depth=depth or self.queue_depth,
                            weight=weight),
            CompletionQueue(tenant, depth=self.completion_backlog),
        )
        self._pairs[tenant] = pair
        self._arbiter.add(pair)
        return pair

    def queue_pair(self, tenant: str = "default") -> QueuePair:
        return self._pairs[tenant]

    # ------------------------------------------------------------- submit
    def submit(
        self,
        program: Program,
        zone_id: int,
        *,
        tenant: str = "default",
        block_off: int = 0,
        n_blocks: Optional[int] = None,
        tier: Optional[str] = None,
        block: bool = False,
        timeout: Optional[float] = None,
        _watch: bool = False,
    ) -> int:
        """Verify and enqueue an offload; returns the command id.

        Verification happens HERE — a rejected program never occupies a queue
        slot, and the SQ carries only admitted commands. A full SQ raises
        :class:`~repro.array.queues.QueueFullError` unless ``block=True``
        (backpressure).
        """
        pair = self._pairs[tenant]
        zone = self.array.zone(zone_id)
        if n_blocks is None:
            n_blocks = zone.write_pointer - block_off
        if block_off % self.pages_per_read:
            raise ValueError(
                f"block_off {block_off} not aligned to read granularity "
                f"{self.pages_per_read}")
        dtype = np.dtype(program.input_dtype)
        page_elems, n_pages = extent_geometry(
            self.array.block_bytes, dtype, n_blocks, self.pages_per_read)
        t_v = time.perf_counter()
        with _trace.span("offload.verify", tenant=tenant, zone=zone_id,
                         program=program.name):
            insns_verified = verify_program(
                program, page_elems=page_elems, n_pages=n_pages,
                limits=self.limits)
            verify_zone_access(
                zone_write_pointer=zone.write_pointer, block_off=block_off,
                n_blocks=n_blocks)
        _registry().histogram("sched.verify_seconds").observe(
            time.perf_counter() - t_v)
        cmd = OffloadCommand(
            program=program, zone_id=zone_id, block_off=block_off,
            n_blocks=n_blocks,
            tier=resolve_tier(tier or self.default_tier, program),
            tenant=tenant, insns_verified=insns_verified,
        )
        # register BEFORE the dispatcher can see the command: _pending lets
        # wait() distinguish in-flight from evicted/unknown, and a watch
        # protects a sync caller's completion from backlog eviction
        with self._comp_cond:
            self._pending.add(cmd.cmd_id)
            if _watch:
                self._watched.add(cmd.cmd_id)
        try:
            pair.sq.submit(cmd, block=block, timeout=timeout)
        except BaseException:
            with self._comp_cond:
                self._pending.discard(cmd.cmd_id)
                self._watched.discard(cmd.cmd_id)
            raise
        self._wake.set()
        return cmd.cmd_id

    # ------------------------------------------------------------ raw I/O
    def submit_io(
        self,
        io_op: str,
        zone_id: int,
        *,
        block_off: int = 0,
        n_blocks: Optional[int] = None,
        data: Optional[np.ndarray] = None,
        tenant: str = "default",
        member: Optional[int] = None,
        block: bool = False,
        timeout: Optional[float] = None,
        on_complete=None,
        _watch: bool = False,
    ) -> int:
        """Enqueue a RAW device I/O command ("read"/"append") on a tenant's
        SQ; returns the command id. The dispatcher forwards it to the array's
        completion ring WITHOUT blocking, so raw I/O (checkpoint traffic)
        overlaps with offload execution while paying its way through the same
        WRR arbitration as offloads. The SQ depth bounds QUEUED commands
        (admission, felt when the dispatcher is busy executing offloads); the
        number of in-flight transfers is bounded by the device's per-zone
        clocks, not the queue — forwarded commands leave the SQ immediately.

        ``member`` targets ONE array member instead of the logical array —
        the rebuild/scrub path: member-local addressing, same tenant SQs,
        same WRR metering against live offload traffic.
        """
        if io_op not in ("read", "append"):
            raise ValueError(f"unknown io_op {io_op!r}")
        pair = self._pairs[tenant]
        if io_op == "read":
            if member is None:
                zone = self.array.zone(zone_id)
            else:
                zone = self.array.devices[member].zone(zone_id)
            if n_blocks is None:
                n_blocks = zone.write_pointer - block_off
            verify_zone_access(
                zone_write_pointer=zone.write_pointer, block_off=block_off,
                n_blocks=n_blocks)
        elif data is None:
            raise ValueError("append command requires data")
        cmd = OffloadCommand(
            program=None, zone_id=zone_id, block_off=block_off,
            n_blocks=n_blocks, tier=None, tenant=tenant,
            io_op=io_op, data=data, member=member, on_complete=on_complete,
        )
        with self._comp_cond:
            self._pending.add(cmd.cmd_id)
            if _watch:
                self._watched.add(cmd.cmd_id)
        try:
            pair.sq.submit(cmd, block=block, timeout=timeout)
        except BaseException:
            with self._comp_cond:
                self._pending.discard(cmd.cmd_id)
                self._watched.discard(cmd.cmd_id)
            raise
        self._wake.set()
        return cmd.cmd_id

    # ----------------------------------------------------------- dispatch
    def dispatch_one(self) -> bool:
        """Arbitrate and launch ONE queued command. Returns False when every
        SQ is empty. Offload commands execute to completion here; raw I/O
        commands are forwarded to the completion ring and retire later (their
        completion lands via the reactor, not this thread)."""
        nxt = self._arbiter.next_command()
        if nxt is None:
            return False
        cmd, pair = nxt
        if _trace.enabled() and cmd.submitted_at:
            # SQ residency as a trace event on the tenant's own track —
            # emitted post-hoc now that the interval is known
            _trace.event_complete(
                "offload.queued", cmd.submitted_at,
                time.monotonic() - cmd.submitted_at,
                track=f"tenant/{cmd.tenant}", tenant=cmd.tenant,
                cmd=cmd.cmd_id)
        if cmd.io_op is not None:
            self._dispatch_io(cmd, pair)
            return True
        try:
            with _trace.span("offload.execute", tenant=cmd.tenant,
                             tier=cmd.tier, zone=cmd.zone_id,
                             program=cmd.program.name):
                value, stats = self._execute(cmd)
            comp = Completion(cmd.cmd_id, cmd.tenant, value=value, stats=stats)
            self.history.append(stats)
            self._publish_stats(stats)
        except Exception as e:  # surfaced via the CQ, never swallowed
            comp = Completion(cmd.cmd_id, cmd.tenant, error=e)
        self._finish(cmd, pair, comp)
        return True

    def _dispatch_io(self, cmd: OffloadCommand, pair: QueuePair) -> None:
        """Forward a raw I/O command to the array's submit path. Never blocks
        on the emulated transfer: the ring retires the completion, and the
        scheduler's completion bookkeeping runs from its done-callback."""
        try:
            target = self.array if cmd.member is None \
                else self.array.devices[cmd.member]
            if cmd.io_op == "append":
                fut = target.submit_append(cmd.zone_id, cmd.data)
            else:
                fut = target.submit_read(cmd.zone_id, cmd.block_off,
                                         cmd.n_blocks)
        except Exception as e:
            self._finish(cmd, pair, Completion(cmd.cmd_id, cmd.tenant, error=e))
            return
        fut.tenant = cmd.tenant    # stuck-op diagnostics name the owner
        fut.add_done_callback(lambda f: self._finish(
            cmd, pair,
            Completion(cmd.cmd_id, cmd.tenant,
                       value=None if f.error is not None else f.value,
                       error=f.error)))

    @staticmethod
    def _publish_stats(stats: ArrayOffloadStats) -> None:
        """Fold one command's ArrayOffloadStats into the global registry, so
        ``metrics.registry().snapshot()`` shows the rolling offload picture
        (commands, read/compute/overlap seconds, the latest overlap ratio)
        next to the cache and gather-pool series."""
        reg = _registry()
        reg.counter("offload.commands").inc()
        reg.counter("offload.dispatches").inc(stats.n_dispatches)
        reg.histogram("offload.exec_seconds").observe(stats.exec_seconds)
        reg.histogram("offload.read_seconds").observe(stats.read_seconds)
        reg.histogram("offload.read_wait_seconds").observe(
            stats.read_wait_seconds)
        reg.histogram("offload.overlap_seconds").observe(stats.overlap_seconds)
        reg.gauge("offload.overlap_ratio").set(stats.overlap_ratio)

    def _account_tenant(self, cmd: OffloadCommand, comp: Completion) -> None:
        """Per-tenant QoS accounting at completion time (offloads AND raw
        I/O ride through here): bytes moved, ops, end-to-end command latency
        (SQ entry → completion, the SLO the alert rules watch), errors, and
        degraded-read counts. Tenant names are a bounded set (queues.py), so
        the series live on the global registry."""
        reg = _registry()
        t = cmd.tenant
        reg.counter(f"tenant.{t}.ops").inc()
        if comp.error is not None:
            reg.counter(f"tenant.{t}.errors").inc()
        if cmd.io_op == "append" and cmd.data is not None:
            nbytes = int(np.asarray(cmd.data).nbytes)
        else:
            nbytes = (cmd.n_blocks or 0) * self.array.block_bytes
        if nbytes:
            reg.counter(f"tenant.{t}.bytes").inc(nbytes)
        if cmd.submitted_at:
            reg.histogram(
                f"tenant.{t}.offload_latency_seconds").observe(
                    time.monotonic() - cmd.submitted_at)
        degraded = getattr(comp.stats, "degraded_reads", 0)
        if degraded:
            reg.counter(f"tenant.{t}.degraded_reads").inc(degraded)
        if comp.stats is not None:
            comp.stats.tenant_totals = self._tenant_snapshot(t)

    def _tenant_snapshot(self, tenant: str) -> dict:
        """One tenant's cumulative accounting, read straight off the series
        handles (no full registry snapshot on the completion path)."""
        reg = _registry()
        pfx = f"tenant.{tenant}."
        lat = reg.histogram(pfx + "offload_latency_seconds")
        return {
            "tenant": tenant,
            "bytes": reg.counter(pfx + "bytes").value,
            "ops": reg.counter(pfx + "ops").value,
            "errors": reg.counter(pfx + "errors").value,
            "degraded_reads": reg.counter(pfx + "degraded_reads").value,
            "p50_s": lat.percentile(50),
            "p99_s": lat.percentile(99),
        }

    def tenant_stats(self) -> dict[str, dict]:
        """``{tenant: {bytes, ops, errors, degraded_reads, p50_s, p99_s}}``
        for every registered tenant — the QoS report the ROADMAP's
        per-tenant accounting item asks for (``zcsd-top`` renders it live)."""
        return {t: self._tenant_snapshot(t) for t in self._pairs}

    def _finish(self, cmd: OffloadCommand, pair: QueuePair,
                comp: Completion) -> None:
        """Completion bookkeeping shared by the synchronous offload path and
        the ring-retired raw-I/O path (any thread may run this)."""
        self._account_tenant(cmd, comp)
        with self._comp_cond:
            watched = cmd.cmd_id in self._watched
        # when the payload has a dedicated consumer — a sync caller's wait()
        # (watched) or an on_complete hook — every OTHER completion surface
        # gets a payload-free record (stats/errors stay observable), so
        # neither the CQ ring nor the wait() rendezvous pins up to `depth`
        # dead result buffers (e.g. a queue-routed restore's leaf extents)
        stripped = Completion(cmd.cmd_id, cmd.tenant, value=None,
                              stats=comp.stats, error=comp.error) \
            if (watched or cmd.on_complete is not None) else comp
        pair.cq.push(stripped)
        stored = comp if watched else stripped
        with self._comp_cond:
            self._completions[cmd.cmd_id] = stored
            self._pending.discard(cmd.cmd_id)
            # bound the wait() rendezvous: consumers that read the CQ directly
            # never pop here, so evict oldest-first past the backlog limit —
            # but never a completion a sync caller has reserved with a watch
            while len(self._completions) > self.completion_backlog:
                victim = next((k for k in self._completions
                               if k not in self._watched), None)
                if victim is None:
                    break
                self._completions.pop(victim)
            if cmd.program is not None:
                # raw I/O must not clobber the part-i last-result register
                self._result = comp
            self._comp_cond.notify_all()
        if cmd.on_complete is not None:
            try:
                cmd.on_complete(comp)
            except Exception:
                pass  # a consumer hook must not kill the dispatcher/reactor

    def drain(self) -> int:
        """Dispatch until every submission queue is empty (synchronous pump)."""
        n = 0
        while self.dispatch_one():
            n += 1
        return n

    def wait(self, cmd_id: int, *, timeout: Optional[float] = None) -> Completion:
        """Block until ``cmd_id`` completes (requires a running dispatcher or
        a concurrent ``drain``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._comp_cond:
            while cmd_id not in self._completions:
                if cmd_id not in self._pending:
                    raise LookupError(
                        f"command {cmd_id} has no pending completion (already "
                        f"waited, evicted past completion_backlog, or unknown)")
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"command {cmd_id} still pending")
                self._comp_cond.wait(timeout=remaining)
            self._watched.discard(cmd_id)
            return self._completions.pop(cmd_id)

    def start(self) -> None:
        """Run the dispatcher on a background thread (async mode — the
        paper's stated future extension, at array scope)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.dispatch_one():
                    self._wake.wait(timeout=0.01)
                    self._wake.clear()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="offload-dispatcher")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def close(self) -> None:
        """Stop the dispatcher (if running). The staged pipeline owns no
        worker pool — reads ride the completion ring and combining the
        shared gather pool — so there is nothing else to release. The
        scheduler is unusable afterwards; the array is not."""
        self.stop()

    def __enter__(self) -> "OffloadScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------- NvmCsd-compatible part-i
    def _run_sync(self, program: Program, zone_id: int, *,
                  block_off: int = 0, n_blocks: Optional[int] = None,
                  tier: Optional[str] = None,
                  tenant: str = "default") -> Completion:
        """Submit, wait, and return THIS command's completion (not the shared
        last-result register, which another tenant may overwrite)."""
        cmd_id = self.submit(program, zone_id, tenant=tenant,
                             block_off=block_off, n_blocks=n_blocks, tier=tier,
                             _watch=True)
        if self._thread is None:
            self.drain()
        # unbounded wait is safe: either a dispatcher thread is running, or
        # drain() returned with every SQ empty — meaning our command was
        # popped (possibly by a concurrent caller's drain) and its completion
        # is forthcoming
        comp = self.wait(cmd_id)
        if comp.error is not None:
            raise comp.error
        return comp

    def nvm_cmd_bpf_run(self, program: Program, zone_id: int, *,
                        block_off: int = 0, n_blocks: Optional[int] = None,
                        tier: Optional[str] = None,
                        tenant: str = "default") -> ArrayOffloadStats:
        """Synchronous verified offload over the whole array (the degenerate
        single-command path through the queue machinery)."""
        return self._run_sync(program, zone_id, block_off=block_off,
                              n_blocks=n_blocks, tier=tier, tenant=tenant).stats

    def nvm_cmd_bpf_result(self) -> object:
        if self._result is None or self._result.error is not None:
            raise RuntimeError("no offload result available")
        return self._result.value

    def run_and_fetch(self, program: Program, zone_id: int, **kw):
        comp = self._run_sync(program, zone_id, **kw)
        return comp.value, comp.stats

    # ---------------------------------------------------------- execution
    def _execute(self, cmd: OffloadCommand) -> tuple[object, ArrayOffloadStats]:
        """Three-stage offload pipeline.

        1. **read stage** — every member transfer the plan needs goes in
           flight on the completion ring UP FRONT: coalesced chunk-group
           reads per member, tail-chunk reads, xor survivor reconstructions.
           No thread parks per transfer; in-flight depth is bounded by the
           emulated devices.
        2. **compute stage** — ONE dispatcher consumes staged groups in
           logical order and issues ONE array-wide batched compiled call per
           group over the chunks of ALL members (total chunk count is a
           property of the logical extent, so the dispatch shape — and the
           host work — is constant across array widths). Tail, degraded and
           fallback chunks ride the same staged bytes through the plain
           per-chunk executables.
        3. **combine stage** — the program-aware combiner folds per-chunk
           partials in logical stripe order ON THE GATHER POOL as results
           land, off the straggler's critical path; the stage span covers
           only the final rendezvous.
        """
        program, zone_id, tier = cmd.program, cmd.zone_id, cmd.tier
        array = self.array
        reg = _registry()
        t_p = time.perf_counter()
        with _trace.span("offload.plan"):
            try:
                chunks = array.chunks(zone_id, cmd.block_off, cmd.n_blocks)
            except (ZNSError, TransientIOError) as e:
                # the PR 2 clean-error contract: callers handle degraded/
                # failed offloads via ArrayOffloadError, whether one raid0
                # member died or the loss defeated the redundancy mode
                raise ArrayOffloadError(
                    f"offload failed: zone {zone_id} unrecoverable under "
                    f"{array.redundancy}: {e}"
                ) from e
        reg.histogram("sched.plan_seconds").observe(time.perf_counter() - t_p)
        if any(c.degraded for c in chunks):
            array.note_degraded_serving(zone_id)
        n_members = len({c.device for c in chunks})
        pos_of = {c.index: p for p, c in enumerate(chunks)}

        t0 = time.perf_counter()
        t_r = time.perf_counter()
        with _trace.span("offload.stage.read", devices=n_members,
                         chunks=len(chunks)):
            staged = self._submit_stage_reads(zone_id, chunks, program, tier)
        reg.histogram("sched.stage.read_seconds").observe(
            time.perf_counter() - t_r)

        agg = _StageAgg({})
        combiner = _StagedCombiner(program, len(chunks))
        t_x = time.perf_counter()
        with _trace.span("offload.stage.compute", groups=len(staged.groups),
                         chunks=len(chunks)):
            self._compute_stage(cmd, staged, pos_of, agg, combiner)
        reg.histogram("sched.stage.compute_seconds").observe(
            time.perf_counter() - t_x)

        t_c = time.perf_counter()
        with _trace.span("offload.stage.combine"):
            value = combiner.result()
        agg.combine_s = combiner.fold_seconds
        reg.histogram("sched.stage.combine_seconds").observe(
            time.perf_counter() - t_c)
        # keep exec and JIT time disjoint, as NvmCsd reports them (compiles
        # happen inside the pipeline wall time on cache misses)
        exec_seconds = max(time.perf_counter() - t0 - agg.compile_s, 0.0)

        if isinstance(value, tuple):
            bytes_returned = np.asarray(value[0]).nbytes + 8
        else:
            bytes_returned = np.asarray(value).nbytes
        stats = ArrayOffloadStats(
            program=program.name, tier=tier, zone_id=zone_id,
            pages=cmd.n_blocks // self.pages_per_read,
            insns_verified=cmd.insns_verified,
            insns_executed=agg.insns,
            bytes_read=cmd.n_blocks * array.block_bytes,
            bytes_returned=bytes_returned,
            jit_seconds=agg.compile_s, exec_seconds=exec_seconds,
            read_seconds=agg.read_s, compute_seconds=agg.compute_s,
            read_wait_seconds=agg.read_wait_s, stage_seconds=agg.stage_s,
            combine_seconds=agg.combine_s,
            overlap_seconds=max(agg.read_s - agg.read_wait_s, 0.0),
            cache_hits=agg.hits, cache_misses=agg.misses,
            n_devices=n_members, n_chunks=len(chunks),
            batched_chunks=agg.batched, n_dispatches=agg.dispatches,
            degraded_reads=agg.degraded,
            tenant=cmd.tenant,
        )
        return value, stats

    # ----------------------------------------------------------- read stage
    def _submit_stage_reads(self, zone_id: int, chunks: list[StripeChunk],
                            program: Program, tier: str) -> "_StagedReads":
        """READ stage: classify the planned chunks and put every member
        transfer in flight before any compute runs.

        Full-size chunks (jit/kernel tiers, more than one) form the batch
        groups: consecutive logical chunks, bucketed to a power-of-two batch
        width, each group's member shares coalesced into maximal contiguous
        runs — ONE ring read per run (raid0/xor coalesce whole groups;
        raid1's round-robin replica assignment is member-locally
        discontiguous and degrades to per-chunk runs, all still in flight up
        front). Tail chunks and xor reconstructions submit alongside. A
        member that fails AT SUBMISSION parks its chunks on the fallback
        list for the degraded re-serve (raid0 raises — the PR 2 clean-error
        contract)."""
        array = self.array
        stripe = array.stripe_blocks
        dtype = np.dtype(program.input_dtype)
        direct = [c for c in chunks if not c.reconstruct]
        recon = [c for c in chunks if c.reconstruct]
        full = [c for c in direct if c.n_blocks == stripe]
        # a single full chunk reuses the plain single-chunk executable
        # (shared with NvmCsd) instead of compiling a batch-of-1 variant
        if tier in (CsdTier.JIT, CsdTier.KERNEL) and len(full) > 1:
            rest = [c for c in direct if c.n_blocks != stripe]
        else:
            full, rest = [], direct
        staged = _StagedReads()
        if full:
            m = len(full)
            # Split into pipeline groups, then bucket the group size to a
            # power of two and zero-pad the tail group, so compiles stay
            # O(#programs x log(total chunks)) instead of one per distinct
            # extent size; pad-row outputs are discarded at dispatch. Floor
            # of 2: a batch-of-1 variant would duplicate the plain
            # single-chunk executable at the cost of an extra XLA compile.
            n_groups = max(min(self.prefetch_depth, m), 1)
            staged.m_b = max(1 << (-(-m // n_groups) - 1).bit_length(), 2)
            page_elems, chunk_pages = extent_geometry(
                array.block_bytes, dtype, stripe, self.pages_per_read)
            for i in range(0, m, staged.m_b):
                grp_chunks = full[i:i + staged.m_b]
                runs = []
                for dev_idx, items in coalesce_member_runs(grp_chunks,
                                                           stripe):
                    n_blocks = sum(c.n_blocks for _, c in items)
                    try:
                        fut = array.devices[dev_idx].submit_read(
                            zone_id, items[0][1].local_off, n_blocks,
                            dtype=dtype)
                    except (ZNSError, TransientIOError) as e:
                        self._member_failed(dev_idx, zone_id, e)
                        staged.fallback.extend(c for _, c in items)
                        continue
                    runs.append(_MemberRun(dev_idx, items, fut))
                grp = _StageGroup(grp_chunks, runs)
                one = runs[0] if len(runs) == 1 else None
                if (one is not None and len(one.items) == staged.m_b
                        and all(row == j
                                for j, (row, _) in enumerate(one.items))):
                    # the one run covers every batch row in member order
                    # (the 1-member case): dispatch the device view as-is
                    grp.zero_copy = True
                    grp.staged.set()
                else:
                    # np.empty, not zeros: every served row is overwritten by
                    # staging, and rows whose member read failed feed garbage
                    # to batch outputs that are discarded — zero-filling
                    # 2×stripe-width of pages here costs real dispatcher
                    # milliseconds at 8 members
                    grp.pages = np.empty(
                        (staged.m_b, chunk_pages, page_elems), dtype)
                    grp.pending = len(runs)
                    if not runs:
                        grp.staged.set()
                    for run in runs:
                        self._stage_on_land(grp, run, chunk_pages,
                                            page_elems)
                staged.groups.append(grp)
        for c in rest:
            try:
                fut = array.devices[c.device].submit_read(
                    zone_id, c.local_off, c.n_blocks)
            except (ZNSError, TransientIOError) as e:
                self._member_failed(c.device, zone_id, e)
                staged.fallback.append(c)
                continue
            staged.rest.append((c, fut))
        for c in recon:
            try:
                staged.recon.append(
                    (c, array.submit_read(zone_id, c.logical_off,
                                          c.n_blocks)))
            except (ZNSError, TransientIOError) as e:
                raise ArrayOffloadError(
                    f"offload failed: chunk {c.index} of zone {zone_id} is "
                    f"unrecoverable under {array.redundancy}: {e}"
                ) from e
        return staged

    @staticmethod
    def _stage_on_land(grp: "_StageGroup", run: "_MemberRun",
                       chunk_pages: int, page_elems: int) -> None:
        """Scatter one member run into the group's staging buffer the moment
        its ring completion retires — on the gather pool, never the reactor
        thread — so staging memcpys hide under the remaining members'
        transfers and the previous group's dispatch instead of serializing
        on the dispatcher's critical path."""
        def copy():
            t0 = time.perf_counter()
            try:
                if run.fut.error is None:
                    part = np.asarray(run.fut.value).reshape(
                        len(run.items), chunk_pages, page_elems)
                    for j, (row, _c) in enumerate(run.items):
                        grp.pages[row] = part[j]
            finally:
                with grp.lock:
                    grp.stage_s += time.perf_counter() - t0
                    grp.pending -= 1
                    if grp.pending == 0:
                        grp.staged.set()
        # Always hop to the gather pool: the callback fires inline on the
        # DISPATCHER thread when a short emulated transfer retires before
        # registration, and an inline memcpy there serializes all staging
        # into the read-submission loop — the exact cliff this stage hides.
        run.fut.add_done_callback(lambda _f: _gather_executor().submit(copy))

    # -------------------------------------------------------- compute stage
    def _compute_stage(self, cmd: OffloadCommand, staged: "_StagedReads",
                       pos_of: dict[int, int], agg: "_StageAgg",
                       combiner: "_StagedCombiner") -> None:
        """COMPUTE stage: one dispatcher thread drains the staged reads in
        logical order and issues one array-wide batched compiled call per
        group; every partial is handed to the combiner the moment it exists,
        so combining overlaps the next group's read wait and dispatch.

        A ``TransientIOError`` surfacing on one member's group read does NOT
        poison the batch: the surviving runs still stage and dispatch
        together (the dead member's rows stay unstaged and their outputs
        are discarded), and the failed member's chunks re-serve individually
        through the array's degraded read — raid1 mirror redirect / xor
        reconstruction, the exact observable behavior of the pre-staged
        per-worker fallback."""
        program, zone_id, tier = cmd.program, cmd.zone_id, cmd.tier
        array = self.array
        reg = _registry()
        stripe = array.stripe_blocks

        def serve_degraded(c: StripeChunk, fut=None) -> None:
            with _trace.span("stage.serve_chunk", chunk=c.index,
                             degraded=True):
                self._run_chunk_degraded(zone_id, c, program, tier, agg,
                                         fut=fut)
            combiner.feed({pos_of[c.index]: agg.vals.pop(c.index)})

        if staged.groups:
            m_b = staged.m_b
            dtype = np.dtype(program.input_dtype)
            page_elems, chunk_pages = extent_geometry(
                array.block_bytes, dtype, stripe, self.pages_per_read)
            if tier == CsdTier.KERNEL:
                from repro.kernels.zone_filter import ops as zf_ops
                key = ("kernel_batched", program, m_b, chunk_pages,
                       page_elems)
                builder = lambda: zf_ops.kernel_program_batched(
                    program, m_b, chunk_pages, page_elems)
            else:
                key = ("jit_batched", program, m_b, chunk_pages, page_elems)
                builder = lambda: jit_program_batched(
                    program, m_b, chunk_pages, page_elems)
            jp, compile_s, hit = self.cache.get_or_build(key, builder)
            agg.compile_s += compile_s
            agg.hits += int(hit)
            agg.misses += int(not hit)
        for grp in staged.groups:
            # read_wait = wall time the dispatcher BLOCKED on this group's
            # ring completions and their staging (near zero when earlier
            # groups' dispatch covered the transfers) — the number that
            # grows if the pipeline serializes on I/O
            served = []
            raw0 = None
            t_w = time.perf_counter()
            with _trace.span("stage.read_wait", chunks=len(grp.chunks)):
                for run in grp.runs:
                    try:
                        raw0 = run.fut.result(self.io_timeout_s)
                    except (ZNSError, TransientIOError) as e:
                        self._member_failed(run.device, zone_id, e)
                        staged.fallback.extend(c for _, c in run.items)
                        continue
                    agg.read_s += run.fut.service_seconds
                    served.extend(run.items)
                if not grp.staged.wait(self.io_timeout_s):
                    raise TimeoutError(
                        f"offload staging stalled on zone {zone_id}: "
                        f"{grp.pending} member runs never landed "
                        f"(gather pool wedged?)")
            dt = time.perf_counter() - t_w
            agg.read_wait_s += dt
            reg.histogram("sched.stage.read_wait_seconds").observe(dt)
            if not served:
                continue
            with _trace.span("stage.staging", chunks=len(served)):
                if grp.zero_copy:
                    pages = np.asarray(raw0).reshape(m_b, chunk_pages,
                                                     page_elems)
                else:
                    pages = grp.pages
            agg.stage_s += grp.stage_s
            reg.histogram("sched.stage.staging_seconds").observe(grp.stage_s)
            t_d = time.perf_counter()
            with _trace.span("stage.dispatch", chunks=len(served)):
                out = jp(pages)
            dt = time.perf_counter() - t_d
            agg.compute_s += dt
            agg.dispatches += 1
            reg.histogram("sched.stage.dispatch_seconds").observe(dt)
            agg.batched += len(served)
            agg.degraded += sum(1 for _, c in served if c.degraded)
            # Materialize the batch output OFF the dispatcher: np.asarray on
            # the lazy jax result blocks until XLA finishes, and paying that
            # here would serialize group k's compute ahead of group k+1's
            # read wait and dispatch — the pool thread eats the wait instead,
            # then feeds the combiner its rows in one go.
            rows = [(row, pos_of[c.index]) for row, c in served]

            def land(out=out, rows=rows):
                try:
                    with _trace.span("stage.materialize", rows=len(rows)):
                        if isinstance(out, tuple):
                            bufs, ns = (np.asarray(v) for v in out)
                            vals = {pos: (bufs[row], ns[row])
                                    for row, pos in rows}
                        else:
                            o = np.asarray(out)
                            vals = {pos: o[row] for row, pos in rows}
                    combiner.feed(vals)
                except BaseException as e:
                    combiner.fail(e)

            _gather_executor().submit(land)
        if staged.groups:
            agg.insns += program.n_insns * agg.batched * (
                stripe // self.pages_per_read)

        for c, fut in staged.rest:
            t_w = time.perf_counter()
            try:
                flat = np.asarray(fut.result(self.io_timeout_s))
            except (ZNSError, TransientIOError) as e:
                agg.read_wait_s += time.perf_counter() - t_w
                self._member_failed(c.device, zone_id, e)
                serve_degraded(c)
                continue
            agg.read_wait_s += time.perf_counter() - t_w
            agg.read_s += fut.service_seconds
            with _trace.span("stage.serve_chunk", chunk=c.index):
                src = _ExtentSource(array.block_bytes, c.local_off, flat)
                result = execute_extent(
                    src, program, zone_id, c.local_off, c.n_blocks,
                    tier=tier, pages_per_read=self.pages_per_read,
                    cache=self.cache, prefetch_depth=0,
                )
            if c.degraded:
                agg.degraded += 1
            agg.fold_result(result)
            combiner.feed({pos_of[c.index]: result.value})
        for c, fut in staged.recon:
            serve_degraded(c, fut=fut)
        for c in staged.fallback:
            serve_degraded(c)

    def _member_failed(self, dev_idx: int, zone_id: int,
                   e: Exception) -> None:
        """Raise the PR 2 clean degradation error when the array has no
        redundancy to absorb the member failure; otherwise return and let
        the caller reconstruct."""
        if self.array.redundancy == "raid0":
            raise ArrayOffloadError(
                f"offload degraded: member device {dev_idx} failed on zone "
                f"{zone_id}: {e}"
            ) from e

    def _run_chunk_degraded(self, zone_id: int, c: StripeChunk,
                            program: Program, tier: str,
                            agg: "_StageAgg", *,
                            fut=None) -> None:
        """Execute one chunk whose member cannot serve it: rebuild the bytes
        through the array's degraded read (raid1 mirror redirect / xor
        survivor reconstruction, riding the completion ring) and run the
        SAME execution tier over the host buffer — bit-identical results by
        construction. Pass a pre-submitted ``fut`` to overlap many chunks'
        reconstruction transfers (the planned-degraded fan-out does)."""
        t_w = time.perf_counter()
        try:
            if fut is None:
                fut = self.array.submit_read(zone_id, c.logical_off,
                                             c.n_blocks)
            flat = np.asarray(fut.result(self.io_timeout_s))
        except (ZNSError, TransientIOError) as e:
            raise ArrayOffloadError(
                f"offload failed: chunk {c.index} of zone {zone_id} is "
                f"unrecoverable under {self.array.redundancy}: {e}"
            ) from e
        finally:
            agg.read_wait_s += time.perf_counter() - t_w
        src = _ExtentSource(self.array.block_bytes, c.local_off, flat)
        result = execute_extent(
            src, program, zone_id, c.local_off, c.n_blocks,
            tier=tier, pages_per_read=self.pages_per_read,
            cache=self.cache, prefetch_depth=0,
        )
        agg.vals[c.index] = result.value
        agg.fold_result(result)
        agg.read_s += fut.service_seconds
        agg.degraded += 1

    # ----------------------------------------------------------- combiner
    def _combine(self, program: Program, ordered: list[object]) -> object:
        """Re-reduce per-chunk results in logical stripe order — the
        scatter-gather step, as one inline fold. Semantics match
        :func:`repro.core.vm.run_oracle` over the concatenated logical
        stream; the staged pipeline streams the same fold incrementally
        through :class:`_StagedCombiner`."""
        comb = _StagedCombiner(program, len(ordered))
        comb.feed(dict(enumerate(ordered)), inline=True)
        return comb.result()
