"""Zoned Namespace (ZNS) storage substrate.

Software emulation of an NVMe ZNS device (host-memory or file backed), faithful
to the semantics the paper builds on: fixed-size zones, append-only writes at a
per-zone write pointer, explicit zone states (EMPTY/OPEN/FULL/READ_ONLY),
host-managed reset (garbage collection), and block-granular reads — plus the
NVMe-style asynchronous completion model (:mod:`repro.zns.ring`): submit
queues' worth of reads/appends and let ONE reactor thread retire them in
emulated-deadline order.
"""
from repro.zns.device import (
    Zone,
    ZoneState,
    ZonedDevice,
    ZNSError,
    ZoneFullError,
    ZoneStateError,
    OutOfBoundsError,
    payload_as_uint8,
)
from repro.zns.ring import (
    CompletionBarrier,
    CompletionRing,
    IoFuture,
    IoReactor,
)

__all__ = [
    "Zone",
    "ZoneState",
    "ZonedDevice",
    "ZNSError",
    "ZoneFullError",
    "ZoneStateError",
    "OutOfBoundsError",
    "payload_as_uint8",
    "CompletionBarrier",
    "CompletionRing",
    "IoFuture",
    "IoReactor",
]
