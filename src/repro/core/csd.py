"""The ZCSD device: zoned storage + verified offload execution.

Mirrors the paper's two-part ``NvmCsd`` API (Listing 1):

  part-i  (app <-> ZCSD): :meth:`NvmCsd.nvm_cmd_bpf_run` submits a program and
          executes it synchronously; :meth:`NvmCsd.nvm_cmd_bpf_result` fetches
          the result. :meth:`NvmCsd.nvm_cmd_bpf_run_async` is the asynchronous
          extension the paper lists as future work.
  part-ii (program <-> device hooks): :meth:`bpf_read` (bounds-checked page
          read), :meth:`bpf_return_data`, :meth:`bpf_get_lba_size`,
          :meth:`bpf_get_mem_info` — the environment the interpreter tier
          executes against.

The device keeps the paper's per-offload statistics: runtime, number of
instructions executed, JIT time, and the amount of data movement saved.

Workflow lifecycle (paper Figure 1): (1) app calls the API with a program;
(2,3) device reads the necessary blocks from the ZNS zone; (4,5) program is
verified and JITed; (6) only the (reduced) result returns to the app.
"""
from __future__ import annotations

import concurrent.futures
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cache import CompiledProgramCache
from repro.core.prefetch import RingReader
from repro.telemetry import trace as _trace
from repro.core.programs import OpCode, Program
from repro.core.verifier import VerifierLimits, verify_program, verify_zone_access
from repro.core.vm import (
    OffloadResult,
    interpret_program,
    jit_program,
    run_oracle,
)
from repro.zns.device import ZonedDevice

__all__ = ["NvmCsd", "OffloadStats", "CsdTier", "extent_geometry",
           "execute_extent", "resolve_tier"]

TIERS = ("interp", "jit", "kernel")


def resolve_tier(tier: str, program: Program) -> str:
    """The tier that will actually execute ``program``: kernel-tier requests
    for non-kernelizable programs fall back to the XLA JIT tier, and the
    stats/history must say so rather than mis-attributing JIT timings."""
    if tier == CsdTier.KERNEL:
        from repro.kernels.zone_filter import ops as zf_ops
        if not zf_ops.kernelizable(program):
            return CsdTier.JIT
    return tier


def extent_geometry(
    block_bytes: int, dtype: np.dtype, n_blocks: int, pages_per_read: int
) -> tuple[int, int]:
    """Page geometry of a zone extent: (elements per page, number of pages).

    Raises ValueError when the extent does not tile into whole pages — the
    alignment contract every execution tier relies on.
    """
    page_elems = block_bytes * pages_per_read // dtype.itemsize
    if block_bytes * pages_per_read % dtype.itemsize:
        raise ValueError("block size not a multiple of element size")
    if n_blocks % pages_per_read:
        raise ValueError(
            f"extent of {n_blocks} blocks not a multiple of read granularity "
            f"{pages_per_read}"
        )
    return page_elems, n_blocks // pages_per_read


def execute_extent(
    device: ZonedDevice,
    program: Program,
    zone_id: int,
    block_off: int,
    n_blocks: int,
    *,
    tier: str,
    pages_per_read: int = 1,
    cache: Optional[CompiledProgramCache] = None,
    prefetch_depth: int = 2,
) -> OffloadResult:
    """Execute an (already verified) program over one zone extent on one
    device, on the requested tier. The single-device execution engine shared
    by :class:`NvmCsd` and the array scheduler (which calls it per stripe
    chunk when the batched path does not apply).

    The extent reaches the execution tier zero-copy (``read_extent`` hands
    out a typed view of the device buffer; XLA's own device_put is the one
    unavoidable host-side move). ``result.compile_seconds`` is non-zero only
    when this call compiled a fresh executable (miss in ``cache``).
    """
    tier = resolve_tier(tier, program)   # kernel -> jit for non-kernelizable
    dtype = np.dtype(program.input_dtype)
    page_elems, n_pages = extent_geometry(
        device.block_bytes, dtype, n_blocks, pages_per_read)
    insns_bound = program.n_insns * n_pages
    if cache is None:
        cache = CompiledProgramCache(capacity=4)  # private one-shot cache

    if tier == CsdTier.INTERP:
        def read_page(p: int) -> np.ndarray:
            return device.read_blocks_view(
                zone_id, block_off + p * pages_per_read, pages_per_read)
        # Lookahead only runs when there is transfer time to hide (the device
        # models bandwidth); against pure host memory it would be all
        # overhead. Every bandwidth-modelling device is ring-capable, so the
        # pages stream as in-flight completion futures — no producer thread:
        # the emulated transfer of pages p+1..p+depth elapses on the zone's
        # virtual clock while page p is being interpreted.
        if (n_pages > 1 and prefetch_depth > 0
                and getattr(device, "read_us_per_block", 0.0) > 0):
            with RingReader(
                    lambda p: device.submit_read(
                        zone_id, block_off + p * pages_per_read,
                        pages_per_read),
                    n_pages, depth=prefetch_depth) as reader:
                result = interpret_program(program, reader, n_pages,
                                           page_elems)
                result.read_seconds = reader.read_seconds
            return result
        return interpret_program(program, read_page, n_pages, page_elems)
    if tier == CsdTier.JIT:
        jp, compile_seconds, hit = cache.get_or_build(
            ("jit", program, n_pages, page_elems),
            lambda: jit_program(program, n_pages, page_elems))
        # steps 2,3: device DMA of the zone extent into device DRAM — a typed
        # view of the backing buffer, not a host-side copy
        t_r = time.perf_counter()
        with _trace.span("tier.read", tier=tier, zone=zone_id,
                         nblocks=n_blocks):
            pages = device.read_extent(zone_id, block_off, n_blocks,
                                       dtype).reshape(n_pages, page_elems)
        read_seconds = time.perf_counter() - t_r
        t0 = time.perf_counter()
        with _trace.span("tier.compute", tier=tier, pages=n_pages):
            value = jp(pages)
            value = tuple(np.asarray(v) for v in value) \
                if isinstance(value, tuple) else np.asarray(value)
        exec_seconds = time.perf_counter() - t0
        nbytes = (sum(v.nbytes for v in value) if isinstance(value, tuple)
                  else value.nbytes)
        return OffloadResult(value, nbytes, n_pages,
                             insns_bound, exec_seconds, compile_seconds,
                             read_seconds=read_seconds,
                             cache_hits=int(hit), cache_misses=int(not hit))
    if tier == CsdTier.KERNEL:
        # Pallas tier (TPU target; interpret-mode on CPU); resolve_tier above
        # already routed non-kernelizable programs to the JIT branch
        from repro.kernels.zone_filter import ops as zf_ops
        jp, compile_seconds, hit = cache.get_or_build(
            ("kernel", program, n_pages, page_elems),
            lambda: zf_ops.kernel_program(program, n_pages, page_elems))
        t_r = time.perf_counter()
        with _trace.span("tier.read", tier=tier, zone=zone_id,
                         nblocks=n_blocks):
            pages = device.read_extent(zone_id, block_off, n_blocks,
                                       dtype).reshape(n_pages, page_elems)
        read_seconds = time.perf_counter() - t_r
        t0 = time.perf_counter()
        with _trace.span("tier.compute", tier=tier, pages=n_pages):
            value = np.asarray(jp(pages))
        exec_seconds = time.perf_counter() - t0
        return OffloadResult(value, value.nbytes, n_pages,
                             insns_bound, exec_seconds, compile_seconds,
                             read_seconds=read_seconds,
                             cache_hits=int(hit), cache_misses=int(not hit))
    raise ValueError(f"unknown tier {tier!r}")


@dataclass
class OffloadStats:
    """Per-offload statistics (paper §3: runtime, #insns, JIT time, data
    movement saved)."""

    program: str
    tier: str
    zone_id: int
    pages: int
    bytes_read: int = 0               # storage -> compute (stayed inside device)
    bytes_returned: int = 0           # device -> host (crossed the link)
    insns_verified: int = 0
    insns_executed: int = 0
    verify_seconds: float = 0.0
    jit_seconds: float = 0.0
    exec_seconds: float = 0.0
    read_seconds: float = 0.0         # time inside device transfers
    cache_hits: int = 0               # shared compile-cache hits this offload
    cache_misses: int = 0

    @property
    def movement_saved_bytes(self) -> int:
        """Bytes that did NOT cross the host link thanks to the offload."""
        return max(self.bytes_read - self.bytes_returned, 0)

    @property
    def reduction_factor(self) -> float:
        return self.bytes_read / max(self.bytes_returned, 1)

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0


class CsdTier:
    INTERP = "interp"
    JIT = "jit"
    KERNEL = "kernel"


class NvmCsd:
    """A Zoned Computational Storage Device.

    ``pages_per_read`` controls the device-internal streaming granularity
    (paper default: one 4 KiB block per access). ``cache`` holds compiled
    executables for every tier; pass one :func:`repro.core.cache.default_cache`
    (or any shared :class:`CompiledProgramCache`) to reuse compiles across CSD
    instances — programs are device-agnostic.
    """

    def __init__(
        self,
        device: ZonedDevice,
        *,
        default_tier: str = CsdTier.JIT,
        pages_per_read: int = 1,
        limits: VerifierLimits = VerifierLimits(),
        max_workers: int = 2,
        cache: Optional[CompiledProgramCache] = None,
        prefetch_depth: int = 2,
    ):
        self.device = device
        self.default_tier = default_tier
        self.pages_per_read = int(pages_per_read)
        self.limits = limits
        self.prefetch_depth = int(prefetch_depth)
        self._result: Optional[OffloadResult] = None
        self.cache = cache if cache is not None else CompiledProgramCache()
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=max_workers)
        self.history: list[OffloadStats] = []

    # ------------------------------------------------------- part-ii hooks
    def bpf_get_lba_size(self) -> int:
        return self.device.lba_size

    def bpf_get_mem_info(self) -> tuple[int, int]:
        """(scratch bytes available, block bytes) — the device-memory budget
        an offloaded program may assume (maps to the VMEM budget for the
        kernel tier)."""
        return 16 * 1024 * 1024, self.device.lba_size  # 16 MiB ~ one core's VMEM

    def bpf_read(self, zone_id: int, block_off: int, n_blocks: int) -> np.ndarray:
        """Bounds-checked read used by the interpreter tier (device enforces
        the write-pointer bound; the verifier proved the static extent)."""
        return self.device.read_blocks(zone_id, block_off, n_blocks)

    def bpf_return_data(self, data: OffloadResult) -> None:
        self._result = data

    # --------------------------------------------------------- part-i API
    def nvm_cmd_bpf_run(
        self,
        program: Program,
        zone_id: int,
        *,
        block_off: int = 0,
        n_blocks: Optional[int] = None,
        tier: Optional[str] = None,
    ) -> OffloadStats:
        """Verify + execute ``program`` against a zone extent. Synchronous:
        returns once the (reduced) result is available via
        :meth:`nvm_cmd_bpf_result`."""
        tier = resolve_tier(tier or self.default_tier, program)
        zone = self.device.zone(zone_id)
        if n_blocks is None:
            n_blocks = zone.write_pointer - block_off

        dtype = np.dtype(program.input_dtype)
        block_bytes = self.device.block_bytes
        page_elems, n_pages = extent_geometry(
            block_bytes, dtype, n_blocks, self.pages_per_read)

        # steps 4: verify (static program + the zone extent it may touch)
        t0 = time.perf_counter()
        insns_verified = verify_program(
            program, page_elems=page_elems, n_pages=n_pages, limits=self.limits
        )
        verify_zone_access(
            zone_write_pointer=zone.write_pointer, block_off=block_off,
            n_blocks=n_blocks,
        )
        verify_seconds = time.perf_counter() - t0

        stats = OffloadStats(
            program=program.name, tier=tier, zone_id=zone_id, pages=n_pages,
            insns_verified=insns_verified, verify_seconds=verify_seconds,
            bytes_read=n_blocks * block_bytes,
        )

        result = execute_extent(
            self.device, program, zone_id, block_off, n_blocks,
            tier=tier, pages_per_read=self.pages_per_read,
            cache=self.cache, prefetch_depth=self.prefetch_depth,
        )
        stats.jit_seconds = result.compile_seconds
        stats.insns_executed = result.insns_executed
        stats.exec_seconds = result.exec_seconds
        stats.read_seconds = result.read_seconds
        stats.bytes_returned = result.bytes_returned
        stats.cache_hits = result.cache_hits
        stats.cache_misses = result.cache_misses
        self.bpf_return_data(result)
        self.history.append(stats)
        return stats

    def nvm_cmd_bpf_result(self) -> object:
        """Fetch the last offload's result (paper API line 8)."""
        if self._result is None:
            raise RuntimeError("no offload result available")
        return self._result.value

    # ------------------------------------------------- async extension
    def nvm_cmd_bpf_run_async(
        self, program: Program, zone_id: int, **kw
    ) -> concurrent.futures.Future:
        """Asynchronous execution (the paper's stated future extension)."""
        return self._pool.submit(self.nvm_cmd_bpf_run, program, zone_id, **kw)

    # ---------------------------------------------------------- helpers
    def run_and_fetch(self, program: Program, zone_id: int, **kw):
        stats = self.nvm_cmd_bpf_run(program, zone_id, **kw)
        return self.nvm_cmd_bpf_result(), stats

    def oracle(self, program: Program, zone_id: int, *, block_off: int = 0,
               n_blocks: Optional[int] = None):
        """Host-side reference execution (reads the WHOLE extent over the
        link — the "no CSD" baseline; the link transfer is the point, the
        typed view just avoids gratuitous extra host copies)."""
        zone = self.device.zone(zone_id)
        if n_blocks is None:
            n_blocks = zone.write_pointer - block_off
        return run_oracle(program, self.device.read_extent(
            zone_id, block_off, n_blocks, np.dtype(program.input_dtype)))
