"""Async completion-ring scaling: in-flight depth, not thread count.

Two measurements, both asserted (benchmark-as-tripwire):

  1. **Queue-depth scaling** — a fixed pool of 4 submitter threads drives a
     sliding window of ``depth`` in-flight ``submit_read`` futures over the
     zones of one emulated device. With the old thread-per-transfer model,
     throughput saturates at the pool size (4 transfers sleeping = 4 threads
     burned); with the completion ring, ONE reactor thread retires every
     in-flight transfer, so throughput keeps scaling with the window —
     the intra-device queue-depth scaling real ZNS hardware exhibits
     (arXiv:2010.06243). Asserted: monotonic throughput from depth 1→8, and
     ring depth-8 beats 4 blocking threads on the same workload.

  2. **Overlapped checkpoint save** — a checkpoint save rides the offload
     scheduler's submission queues (WRR-arbitrated against a live offload
     burst) instead of issuing synchronous array appends. Asserted: the
     overlapped schedule completes faster than running the same offload
     burst and the same save back-to-back.
"""
from __future__ import annotations

import concurrent.futures
import time
from collections import deque

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.train.checkpoint import ZonedCheckpointStore
from repro.zns import IoReactor, ZonedDevice

RAND_MAX = 2**31 - 1
BLOCK = 4096


# ------------------------------------------------------------- depth scaling

def _drive_window(device, reads, window: int) -> None:
    """Issue ``reads`` (zone ids) keeping at most ``window`` futures in
    flight — one tenant's sliding submission window."""
    futs: deque = deque()
    for zone in reads:
        if len(futs) >= window:
            futs.popleft().result()
        futs.append(device.submit_read(zone, 0, device.zone(zone).write_pointer))
    while futs:
        futs.popleft().result()


def run_depth_scaling(
    *,
    depths: tuple[int, ...] = (1, 2, 4, 8, 16, 32),
    zones: int = 32,
    blocks_per_zone: int = 64,
    read_us_per_block: float = 8.0,
    reads_per_zone: int = 2,
    workers: int = 4,
) -> list[dict]:
    """Aggregate read throughput vs in-flight depth at a FIXED worker count.

    Each read moves one whole zone (``blocks_per_zone`` blocks); reads are
    spread round-robin over the zones so the per-zone virtual-time queues,
    not a shared lock, are the only serialization.
    """
    reactor = IoReactor("bench-async")
    device = ZonedDevice(num_zones=zones, zone_bytes=blocks_per_zone * BLOCK,
                         block_bytes=BLOCK,
                         read_us_per_block=read_us_per_block, reactor=reactor)
    payload = np.ones(blocks_per_zone * BLOCK // 4, np.int32)
    for z in range(zones):
        device.zone_append(z, payload)
    total_reads = zones * reads_per_zone
    reads = [i % zones for i in range(total_reads)]
    total_mib = total_reads * blocks_per_zone * BLOCK / 2**20

    out: list[dict] = []
    with concurrent.futures.ThreadPoolExecutor(workers) as pool:
        for depth in depths:
            active = min(workers, depth)        # depth < pool: idle the rest
            window = depth // active
            shards = [reads[t::active] for t in range(active)]
            reactor.max_in_flight = 0           # per-row, not lifetime, max
            # best-of-3: on a loaded 2-core CI box a single run's scheduler
            # noise at adjacent depths can exceed the expected step; the best
            # run approaches the emulated-time floor, which is what scales
            seconds = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                list(pool.map(lambda s: _drive_window(device, s, window),
                              shards))
                seconds = min(seconds, time.perf_counter() - t0)
            out.append({
                "depth": depth,
                "seconds": seconds,
                "mib_per_s": total_mib / seconds,
                "workers": active,
                "max_in_flight": reactor.max_in_flight,
            })

        # baseline: the pre-ring model — every in-flight transfer blocks a
        # worker thread, so 4 workers cap in-flight depth at 4 no matter how
        # deep the submission window is
        blocking_seconds = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            list(pool.map(
                lambda s: [device.read_blocks_view(z, 0, blocks_per_zone)
                           for z in s],
                [reads[t::workers] for t in range(workers)]))
            blocking_seconds = min(blocking_seconds,
                                   time.perf_counter() - t0)

    by_depth = {r["depth"]: r["mib_per_s"] for r in out}
    for lo, hi in ((1, 2), (2, 4), (4, 8)):
        assert by_depth[hi] > by_depth[lo], (
            f"queue-depth scaling regressed: depth-{hi} "
            f"{by_depth[hi]:.1f} MiB/s <= depth-{lo} {by_depth[lo]:.1f} MiB/s")
    assert by_depth[8] > total_mib / blocking_seconds, (
        f"ring depth-8 ({by_depth[8]:.1f} MiB/s) did not beat {workers} "
        f"blocking threads ({total_mib / blocking_seconds:.1f} MiB/s)")
    out.append({
        "depth": 0,    # the thread-per-transfer baseline row
        "seconds": blocking_seconds,
        "mib_per_s": total_mib / blocking_seconds,
        "workers": workers,
        "max_in_flight": workers,
    })
    reactor.close()
    return out


# ------------------------------------------------- overlapped checkpoint save

def run_checkpoint_overlap(
    *,
    n_devices: int = 4,
    data_mib: int = 8,
    ckpt_mib: int = 8,
    offloads: int = 4,
    us_per_block: float = 20.0,
    runs: int = 2,
) -> dict:
    """Checkpoint save riding the submission queues vs serialized after the
    offload burst. The data zone and the payload zones live on the same
    devices; overlap comes from per-zone virtual-time queues + non-blocking
    raw-I/O dispatch, not from extra hardware."""
    data_blocks = data_mib * 2**20 // BLOCK
    member_zone_bytes = max(data_mib, ckpt_mib) * 2**20 // n_devices * 2
    devices = [ZonedDevice(num_zones=8, zone_bytes=member_zone_bytes,
                           block_bytes=BLOCK,
                           read_us_per_block=us_per_block,
                           append_us_per_block=us_per_block)
               for _ in range(n_devices)]
    array = StripedZoneArray(devices, stripe_blocks=64)
    rng = np.random.default_rng(0)
    data = rng.integers(0, RAND_MAX, data_mib * 2**20 // 4, dtype=np.int32)
    data_zone = 7
    array.zone_append(data_zone, data)
    array.finish_zone(data_zone)   # not a checkpoint placement target

    n_leaves = 2
    tree = {f"w{i}": rng.integers(0, 127, ckpt_mib * 2**20 // 4 // n_leaves,
                                  dtype=np.int32) for i in range(n_leaves)}
    program = filter_count("int32", "gt", RAND_MAX // 2)
    expected = int((data > RAND_MAX // 2).sum())

    with OffloadScheduler(array) as sched:
        # keep > total saves: GC must never fire here — it resets any written
        # zone no manifest references, which includes the offload data zone
        store = ZonedCheckpointStore(device=array, keep=4 * runs,
                                     scheduler=sched)
        sched.start()
        sched.nvm_cmd_bpf_run(program, data_zone)          # warm-up: compile
        step = 0
        serial_s, overlap_s = [], []
        for _ in range(runs):
            # serialized: offload burst, THEN the save
            t0 = time.perf_counter()
            for _ in range(offloads):
                assert int(sched.run_and_fetch(program, data_zone)[0]) \
                    == expected
            store.save(step, tree)
            serial_s.append(time.perf_counter() - t0)
            step += 1
            # overlapped: the save's appends ride the queues WITH the burst
            # (burst queued first, so even the save's host-side leaf
            # serialization overlaps the dispatcher's offload execution)
            t0 = time.perf_counter()
            cmd_ids = [sched.submit(program, data_zone, _watch=True)
                       for _ in range(offloads)]
            ticket = store.save_async(step, tree)
            comps = [sched.wait(c, timeout=120) for c in cmd_ids]
            ticket.result(timeout=120)
            overlap_s.append(time.perf_counter() - t0)
            step += 1
            assert all(c.ok and int(c.value) == expected for c in comps)

    serial, overlap = min(serial_s), min(overlap_s)
    assert overlap < serial, (
        f"overlapped checkpoint save ({overlap * 1e3:.0f} ms) not faster than "
        f"serialized ({serial * 1e3:.0f} ms)")
    return {
        "serial_seconds": serial,
        "overlap_seconds": overlap,
        "speedup": serial / overlap,
        "offloads": offloads,
        "ckpt_mib": ckpt_mib,
    }


def main(data_mib: int = 8, runs: int = 2) -> list[str]:
    rows = []
    for r in run_depth_scaling():
        name = f"async_depth{r['depth']}" if r["depth"] else "async_blocking4"
        rows.append(
            f"{name},{r['seconds'] * 1e6:.0f},"
            f"mib_per_s={r['mib_per_s']:.1f};workers={r['workers']};"
            f"max_in_flight={r['max_in_flight']}"
        )
    c = run_checkpoint_overlap(data_mib=data_mib, ckpt_mib=4 * data_mib,
                               runs=runs)
    rows.append(
        f"async_ckpt_overlap,{c['overlap_seconds'] * 1e6:.0f},"
        f"serial_us={c['serial_seconds'] * 1e6:.0f};"
        f"speedup={c['speedup']:.2f}x;offloads={c['offloads']};"
        f"ckpt_mib={c['ckpt_mib']}"
    )
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
