"""CSD array subsystem: stripe round-trips, queue arbitration/backpressure,
scheduler result-equivalence vs the single-device NvmCsd oracle for every
OpCode terminal, and fault degradation when a member zone goes OFFLINE."""
import threading

import numpy as np
import pytest

from repro.array import (
    ArrayOffloadError,
    Completion,
    OffloadCommand,
    OffloadScheduler,
    QueueFullError,
    QueuePair,
    CompletionQueue,
    StripedZoneArray,
    SubmissionQueue,
    WeightedRoundRobinArbiter,
)
from repro.core import CsdTier, NvmCsd, VerifyError
from repro.core.programs import (
    Instruction,
    OpCode,
    Program,
    field_reduce,
    filter_count,
    filter_select,
    filter_sum,
    histogram,
    select_records,
)
from repro.zns import OutOfBoundsError, ZonedDevice, ZoneFullError

BLOCK = 4096
STRIPE = 4


def make_array(n_devices, *, num_zones=4, zone_kib=256, stripe=STRIPE):
    devs = [ZonedDevice(num_zones=num_zones, zone_bytes=zone_kib * 1024,
                        block_bytes=BLOCK) for _ in range(n_devices)]
    return StripedZoneArray(devs, stripe_blocks=stripe)


def int32_blocks(n_blocks, seed=0, lo=-1000, hi=1000):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, n_blocks * BLOCK // 4, dtype=np.int32)


# ------------------------------------------------------------------ striping

@pytest.mark.parametrize("n_devices", [1, 2, 3, 4])
def test_stripe_append_read_round_trip(n_devices):
    arr = make_array(n_devices)
    data = int32_blocks(4 * STRIPE * n_devices + 7)  # force a partial chunk
    arr.zone_append(0, data)
    back = np.frombuffer(arr.read_blocks(0, 0, arr.zone(0).write_pointer)
                         .tobytes(), np.int32)
    assert np.array_equal(back, data)


def test_stripe_partial_reads_any_offset():
    arr = make_array(3)
    data = int32_blocks(23)
    arr.zone_append(0, data)
    per_block = BLOCK // 4
    for off, n in [(0, 1), (1, 5), (3, 17), (7, 16), (22, 1), (0, 23)]:
        back = np.frombuffer(arr.read_blocks(0, off, n).tobytes(), np.int32)
        assert np.array_equal(back, data[off * per_block:(off + n) * per_block])


def test_stripe_incremental_appends_interleave_correctly():
    arr = make_array(2)
    parts = [int32_blocks(n, seed=n) for n in (3, 1, 6, 2)]
    for p in parts:
        arr.zone_append(0, p)
    want = np.concatenate(parts)
    back = np.frombuffer(arr.read_zone(0).tobytes(), np.int32)
    assert np.array_equal(back, want)
    # data really is spread over both members
    assert all(d.zone(0).write_pointer > 0 for d in arr.devices)


def test_stripe_reset_and_reuse():
    arr = make_array(2)
    arr.zone_append(1, int32_blocks(8))
    arr.reset_zone(1)
    assert arr.zone(1).write_pointer == 0
    assert all(d.zone(1).write_pointer == 0 for d in arr.devices)
    fresh = int32_blocks(4, seed=9)
    arr.zone_append(1, fresh)
    assert np.array_equal(
        np.frombuffer(arr.read_zone(1).tobytes(), np.int32), fresh)


def test_stripe_bounds_and_capacity_errors():
    arr = make_array(2, zone_kib=64)  # 16 blocks/member -> 32 logical
    arr.zone_append(0, int32_blocks(4))
    with pytest.raises(OutOfBoundsError):
        arr.read_blocks(0, 0, 5)   # beyond logical write pointer
    with pytest.raises(ZoneFullError):
        arr.zone_append(0, int32_blocks(29))  # exceeds logical capacity
    with pytest.raises(ValueError):
        StripedZoneArray([ZonedDevice(num_zones=2, zone_bytes=64 * 1024),
                          ZonedDevice(num_zones=4, zone_bytes=64 * 1024)])


def test_logical_write_pointer_setter_distributes():
    arr = make_array(3, stripe=4)
    z = arr.zone(0)
    z.write_pointer = 4 * 3 * 2 + 4 + 2   # 2 full rows + 1 full chunk + 2
    assert [d.zone(0).write_pointer for d in arr.devices] == [12, 10, 8]
    assert z.write_pointer == 30
    z.write_pointer = 0
    assert all(d.zone(0).write_pointer == 0 for d in arr.devices)


# -------------------------------------------------------------------- queues

def test_sq_backpressure_rejects_then_unblocks():
    sq = SubmissionQueue("t", depth=2)
    prog = filter_count("int32", "gt", 0)
    mk = lambda: OffloadCommand(prog, 0, 0, 4, None)
    sq.submit(mk()); sq.submit(mk())
    with pytest.raises(QueueFullError):
        sq.submit(mk())
    assert sq.rejected == 1
    # a blocked submitter proceeds once the arbiter pops a slot
    done = threading.Event()
    def blocked():
        sq.submit(mk(), block=True, timeout=5.0)
        done.set()
    t = threading.Thread(target=blocked); t.start()
    assert not done.wait(0.05)
    assert sq.pop() is not None
    assert done.wait(5.0)
    t.join()
    assert len(sq) == 2


def test_wrr_arbiter_respects_weights():
    prog = filter_count("int32", "gt", 0)
    pairs = {}
    arb = WeightedRoundRobinArbiter()
    for tenant, weight in [("a", 2), ("b", 1)]:
        pair = QueuePair(SubmissionQueue(tenant, depth=16, weight=weight),
                         CompletionQueue(tenant))
        for _ in range(6):
            pair.sq.submit(OffloadCommand(prog, 0, 0, 4, None, tenant=tenant))
        pairs[tenant] = pair
        arb.add(pair)
    order = []
    while (nxt := arb.next_command()) is not None:
        order.append(nxt[0].tenant)
    # 2:1 service mix while both queues are backlogged; once 'a' drains the
    # arbiter stays work-conserving and serves the remaining 'b' commands
    assert order == ["a", "a", "b"] * 3 + ["b", "b", "b"]


def test_wrr_arbiter_work_conserving_when_queue_empty():
    prog = filter_count("int32", "gt", 0)
    arb = WeightedRoundRobinArbiter()
    a = QueuePair(SubmissionQueue("a", depth=4, weight=3), CompletionQueue("a"))
    b = QueuePair(SubmissionQueue("b", depth=4, weight=1), CompletionQueue("b"))
    arb.add(a); arb.add(b)
    b.sq.submit(OffloadCommand(prog, 0, 0, 4, None, tenant="b"))
    nxt = arb.next_command()
    assert nxt is not None and nxt[0].tenant == "b"
    assert arb.next_command() is None


# ----------------------------------------------------------------- scheduler

def oracle_pair(n_blocks, seed=0):
    """(single-device NvmCsd, striped 4-wide scheduler) over identical data."""
    data = int32_blocks(n_blocks, seed=seed)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    arr = make_array(4)
    arr.zone_append(0, data)
    return NvmCsd(dev), OffloadScheduler(arr)


TERMINAL_PROGRAMS = [
    filter_count("int32", "gt", 0),
    filter_sum("int32", "lt", 100),
    field_reduce("int32", 8, 1, "min"),
    field_reduce("int32", 8, 2, "max"),
    histogram("int32", -1000, 1000, 32),
    filter_select("int32", "gt", 900, 64),
    select_records("int32", 8, 0, "gt", 500, 32),
]


@pytest.mark.parametrize("program", TERMINAL_PROGRAMS,
                         ids=[p.name for p in TERMINAL_PROGRAMS])
def test_scheduler_matches_single_device_oracle(program):
    csd, sched = oracle_pair(40)
    want, _ = csd.run_and_fetch(program, 0)
    got, stats = sched.run_and_fetch(program, 0)
    if isinstance(want, tuple):
        assert np.array_equal(np.asarray(want[0]), np.asarray(got[0]))
        assert int(want[1]) == int(got[1])
    else:
        assert np.asarray(want).dtype == np.asarray(got).dtype
        assert np.array_equal(np.asarray(want), np.asarray(got))
    assert stats.n_devices == 4
    assert stats.n_chunks == 10
    assert stats.bytes_read == 40 * BLOCK


@pytest.mark.parametrize("tier", [CsdTier.INTERP, CsdTier.JIT, CsdTier.KERNEL])
def test_scheduler_tiers_agree_with_tail_chunk(tier):
    csd, sched = oracle_pair(37, seed=3)  # 37 blocks -> partial tail chunk
    program = filter_count("int32", "gt", 0)
    want, _ = csd.run_and_fetch(program, 0, tier=tier)
    got, _ = sched.run_and_fetch(program, 0, tier=tier)
    assert int(want) == int(got)


def test_scheduler_batches_full_chunks_on_jit_tier():
    _, sched = oracle_pair(40)
    stats = sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    # 10 chunks over 4 devices: the 2-chunk devices batch via vmap
    assert stats.batched_chunks > 0
    assert stats.tier == CsdTier.JIT


def test_scheduler_partial_extent_matches_oracle():
    csd, sched = oracle_pair(40, seed=7)
    program = filter_sum("int32", "ge", -50)
    want, _ = csd.run_and_fetch(program, 0, block_off=4, n_blocks=24)
    got, _ = sched.run_and_fetch(program, 0, block_off=4, n_blocks=24)
    assert int(want) == int(got)


def test_scheduler_verifies_before_enqueue():
    _, sched = oracle_pair(8)
    bad = Program("int32", (Instruction(OpCode.CMP_GT, 0),), name="no_terminal")
    with pytest.raises(VerifyError):
        sched.submit(bad, 0)
    assert len(sched.queue_pair().sq) == 0  # rejected work never queues


def test_scheduler_single_device_degenerate_path():
    data = int32_blocks(12, seed=5)
    dev = ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)
    dev.zone_append(0, data)
    arr = StripedZoneArray(
        [ZonedDevice(num_zones=2, zone_bytes=1024 * 1024, block_bytes=BLOCK)],
        stripe_blocks=STRIPE)
    arr.zone_append(0, data)
    program = filter_count("int32", "le", 250)
    want, _ = NvmCsd(dev).run_and_fetch(program, 0)
    got, stats = OffloadScheduler(arr).run_and_fetch(program, 0)
    assert int(want) == int(got)
    assert stats.n_devices == 1


def test_scheduler_offline_member_degrades_with_clear_error():
    _, sched = oracle_pair(40)
    sched.array.set_offline(0, device=2)
    with pytest.raises(ArrayOffloadError, match="member device 2"):
        sched.nvm_cmd_bpf_run(filter_count("int32", "gt", 0), 0)
    # the failure is also visible on the completion queue, not just raised
    comps = sched.queue_pair().cq.drain()
    assert comps and not comps[-1].ok


def test_scheduler_async_dispatcher_and_wait():
    csd, sched = oracle_pair(40, seed=11)
    program = filter_sum("int32", "lt", 0)
    want, _ = csd.run_and_fetch(program, 0)
    sched.start()
    try:
        cmd_ids = [sched.submit(program, 0) for _ in range(3)]
        comps = [sched.wait(cid, timeout=60) for cid in cmd_ids]
    finally:
        sched.stop()
    assert all(c.ok for c in comps)
    assert all(int(c.value) == int(want) for c in comps)


def test_scheduler_multi_tenant_stats_history():
    _, sched = oracle_pair(40)
    sched.register_tenant("analytics", weight=2)
    sched.submit(filter_count("int32", "gt", 0), 0, tenant="analytics")
    sched.submit(filter_count("int32", "lt", 0), 0)
    assert sched.drain() == 2
    assert len(sched.history) == 2
    assert {s.program for s in sched.history} == {
        "filter_count_gt", "filter_count_lt"}
    assert all(s.movement_saved_bytes > 0 for s in sched.history)
