"""Async completion-ring device model: one reactor drives all in-flight I/O.

The paper's ZCSD sits behind an NVMe-style asynchronous submission/completion
interface; real ZNS hardware sustains throughput by keeping MANY transfers in
flight per device (arXiv:2010.06243 characterizes intra-device queue-depth
scaling). The previous emulation modelled transfer time with a per-transfer
``time.sleep`` — every in-flight read burned a worker thread, so array fan-out
concurrency was bounded by pool size, not by the emulated device parallelism.

This module replaces thread-per-transfer blocking with an event-loop model:

  * :class:`IoFuture` — one in-flight transfer descriptor + completion
    rendezvous (the NVMe command/CQE pair). The data effect (buffer slice,
    write-pointer advance) happens synchronously at submission under the
    device lock, exactly as before; only the *timing* — when the completion
    is visible — is deferred to the emulated deadline.
  * :class:`IoReactor` — a single daemon thread holding a deadline-ordered
    heap of in-flight futures. It sleeps until the earliest deadline and
    retires everything due, like an NVMe controller posting CQEs: one thread
    drives hundreds of in-flight transfers.
  * :class:`CompletionRing` — a bounded MPSC ring a submitter may attach to
    its futures; retired completions land there in retirement order (the
    host-visible CQ analogue, with ring-overwrite ``dropped`` accounting).

Per-zone serialization (one flash die per zone) is preserved by the devices
through *virtual-time queues*: each zone tracks ``io_busy_until``, and a new
transfer's deadline is ``max(now, busy_until) + service``; the zone's clock
advances to that deadline. Transfers against one zone retire strictly in
submission order; transfers against different zones overlap — the same
semantics the old per-zone ``io_gate`` sleeps enforced, minus the threads.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Callable, Optional

from repro.telemetry import metrics as _metrics
from repro.telemetry.events import Severity as _Sev, publish as _publish_event

__all__ = ["IoFuture", "IoReactor", "CompletionRing", "CompletionBarrier",
           "in_reactor_thread"]

# One lock serializes completion/callback transitions for ALL futures. The
# critical sections are a few pointer moves, and a shared lock keeps IoFuture
# allocation-free on the inline-completion fast path (no per-future Event
# unless somebody actually blocks on a timed transfer).
_TRANSITION_LOCK = threading.Lock()

# Set inside every reactor loop thread: lets completion consumers route heavy
# callback work (gather memcpys) off the pump precisely, instead of guessing
# from submission phase.
_IN_REACTOR = threading.local()


def in_reactor_thread() -> bool:
    """True when the calling thread is an IoReactor completion pump."""
    return getattr(_IN_REACTOR, "active", False)

_seq = itertools.count(1)


class IoFuture:
    """One submitted I/O: descriptor fields + a completion rendezvous.

    ``value``/``error`` become readable once :meth:`done` — for reads the
    value is the device buffer view (or copy) snapshotted at submission (zones
    are append-only, so the bytes cannot change underneath a legal host);
    for appends it is the landing block, which real ZNS Zone Append also only
    reports in the completion entry.
    """

    __slots__ = ("op", "zone_id", "block_off", "nblocks", "service_seconds",
                 "deadline", "seq", "submitted_block", "ring", "device",
                 "tenant", "waits_on", "_prev", "_value", "_error", "_done",
                 "_event", "_callbacks", "__weakref__")

    def __init__(self, op: str = "io", zone_id: int = -1, block_off: int = 0,
                 nblocks: int = 0, service_seconds: float = 0.0,
                 ring: Optional["CompletionRing"] = None):
        self.op = op
        self.zone_id = zone_id
        self.block_off = block_off
        self.nblocks = nblocks
        self.service_seconds = service_seconds
        self.deadline = 0.0
        self.seq = next(_seq)
        self.submitted_block: Optional[int] = None
        self.ring = ring
        # stuck-op diagnostics: who owns this transfer and what it fans out
        # to — ``result(timeout=)`` names them instead of timing out mutely
        self.device: str = ""
        self.tenant: Optional[str] = None
        self.waits_on: Optional[list] = None   # member futures of a fan-out
        # the zone's previous timed transfer (completion-order chain): an
        # already-due future may only retire inline if its predecessor has
        # retired — otherwise it parks in the reactor heap, whose
        # (deadline, seq) order preserves the per-zone sequence
        self._prev: Optional["IoFuture"] = None
        self._value = None
        self._error: Optional[BaseException] = None
        self._done = False
        self._event: Optional[threading.Event] = None
        self._callbacks: list[Callable[["IoFuture"], None]] = []

    # ------------------------------------------------------------- consumers
    def done(self) -> bool:
        return self._done

    @property
    def value(self):
        """The completed value (None until :meth:`done`; raises if errored)."""
        if self._error is not None:
            raise self._error
        return self._value

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def stuck_detail(self) -> str:
        """One-line diagnosis of an overdue transfer: op, device, zone,
        owning tenant, and — for a fan-out aggregate — the first member
        transfer still holding it up (a hung command names itself)."""
        where = f" on {self.device}" if self.device else ""
        who = f" for tenant {self.tenant!r}" if self.tenant else ""
        msg = f"{self.op}{where} zone {self.zone_id}{who} still in flight"
        for m in (self.waits_on or ()):
            if not m.done():
                msg += (f" (waiting on {m.op} {m.device or '?'} "
                        f"zone {m.zone_id} seq #{m.seq})")
                break
        return msg

    def result(self, timeout: Optional[float] = None):
        """Block until the emulated completion deadline; return the value or
        re-raise the transfer's error. ``timeout`` bounds the wait in wall
        seconds — on expiry a ``TimeoutError`` names the stuck op
        (device/zone/op/tenant) instead of hanging the caller forever."""
        if not self._done:
            with _TRANSITION_LOCK:
                if not self._done and self._event is None:
                    self._event = threading.Event()
                ev = self._event
            if ev is not None and not ev.wait(timeout):
                raise TimeoutError(self.stuck_detail())
        if self._error is not None:
            raise self._error
        return self._value

    def add_done_callback(self, fn: Callable[["IoFuture"], None]) -> None:
        """Run ``fn(self)`` when the completion retires (immediately if it
        already has). Callback exceptions are swallowed, as with
        ``concurrent.futures`` — a completion consumer must not be able to
        kill the reactor."""
        with _TRANSITION_LOCK:
            if not self._done:
                self._callbacks.append(fn)
                return
        self._run_callback(fn)

    # ------------------------------------------------------------- producers
    def complete(self, value=None) -> "IoFuture":
        self._value = value
        self._retire()
        return self

    def fail(self, error: BaseException) -> "IoFuture":
        self._error = error
        self._retire()
        return self

    def _retire(self) -> None:
        with _TRANSITION_LOCK:
            if self._done:
                raise RuntimeError(f"completion {self.seq} retired twice")
            self._done = True
            self._prev = None          # release the per-zone chain for GC
            cbs, self._callbacks = self._callbacks, []
            ev = self._event
        if ev is not None:
            ev.set()
        if self.ring is not None:
            self.ring.push(self)
        for fn in cbs:
            self._run_callback(fn)

    def _run_callback(self, fn) -> None:
        try:
            fn(self)
        except Exception:
            pass  # a consumer bug must not take down the reactor thread

    def __repr__(self) -> str:
        state = "done" if self._done else "in-flight"
        return (f"IoFuture(#{self.seq} {self.op} zone={self.zone_id} "
                f"[{self.block_off},+{self.nblocks}) {state})")


class CompletionBarrier:
    """Fan-in join over ``n`` completions settled from arbitrary threads.

    Collects per-slot values, latches the FIRST error, and fires
    ``on_done(values, error)`` exactly once when the last slot settles — the
    one barrier shape shared by the striped array's member fan-out and the
    checkpoint store's leaf fan-out. An ``n`` of zero fires ``on_done``
    immediately (from the constructor)."""

    def __init__(self, n: int,
                 on_done: Callable[[list, Optional[BaseException]], None]):
        self.values: list = [None] * n
        self._remaining = n
        self._error: Optional[BaseException] = None
        self._on_done = on_done
        self._lock = threading.Lock()
        if n == 0:
            on_done(self.values, None)

    def settle(self, i: int, error: Optional[BaseException] = None,
               value=None) -> None:
        with self._lock:
            if error is not None:
                if self._error is None:
                    self._error = error
            else:
                self.values[i] = value
            self._remaining -= 1
            last = self._remaining == 0
        if last:
            self._on_done(self.values, self._error)


class CompletionRing:
    """Bounded MPSC ring of retired completion entries (NVMe CQ analogue): a
    host that does not keep up loses the oldest entries (counted in
    ``dropped``) rather than growing without bound.

    Entry-type agnostic — the device layer rings :class:`IoFuture`
    descriptors through it and the array layer's per-tenant
    ``CompletionQueue`` subclasses it for command completions, so the
    overwrite/accounting semantics live in exactly one place.
    """

    def __init__(self, depth: int = 256):
        if depth <= 0:
            raise ValueError("ring depth must be positive")
        self.depth = depth
        self._q: deque = deque(maxlen=depth)
        self._cond = threading.Condition()
        self.dropped = 0
        self.retired = 0

    def push(self, entry) -> None:
        with self._cond:
            first_drop = False
            if len(self._q) == self.depth:
                if self.dropped == 0:
                    first_drop = True
                self.dropped += 1          # ring overwrite of the oldest CQE
            self._q.append(entry)
            self.retired += 1
            self._cond.notify_all()
        if first_drop:
            # one event per ring lifetime, outside the lock (push may run on
            # the reactor thread); ``dropped`` counts the rest
            _publish_event(
                "ring.cq_drop", severity=_Sev.WARNING,
                message=f"completion ring depth={self.depth} overwrote its "
                        "oldest entry (host not keeping up)",
                depth=self.depth)

    def pop(self, *, timeout: Optional[float] = None):
        with self._cond:
            if not self._q and timeout is not None:
                self._cond.wait(timeout=timeout)
            return self._q.popleft() if self._q else None

    def drain(self) -> list:
        with self._cond:
            out = list(self._q)
            self._q.clear()
            return out

    def wait_retired(self, n: int, *, timeout: Optional[float] = None) -> bool:
        """Block until ``n`` completions have retired into this ring over its
        lifetime (drops count — they retired, the host just lost the entry)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self.retired < n:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(timeout=remaining)
            return True

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)


class IoReactor:
    """Deadline-ordered completion pump: ONE thread retires every in-flight
    emulated transfer, however many devices share it.

    Futures whose deadline has already passed at scheduling time complete
    inline on the submitter thread (a zero-service transfer on an idle zone —
    the non-emulated fast path pays no thread hop and no allocation beyond
    the future itself). Everything else parks in a heap; the reactor sleeps
    until the earliest deadline and retires all due completions, in deadline
    order with submission sequence as the tiebreak.
    """

    _default: Optional["IoReactor"] = None
    _default_lock = threading.Lock()

    def __init__(self, name: str = "zns-io-reactor"):
        self.name = name
        self._heap: list[tuple[float, int, IoFuture]] = []
        self._cond = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stopped = False
        # host-visible counters: proof of in-flight depth for the benchmarks
        self.retired = 0
        self.max_in_flight = 0

    @classmethod
    def default(cls) -> "IoReactor":
        """The process-wide shared reactor (devices default to it, so one
        thread drives all in-flight I/O of every emulated device)."""
        with cls._default_lock:
            if cls._default is None:
                cls._default = cls()
                # the default reactor is THE process-wide pump — surface its
                # occupancy in the global metrics snapshot
                r = cls._default
                _metrics.registry().register_collector("reactor", lambda: {
                    "in_flight": r.in_flight,
                    "max_in_flight": r.max_in_flight,
                    "retired": r.retired,
                })
            return cls._default

    @property
    def in_flight(self) -> int:
        with self._cond:
            return len(self._heap)

    # ------------------------------------------------------------ scheduling
    def schedule(self, fut: IoFuture, deadline: float) -> IoFuture:
        """Arm ``fut`` to retire at monotonic time ``deadline`` (value/error
        must already be staged via ``fut._value``/``complete`` by the caller
        side — see the device submit paths)."""
        fut.deadline = deadline
        prev = fut._prev
        if deadline <= time.monotonic() and (prev is None or prev._done):
            # already due AND no in-flight predecessor on this zone: retire
            # on the submitter thread (the non-emulated fast path)
            fut._retire()
            return fut
        with self._cond:
            if self._stopped:
                raise RuntimeError(f"reactor {self.name} is closed")
            heapq.heappush(self._heap, (deadline, fut.seq, fut))
            if len(self._heap) > self.max_in_flight:
                self.max_in_flight = len(self._heap)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name=self.name, daemon=True)
                self._thread.start()
            self._cond.notify()
        return fut

    def _run(self) -> None:
        _IN_REACTOR.active = True
        while True:
            due: list[IoFuture] = []
            with self._cond:
                if self._stopped and not self._heap:
                    return
                now = time.monotonic()
                while self._heap and self._heap[0][0] <= now:
                    _, seq, fut = heapq.heappop(self._heap)
                    prev = fut._prev
                    if prev is not None and not prev._done:
                        # the zone's predecessor transfer has not retired —
                        # it was claimed before this one but may not have
                        # reached the heap yet (claim and schedule are not
                        # atomic). Defer briefly; the chain is acyclic, so
                        # this always makes progress.
                        heapq.heappush(self._heap, (now + 5e-5, seq, fut))
                        continue
                    due.append(fut)
                if not due:
                    wait = self._heap[0][0] - now if self._heap else None
                    self._cond.wait(timeout=wait)
                    continue
                self.retired += len(due)
            # Deadline slip = how late the pump retired each completion past
            # its emulated deadline — the reactor's own serialization signal.
            # Fetched per batch (not cached) so a registry reset in tests
            # cannot orphan the series.
            h = _metrics.registry().histogram("reactor.slip_seconds")
            for fut in due:
                h.observe(now - fut.deadline)
            for fut in due:           # outside the lock: callbacks may submit
                fut._retire()

    def close(self) -> None:
        """Drain and stop the reactor thread (in-flight completions still
        retire at their deadlines first)."""
        with self._cond:
            self._stopped = True
            self._cond.notify()
            thread = self._thread
        if thread is not None:
            thread.join(timeout=10.0)
