"""Serving with a zoned KV cache: batched decode over the zone pool.

Demonstrates the ZNS->serving mapping: sequences allocate KV zones from a
shared pool (append-only writes at the zone write pointer), attention runs
*in place* over the pool via the Pallas paged-attention kernel, and eviction
is a host-managed zone reset. Three request waves with evictions show
fragmentation-free reuse.

    PYTHONPATH=src python examples/serve_zoned_kv.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels.paged_attn.ref import paged_attention_ref
from repro.serve.kv_zones import KVZonePool

KV_HEADS, HEADS, HEAD_DIM = 2, 8, 64


def decode_wave(pool, seq_ids, steps, rng):
    """Simulate `steps` decode steps for a batch of sequences."""
    for _ in range(steps):
        for sid in seq_ids:
            k_tok = jnp.asarray(rng.standard_normal((KV_HEADS, HEAD_DIM)),
                                jnp.float32)
            v_tok = jnp.asarray(rng.standard_normal((KV_HEADS, HEAD_DIM)),
                                jnp.float32)
            pool.append(sid, k_tok, v_tok)
        q = jnp.asarray(rng.standard_normal((len(seq_ids), HEADS, HEAD_DIM)),
                        jnp.float32)
        out = pool.attend(seq_ids, q)
        # cross-check against the jnp oracle
        tab, lengths = pool.zone_table(seq_ids)
        ref = paged_attention_ref(q, pool.k, pool.v, tab, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    return out


def main():
    rng = np.random.default_rng(0)
    pool = KVZonePool(num_zones=24, zone_len=16, kv_heads=KV_HEADS,
                      head_dim=HEAD_DIM, max_zones_per_seq=4,
                      dtype=jnp.float32)

    print("wave 1: four sequences decode 40 tokens each")
    for sid in range(4):
        pool.add_sequence(sid)
    decode_wave(pool, [0, 1, 2, 3], 40, rng)
    print(f"  pool utilization {pool.utilization():.0%}, "
          f"stats={pool.stats}")

    print("wave 2: evict two sequences (host-managed zone reset)")
    pool.evict(0)
    pool.evict(2)
    print(f"  pool utilization {pool.utilization():.0%}, "
          f"zones reset so far: {pool.stats['zones_reset']}")

    print("wave 3: four NEW sequences reuse the reclaimed zones")
    for sid in range(10, 14):
        pool.add_sequence(sid)
    decode_wave(pool, [10, 11, 12, 13], 30, rng)
    print(f"  pool utilization {pool.utilization():.0%}, "
          f"stats={pool.stats}")
    print("paged attention matched the oracle at every step — done")


if __name__ == "__main__":
    main()
