"""Zone striping with redundancy over multiple ZNS devices.

The paper defers multi-device operation as future work; real CSD deployments
aggregate many devices behind one logical address space — and must survive a
member failure. A :class:`StripedZoneArray` presents N identical
:class:`~repro.zns.ZonedDevice` members as ONE logical zoned device in one of
three redundancy modes:

  * ``raid0`` (default) — pure striping: logical chunk ``k`` (column
    ``k % C``, row ``k // C``) lives on member ``k % N`` at member-local
    offset ``row * stripe_blocks``; a member-zone failure kills the logical
    zone (the clean-error path PR 2 tested);
  * ``raid1`` — mirrored stripe groups: members pair up into ``N/2`` columns
    and each chunk lands on BOTH partners of its column. Healthy reads
    round-robin the mirror pair by stripe row (up to ~2x aggregate read
    bandwidth); with one partner OFFLINE every read redirects to the
    survivor — bit-identical, no reconstruction math;
  * ``xor`` — RAID-5-style rotating parity: ``N-1`` data chunks per stripe
    row plus one XOR parity chunk on the rotating parity member. A dead
    member's chunk is reconstructed by XOR-ing the surviving row members;
    the parity chunk of the (at most one) incomplete tail row has not landed
    yet, so a host-side parity accumulator (the NVRAM parity buffer of a
    real RAID controller) stands in for it.

Shared invariants, every mode:

  * appends and reads preserve ZNS semantics end-to-end — member appends
    land exactly at each member's write pointer, the logical zone state
    machine is derived from the members', and the logical write pointer
    advances only once every member submission of an append has landed;
  * member transfers fan out as in-flight completion-ring descriptors
    (:mod:`repro.zns.ring`): an N-member read holds N reactor slots and ZERO
    worker threads, and degraded-read reconstruction rides the SAME reactor
    clocks — survivor reads are ordinary member transfers, the XOR combine
    runs at completion time (off the reactor pump, on the gather pool);
  * a member failing mid-fan-out can never orphan the aggregate future:
    already-submitted member completions settle a barrier that retires the
    aggregate with the error (and a torn append fences the zone READ_ONLY).

The class is a drop-in for ``ZonedDevice`` everywhere the repo consumes one
(``NvmCsd``, ``ZoneDataStore``, ``ZonedCheckpointStore``): a 1-member raid0
array is the degenerate single-device path.
"""
from __future__ import annotations

import atexit
import queue
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.telemetry import trace as _trace
from repro.telemetry.events import Severity as _Sev, publish as _publish_event
from repro.telemetry.metrics import MetricsRegistry, registry as _registry
from repro.zns.device import (
    OutOfBoundsError,
    ZNSError,
    ZonedDevice,
    ZoneFullError,
    ZoneState,
    ZoneStateError,
    block_aligned_dtype,
    payload_as_uint8,
)
from repro.zns.ring import (
    CompletionBarrier,
    CompletionRing,
    IoFuture,
    in_reactor_thread,
)

__all__ = ["StripedZoneArray", "LogicalZone", "StripeChunk",
           "REDUNDANCY_MODES", "coalesce_member_runs"]

REDUNDANCY_MODES = ("raid0", "raid1", "xor")


class _GatherPool:
    """Bounded pool of DAEMON threads for gather-interleave / XOR-combine
    memcpys of reactor-retired member reads.

    The reactor must stay a pointer-moving completion pump (a pair of
    concurrent 64 MiB striped reads would otherwise serialize ~100 MiB of
    memcpy ahead of every other due completion in the process), so heavy
    completion work lands here. ``concurrent.futures.ThreadPoolExecutor``
    workers are non-daemonic — they outlive test teardown and stall
    interpreter exit until the global ``_python_exit`` join — so this
    minimal replacement mirrors the reactor's lifecycle handling
    (:mod:`repro.zns.ring`): lazily-spawned daemon workers plus an atexit
    shutdown. Bounded and shared — threads scale with concurrent gathers in
    progress, never with in-flight transfers, so the ring model's claim
    stands.
    """

    def __init__(self, max_workers: int = 4):
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._max = max_workers
        self._closed = False

    def submit(self, fn: Callable[[], None]) -> None:
        with self._lock:
            if not self._closed:
                _registry().counter("gather.jobs").inc()
                self._q.put((fn, time.monotonic()))
                if len(self._threads) < self._max:
                    t = threading.Thread(
                        target=self._work, daemon=True,
                        name=f"stripe-gather-{len(self._threads)}")
                    self._threads.append(t)
                    t.start()
                return
        # pool already shut down (interpreter exit): run inline rather than
        # drop the gather — its barrier slot MUST settle or a caller blocked
        # in result() with no timeout would hang forever
        fn()

    def _work(self) -> None:
        # queue-wait vs execute split is THE scaling-cliff discriminator for
        # this pool: growing wait with flat exec means the 4 workers (or the
        # queue hand-off) are the serialization point, not the memcpys
        reg = _registry()
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, t_submit = item
            t0 = time.monotonic()
            reg.histogram("gather.queue_wait_seconds").observe(t0 - t_submit)
            try:
                with _trace.span("gather.exec"):
                    fn()
            except Exception:
                pass  # gather closures settle their barrier slot themselves
            reg.histogram("gather.exec_seconds").observe(
                time.monotonic() - t0)

    def shutdown(self, timeout: float = 1.0) -> None:
        """Drain the workers (atexit): daemon threads would not block exit,
        but an orderly stop keeps in-flight gathers from dying mid-memcpy."""
        with self._lock:
            self._closed = True
            threads = list(self._threads)
        for _ in threads:
            self._q.put(None)
        for t in threads:
            t.join(timeout=timeout)


_gather_pool: Optional[_GatherPool] = None
_gather_pool_lock = threading.Lock()


def _gather_executor() -> _GatherPool:
    global _gather_pool
    with _gather_pool_lock:
        if _gather_pool is None:
            _gather_pool = _GatherPool(max_workers=4)
            atexit.register(_gather_pool.shutdown)
        return _gather_pool


def _off_reactor(fn: Callable[[], None]) -> None:
    """Run ``fn`` on the gather pool when called from a reactor completion
    pump, inline otherwise — detected by thread, not by submission phase, so
    the pump never memcpys even when a short emulated transfer retires
    mid-registration."""
    if in_reactor_thread():
        _gather_executor().submit(fn)
    else:
        _registry().counter("gather.inline").inc()
        fn()


class StripeChunk:
    """One stripe chunk of a logical zone extent, in logical order.

    ``index`` is the global chunk index (logical order key), ``device`` the
    member the chunk is READ from under the current member health (for
    ``raid1`` the round-robin replica, redirected to the survivor when its
    partner is OFFLINE; for a reconstructing ``xor`` chunk the row's parity
    member, the anchor of the survivor fan-in), ``local_off``/``n_blocks``
    the member-local extent. ``degraded`` marks a chunk served without its
    preferred member; ``reconstruct`` marks an xor chunk whose bytes must be
    rebuilt from the surviving row members rather than read directly.
    """

    __slots__ = ("index", "device", "local_off", "n_blocks", "logical_off",
                 "row", "col", "degraded", "reconstruct")

    def __init__(self, index: int, device: int, local_off: int,
                 n_blocks: int, logical_off: int, *, row: int = 0,
                 col: int = 0, degraded: bool = False,
                 reconstruct: bool = False):
        self.index = index
        self.device = device
        self.local_off = local_off
        self.n_blocks = n_blocks
        self.logical_off = logical_off
        self.row = row
        self.col = col
        self.degraded = degraded
        self.reconstruct = reconstruct

    def __repr__(self) -> str:
        flags = "".join(
            [" degraded" if self.degraded else "",
             " reconstruct" if self.reconstruct else ""])
        return (f"StripeChunk(#{self.index} dev{self.device} "
                f"local[{self.local_off},+{self.n_blocks}){flags})")


def coalesce_member_runs(
        chunks: Sequence[StripeChunk],
        stripe_blocks: int) -> list[tuple[int, list[tuple[int, StripeChunk]]]]:
    """Group ``chunks`` by member and split each member's share into maximal
    member-locally CONTIGUOUS runs — ``[(device, [(position, chunk), ...])]``
    where ``position`` is the chunk's index within the input sequence.

    One run is one device read: raid0/xor full chunks of a member are
    consecutive multiples of ``stripe_blocks`` apart so whole groups coalesce
    into a single transfer, while raid1's round-robin replica assignment
    leaves row-sized holes in member-local space and degrades to per-chunk
    runs. Layout-agnostic on purpose — the scheduler's staged read phase uses
    it for every redundancy mode, so a future placement scheme cannot
    silently break the fan-out's coalescing.
    """
    by_dev: dict[int, list[tuple[int, StripeChunk]]] = {}
    for pos, c in enumerate(chunks):
        by_dev.setdefault(c.device, []).append((pos, c))
    runs: list[tuple[int, list[tuple[int, StripeChunk]]]] = []
    for dev in sorted(by_dev):
        items = sorted(by_dev[dev], key=lambda pc: pc[1].local_off)
        run = [items[0]]
        for pc in items[1:]:
            prev = run[-1][1]
            if pc[1].local_off == prev.local_off + prev.n_blocks:
                run.append(pc)
            else:
                runs.append((dev, run))
                run = [pc]
        runs.append((dev, run))
    return runs


class _DirectRead:
    """One coalesced member-extent read, scattered into the logical buffer
    at completion time (possibly covering several logical chunks)."""

    __slots__ = ("device", "local_off", "n_blocks", "copies", "fut")

    def __init__(self, device: int, local_off: int, n_blocks: int,
                 copies: list[tuple[int, int, int]]):
        self.device = device
        self.local_off = local_off
        self.n_blocks = n_blocks
        self.copies = copies          # (src_block, dst_block, n_blocks)
        self.fut: Optional[IoFuture] = None

    def submit(self, arr: "StripedZoneArray", zone_id: int) -> tuple:
        self.fut = arr.devices[self.device].submit_read(
            zone_id, self.local_off, self.n_blocks)
        return (self.fut,)

    def attach(self, arr: "StripedZoneArray", out: np.ndarray,
               barrier: CompletionBarrier, slot: int) -> None:
        fut = self.fut

        def apply() -> None:
            err = fut.error
            if err is None:
                try:
                    buf = np.asarray(fut._value).reshape(-1, arr.block_bytes)
                    for src, dst, n in self.copies:
                        out[dst:dst + n] = buf[src:src + n]
                except BaseException as e:
                    err = e
            barrier.settle(slot, err)

        fut.add_done_callback(lambda _f: _off_reactor(apply))


class _XorReconstruct:
    """Rebuild a dead member's chunk span as the XOR of the surviving row
    members. ``seed`` starts as zeros (complete row: the parity chunk is one
    of the reads) or as the host parity-accumulator slice (tail row: the
    parity chunk has not landed yet, the accumulator IS its current value).
    Survivor reads are ordinary member transfers on the completion ring; the
    XOR combine runs once the last of them retires."""

    __slots__ = ("reads", "seed", "dst", "n_blocks", "futs")

    def __init__(self, reads: list[tuple[int, int, int]], seed: np.ndarray,
                 dst: int, n_blocks: int):
        self.reads = reads            # (device, local_off, n_avail > 0)
        self.seed = seed              # (n_blocks, block_bytes) uint8, owned
        self.dst = dst
        self.n_blocks = n_blocks
        self.futs: list[IoFuture] = []

    def submit(self, arr: "StripedZoneArray", zone_id: int) -> tuple:
        self.futs = [arr.devices[d].submit_read(zone_id, lo, n)
                     for d, lo, n in self.reads]
        return tuple(self.futs)

    def attach(self, arr: "StripedZoneArray", out: np.ndarray,
               barrier: CompletionBarrier, slot: int) -> None:
        def on_all(vals: list, err: Optional[BaseException]) -> None:
            def apply() -> None:
                e = err
                if e is None:
                    try:
                        acc = self.seed
                        for v in vals:
                            buf = np.asarray(v).reshape(-1, arr.block_bytes)
                            acc[: len(buf)] ^= buf
                        out[self.dst: self.dst + self.n_blocks] = acc
                    except BaseException as ee:
                        e = ee
                barrier.settle(slot, e)

            _off_reactor(apply)

        inner = CompletionBarrier(len(self.futs), on_all)
        for i, f in enumerate(self.futs):
            f.add_done_callback(lambda f, i=i: inner.settle(
                i, f.error, None if f.error is not None else f._value))


class LogicalZone:
    """View of one logical (striped) zone.

    Duck-types the fields of :class:`repro.zns.device.Zone` that callers use:
    ``zone_id``, ``write_pointer`` (settable — distributes to members, needed
    by checkpoint recovery), ``state`` (derived; settable — broadcast to
    surviving members), ``capacity_blocks``, ``remaining_blocks``,
    ``is_writable``, ``reset_count``, plus ``degraded`` (a member zone is
    OFFLINE but the redundancy mode still covers its data).
    """

    def __init__(self, array: "StripedZoneArray", zone_id: int):
        self._array = array
        self.zone_id = zone_id

    def _members(self):
        return [d.zone(self.zone_id) for d in self._array.devices]

    @property
    def capacity_blocks(self) -> int:
        return self._array.zone_blocks

    @property
    def write_pointer(self) -> int:
        return self._array._wp[self.zone_id]

    @write_pointer.setter
    def write_pointer(self, w: int) -> None:
        self._array._set_write_pointer(self.zone_id, int(w))

    @property
    def state(self) -> ZoneState:
        arr = self._array
        with arr._lock:
            states = [z.state for z in self._members()]
            reb = arr._rebuilding.get(self.zone_id)
            off = [i for i, s in enumerate(states)
                   if s is ZoneState.OFFLINE or i == reb]
            if arr._is_unrecoverable(off):
                return ZoneState.OFFLINE
            if off or self.zone_id in arr._fenced:
                # degraded (redundancy covers the dead member) or torn (a
                # mid-append member failure): committed data stays readable,
                # new appends are refused until reset/rebuild
                return ZoneState.READ_ONLY
            alive = set(states)
            if ZoneState.READ_ONLY in alive:
                return ZoneState.READ_ONLY
            if alive == {ZoneState.EMPTY}:
                return ZoneState.EMPTY
            if alive == {ZoneState.FULL}:
                return ZoneState.FULL
            return ZoneState.OPEN

    @state.setter
    def state(self, st: ZoneState) -> None:
        with self._array._lock:
            reb = self._array._rebuilding.get(self.zone_id)
            for i, z in enumerate(self._members()):
                if z.state is ZoneState.OFFLINE or i == reb:
                    # fault injection is not undone by a broadcast, and a
                    # mid-rebuild member reconciles its state at cutover
                    continue
                z.state = st

    @property
    def degraded(self) -> bool:
        arr = self._array
        with arr._lock:
            off = arr._offline_members(self.zone_id)
            return bool(off) and not arr._is_unrecoverable(off)

    @property
    def reset_count(self) -> int:
        return max(z.reset_count for z in self._members())

    @property
    def remaining_blocks(self) -> int:
        return self.capacity_blocks - self.write_pointer

    @property
    def is_writable(self) -> bool:
        return self.state in (ZoneState.EMPTY, ZoneState.OPEN)

    def __repr__(self) -> str:
        return (f"LogicalZone(id={self.zone_id}, wp={self.write_pointer}/"
                f"{self.capacity_blocks}, state={self.state.value})")


class StripedZoneArray:
    """N identical ZNS devices presented as one logical zoned device, with
    optional redundancy (``raid0`` striping, ``raid1`` mirror pairs, ``xor``
    rotating parity)."""

    def __init__(self, devices: Sequence[ZonedDevice], *,
                 stripe_blocks: int = 16, redundancy: str = "raid0"):
        if not devices:
            raise ValueError("StripedZoneArray needs at least one device")
        d0 = devices[0]
        for i, d in enumerate(devices):
            if (d.num_zones, d.zone_blocks, d.block_bytes) != (
                    d0.num_zones, d0.zone_blocks, d0.block_bytes):
                raise ValueError(
                    f"member {i} geometry {(d.num_zones, d.zone_blocks, d.block_bytes)} "
                    f"differs from member 0 {(d0.num_zones, d0.zone_blocks, d0.block_bytes)}"
                )
        if stripe_blocks <= 0:
            raise ValueError("stripe_blocks must be positive")
        if d0.zone_blocks % stripe_blocks != 0:
            raise ValueError(
                f"stripe_blocks {stripe_blocks} must divide member zone size "
                f"{d0.zone_blocks} (chunks may not straddle member zones)"
            )
        if redundancy not in REDUNDANCY_MODES:
            raise ValueError(
                f"redundancy {redundancy!r} not one of {REDUNDANCY_MODES}")
        self.devices = list(devices)
        self.n_devices = len(self.devices)
        self.stripe_blocks = int(stripe_blocks)
        self.redundancy = redundancy
        if redundancy == "raid1":
            if self.n_devices < 2 or self.n_devices % 2:
                raise ValueError(
                    f"raid1 needs an even member count >= 2, got {self.n_devices}")
            self.data_columns = self.n_devices // 2
        elif redundancy == "xor":
            if self.n_devices < 3:
                raise ValueError(
                    f"xor needs >= 3 members (use raid1 for 2), got {self.n_devices}")
            self.data_columns = self.n_devices - 1
        else:
            self.data_columns = self.n_devices
        self.num_zones = d0.num_zones
        self.block_bytes = d0.block_bytes
        # logical geometry: every DATA column contributes its whole zone
        # (raid1 pairs store one copy per partner; xor spends one member's
        # worth of capacity on parity)
        self.zone_blocks = d0.zone_blocks * self.data_columns
        self.zone_bytes = self.zone_blocks * self.block_bytes
        self._lock = threading.RLock()
        # logical write pointers are array state (the one source of truth):
        # member write pointers derive from them per mode — xor parity
        # rotation makes a member-sum derivation ambiguous. Appends advance
        # _wp LAST, under the lock, once every member submission landed.
        self._wp = [0] * self.num_zones
        # zones torn by a mid-append member failure: some members landed
        # their share, others did not — committed data (< _wp) stays
        # readable, appends are fenced until reset_zone
        self._fenced: set[int] = set()
        # xor: host-side parity accumulator per zone — XOR of all data
        # landed in the (at most one) incomplete tail stripe row, i.e. the
        # value the row's parity chunk will have once the row completes
        # (a real RAID controller's NVRAM parity buffer)
        self._pacc: dict[int, np.ndarray] = {}
        # zones whose tail-row accumulator could NOT be recomputed at
        # write-pointer recovery (a tail-row data member was OFFLINE and its
        # parity never landed): tail reconstruction for these must raise,
        # never fabricate zero bytes
        self._pacc_lost: set[int] = set()
        # array-level counters on a PRIVATE registry (arrays are unbounded;
        # the process-global registry is reserved for singletons) — atomic,
        # so the fan-out finalize path no longer re-takes the array lock
        self.metrics = MetricsRegistry("array")
        self._c_degraded_reads = self.metrics.counter("degraded_reads")
        self._c_gather_bytes = self.metrics.counter("gather_bytes_copied")
        # zones that already announced degraded serving in the event log —
        # the first degraded read per zone is the operator-visible moment,
        # the per-read volume lives in the degraded_reads counter
        self._degraded_announced: set[int] = set()
        # zones mid-rebuild: {zone_id: member index being reconstructed}.
        # Planning treats the member as dead for these zones regardless of
        # its actual zone state (the spare's zone is revived EMPTY while the
        # copy runs), and the logical zone stays READ_ONLY — the write
        # pointer must not move under an in-progress reconstruction. Each
        # zone leaves the map individually at commit_member_rebuild, so
        # rebuilt zones accept appends while later zones are still copying.
        self._rebuilding: dict[int, int] = {}
        # member transfers fan out as in-flight completion-ring descriptors
        # (repro.zns.ring): an N-member read holds N reactor slots and ZERO
        # worker threads, and CONCURRENT logical reads (different zones /
        # tenants) overlap on the members' per-zone virtual clocks instead of
        # queuing behind a thread-pool's size.
        self.zones = [LogicalZone(self, z) for z in range(self.num_zones)]

    # -------------------------------------------------------- address math
    def _row_devices(self, row: int) -> tuple[list[int], int]:
        """xor: (data devices in column order, parity device) for a stripe
        row — left-symmetric rotation, so parity load spreads evenly."""
        p = (self.n_devices - 1) - (row % self.n_devices)
        return [d for d in range(self.n_devices) if d != p], p

    def _replicas(self, row: int, col: int) -> tuple[int, ...]:
        """Members holding chunk (row, col)'s data, preferred-read first
        (raid1 round-robins the mirror pair by row for ~2x read bandwidth)."""
        if self.redundancy == "raid1":
            pref = 2 * col + (row & 1)
            return (pref, 2 * col + ((row & 1) ^ 1))
        if self.redundancy == "xor":
            return (self._row_devices(row)[0][col],)
        return (col,)

    def _offline_members(self, zone_id: int) -> list[int]:
        """Members the zone cannot be served from: actually-OFFLINE zones
        plus the member a rebuild is reconstructing (its revived spare zone
        holds no data yet)."""
        reb = self._rebuilding.get(zone_id)
        return [i for i, d in enumerate(self.devices)
                if i == reb or d.zone(zone_id).state is ZoneState.OFFLINE]

    def _is_unrecoverable(self, offline: list[int]) -> bool:
        """True when the OFFLINE member set defeats the redundancy mode."""
        if not offline:
            return False
        if self.redundancy == "raid0":
            return True
        if self.redundancy == "raid1":
            s = set(offline)
            return any(2 * c in s and 2 * c + 1 in s
                       for c in range(self.data_columns))
        return len(offline) > 1

    def _chunk_source(self, zone_id: int, row: int, col: int,
                      alive: list[bool]) -> tuple[int, bool, bool]:
        """(read device, degraded, reconstruct) for chunk (row, col) under
        the current member health."""
        if self.redundancy == "raid0":
            # dead members surface at member-read time (the PR 2 clean-error
            # contract); the logical zone is OFFLINE anyway
            return col, False, False
        if self.redundancy == "raid1":
            pref, alt = self._replicas(row, col)
            if alive[pref]:
                return pref, False, False
            if alive[alt]:
                return alt, True, False
            raise ZoneStateError(
                f"zone {zone_id} unrecoverable: both mirrors of column {col} "
                f"(devices {2 * col},{2 * col + 1}) are offline")
        data_devs, parity = self._row_devices(row)
        d = data_devs[col]
        if alive[d]:
            return d, False, False
        if sum(1 for a in alive if not a) > 1:
            raise ZoneStateError(
                f"zone {zone_id} unrecoverable: more than one member offline "
                f"under xor parity")
        return parity, True, True

    def chunks(self, zone_id: int, block_off: int, n_blocks: int) -> list[StripeChunk]:
        """Decompose a logical extent into stripe chunks, in logical order,
        with health-aware read-source assignment.

        Each chunk is contiguous both logically and on its member device —
        the unit the offload scheduler fans out. Chunks whose preferred
        member zone is OFFLINE come back ``degraded`` (raid1: redirected to
        the mirror partner) or ``degraded + reconstruct`` (xor: must be
        rebuilt from the surviving row members).
        """
        with self._lock:
            return self._plan_chunks(zone_id, block_off, n_blocks)

    def _plan_chunks(self, zone_id: int, block_off: int,
                     n_blocks: int) -> list[StripeChunk]:
        self.zone(zone_id)  # bounds-check the zone id
        s, C = self.stripe_blocks, self.data_columns
        reb = self._rebuilding.get(zone_id)
        alive = [i != reb and d.zone(zone_id).state is not ZoneState.OFFLINE
                 for i, d in enumerate(self.devices)]
        out: list[StripeChunk] = []
        b, end = block_off, block_off + n_blocks
        while b < end:
            chunk = b // s
            take = min(end - b, (chunk + 1) * s - b)
            row, col = divmod(chunk, C)
            local = row * s + b % s
            device, degraded, recon = self._chunk_source(
                zone_id, row, col, alive)
            out.append(StripeChunk(chunk, device, local, take, b, row=row,
                                   col=col, degraded=degraded,
                                   reconstruct=recon))
            b += take
        return out

    # ------------------------------------------------------------- zones
    def zone(self, zone_id: int) -> LogicalZone:
        if not 0 <= zone_id < self.num_zones:
            raise OutOfBoundsError(f"zone {zone_id} out of range [0,{self.num_zones})")
        return self.zones[zone_id]

    def report_zones(self) -> list[LogicalZone]:
        return list(self.zones)

    def open_zones(self) -> list[LogicalZone]:
        return [z for z in self.zones if z.state == ZoneState.OPEN]

    def _pacc_for(self, zone_id: int) -> np.ndarray:
        acc = self._pacc.get(zone_id)
        if acc is None:
            acc = self._pacc[zone_id] = np.zeros(
                (self.stripe_blocks, self.block_bytes), np.uint8)
        return acc

    # ------------------------------------------------------------- append
    def zone_append(self, zone_id: int, data: np.ndarray | bytes, *,
                    timeout: Optional[float] = None) -> int:
        """Striped Zone Append: split ``data`` into stripe chunks and append
        each member's share at that member's write pointer (mirrored on both
        partners under raid1; with a parity chunk per completed stripe row
        under xor). Returns the logical start block. Synchronous shim over
        :meth:`submit_append` — member transfers share one wall-clock window
        (each member's emulated busy time runs on its own zone clock), the
        call returns at the last member's completion deadline. ``timeout``
        bounds the wait; on expiry the ``TimeoutError`` names the stuck
        member transfer (a hung command cannot strand the caller)."""
        return self.submit_append(zone_id, data).result(timeout)

    def _append_plan(
        self, zone_id: int, start: int, blocks: np.ndarray
    ) -> list[tuple[int, np.ndarray, int]]:
        """Member appends for logical blocks [start, start+len(blocks)) as
        ``(device, payload, expected_landing_block)`` in submission order.
        Under xor this also folds the data into the zone's parity accumulator
        and emits the parity-chunk append of every row the payload completes.
        Caller holds the array lock."""
        s, C = self.stripe_blocks, self.data_columns
        n = len(blocks)
        plan: list[tuple[int, np.ndarray, int]] = []
        if self.redundancy != "xor":
            owner_col = (np.arange(start, start + n) // s) % C
            for c in range(C):
                sel = owner_col == c
                if not sel.any():
                    continue
                share = blocks[sel]
                first = start + int(np.flatnonzero(sel)[0])
                chunk, within = divmod(first, s)
                expect = (chunk // C) * s + within
                devs = (c,) if self.redundancy == "raid0" \
                    else (2 * c, 2 * c + 1)
                for dev in devs:
                    plan.append((dev, share, expect))
            return plan
        # A member's data chunks across consecutive rows are member-locally
        # contiguous except where the parity rotation makes it the parity
        # member, so buffer each member's share and flush one coalesced
        # append per contiguous run — ~(N-1) rows per member append instead
        # of one append per chunk. A member's parity chunk flushes its
        # buffered data first (its data for earlier rows must land below the
        # parity slot).
        acc = self._pacc_for(zone_id)
        pending: dict[int, list] = {}   # dev -> [parts, expect_local, nblocks]

        def flush(dev: int) -> None:
            entry = pending.pop(dev, None)
            if entry is None:
                return
            parts, expect, _nb = entry
            payload = parts[0] if len(parts) == 1 else np.concatenate(parts)
            plan.append((dev, payload, expect))

        b, end = start, start + n
        while b < end:
            chunk = b // s
            take = min(end - b, (chunk + 1) * s - b)
            row, col = divmod(chunk, C)
            within = b % s
            data_devs, parity = self._row_devices(row)
            d = data_devs[col]
            share = blocks[b - start: b - start + take]
            local = row * s + within
            entry = pending.get(d)
            if entry is not None and entry[1] + entry[2] == local:
                entry[0].append(share)
                entry[2] += take
            else:
                flush(d)
                pending[d] = [[share], local, take]
            acc[within: within + take] ^= share
            if col == C - 1 and b + take == (chunk + 1) * s:
                # the stripe row is complete: its parity value is final —
                # append it to the rotating parity member and reset the
                # accumulator for the next row
                flush(parity)
                plan.append((parity, acc.copy(), row * s))
                acc[:] = 0
            b += take
        for dev in list(pending):
            flush(dev)
        return plan

    def _refusal_detail(self, zone_id: int, state: ZoneState) -> str:
        """Append-refusal message naming WHY the logical zone is not
        writable — offline member indices, redundancy mode, rebuild/fence
        status — so operators can correlate the refusal with
        ``array.member_offline`` events instead of guessing. Caller holds
        the array lock."""
        clauses = [f"state={state}", f"redundancy={self.redundancy}"]
        offline = [i for i, d in enumerate(self.devices)
                   if d.zone(zone_id).state is ZoneState.OFFLINE]
        if offline:
            clauses.append(f"offline members={offline}")
        reb = self._rebuilding.get(zone_id)
        if reb is not None:
            clauses.append(f"member {reb} rebuilding onto spare")
        if zone_id in self._fenced:
            clauses.append("fenced by a torn/failed append")
        hint = ""
        if offline or reb is not None:
            hint = (" — correlate with array.member_offline events; appends "
                    "resume after rebuild-to-spare (or reset_zone)")
        return (f"logical zone {zone_id} not writable "
                f"({', '.join(clauses)}){hint}")

    def submit_append(self, zone_id: int, data: np.ndarray | bytes, *,
                      ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous striped Zone Append: member writes land immediately
        (metadata and bytes, under the array lock), the returned future
        retires when the LAST member completion does, with the logical start
        block as its value. ``fut.submitted_block`` carries the logical start
        synchronously.

        A member failing mid-fan-out (e.g. its zone going OFFLINE between
        the array check and its submission) FAILS the aggregate instead of
        orphaning the already-submitted member futures: they settle a
        barrier that retires the aggregate with the error once the last of
        them completes, and the zone is fenced READ_ONLY (its members no
        longer agree on the stripe stream) until ``reset_zone``.
        """
        raw = payload_as_uint8(data)
        nblocks = -(-raw.size // self.block_bytes)  # ceil
        member_futs: list[IoFuture] = []
        error: Optional[BaseException] = None
        with self._lock:
            z = self.zone(zone_id)
            if not z.is_writable:
                raise ZoneStateError(self._refusal_detail(zone_id, z.state))
            start = z.write_pointer
            if nblocks > z.remaining_blocks:
                raise ZoneFullError(
                    f"append of {nblocks} blocks exceeds logical zone {zone_id} "
                    f"remaining {z.remaining_blocks}"
                )
            padded = np.zeros(nblocks * self.block_bytes, np.uint8)
            padded[: raw.size] = raw
            blocks = padded.reshape(nblocks, self.block_bytes)
            acc_backup = self._pacc_for(zone_id).copy() \
                if self.redundancy == "xor" else None
            try:
                plan = self._append_plan(zone_id, start, blocks)
                for dev_idx, payload, expect in plan:
                    f = self.devices[dev_idx].submit_append(zone_id, payload)
                    member_futs.append(f)
                    # member-local target is contiguous and starts at the
                    # member write pointer (appends only go through the array)
                    if f.submitted_block != expect:
                        raise ZoneStateError(
                            f"stripe desync on device {dev_idx} zone {zone_id}: "
                            f"member append landed at {f.submitted_block}, "
                            f"expected {expect}"
                        )
            except BaseException as e:
                error = e
                if acc_backup is not None:
                    self._pacc[zone_id] = acc_backup
                if member_futs:
                    self._fenced.add(zone_id)
            else:
                # the logical write pointer advances LAST, under this lock:
                # readers never see a range whose member shares have not all
                # been submitted
                self._wp[zone_id] = start + nblocks

        agg = IoFuture(op="append", zone_id=zone_id, block_off=start,
                       nblocks=nblocks,
                       service_seconds=max(
                           (f.service_seconds for f in member_futs),
                           default=0.0),
                       ring=ring)
        agg.submitted_block = start
        agg.device = "array"
        agg.waits_on = member_futs
        if error is not None:
            if member_futs:
                # the zone was fenced above: members no longer agree on the
                # stripe stream until reset_zone
                _publish_event(
                    "array.zone_fenced", severity=_Sev.ERROR,
                    message=f"logical zone {zone_id} fenced READ_ONLY after "
                            f"torn append: {error}",
                    zone=zone_id, error=type(error).__name__)
            err = error
            barrier = CompletionBarrier(
                len(member_futs), lambda _vals, _e: agg.fail(err))
            for i, f in enumerate(member_futs):
                f.add_done_callback(lambda f, i=i: barrier.settle(i, f.error))
            return agg
        self._join_members(
            agg, member_futs, lambda: start,
            on_error=lambda err: self._fence_on_completion(zone_id, err))
        return agg

    @staticmethod
    def _join_members(agg: IoFuture, member_futs: list[IoFuture],
                      finalize: Callable[[], object],
                      on_error: Optional[Callable[[BaseException], None]] = None
                      ) -> None:
        """Retire ``agg`` with ``finalize()`` (or the first member error) once
        every member future has retired. Members that completed inline fire
        their callback inline, so a fully-inline fan-out retires ``agg``
        before this returns (including the zero-member case). ``on_error``
        runs before the aggregate fails — the append path fences the zone
        there, since a member completion error (exhausted retry budget, torn
        append) means the members no longer agree on the stripe stream."""

        def done(_vals, err):
            if err is not None:
                if on_error is not None:
                    on_error(err)
                agg.fail(err)
            else:
                agg.complete(finalize())

        barrier = CompletionBarrier(len(member_futs), done)
        for i, f in enumerate(member_futs):
            f.add_done_callback(lambda f, i=i: barrier.settle(i, f.error))

    def _fence_on_completion(self, zone_id: int, err: BaseException) -> None:
        """A member append FAILED at completion time (the submit itself was
        legal): fence the logical zone READ_ONLY — its members may disagree
        on the stripe stream past the last joined append — and page the
        operator. Reads still serve; appends refuse until ``reset_zone``.
        Idempotent per fence epoch."""
        with self._lock:
            if zone_id in self._fenced:
                return
            self._fenced.add(zone_id)
        _publish_event(
            "array.zone_fenced", severity=_Sev.ERROR,
            message=f"logical zone {zone_id} fenced READ_ONLY after a member "
                    f"append failed at completion: {err}",
            zone=zone_id, error=type(err).__name__)

    # --------------------------------------------------------------- read
    def read_blocks(self, zone_id: int, block_off: int, nblocks: int, *,
                    timeout: Optional[float] = None) -> np.ndarray:
        """Striped read, interleaved back into logical order (reconstructing
        any chunk whose member is OFFLINE under raid1/xor).

        Only the bounds check, address math, and member submissions run
        under the array lock; member transfers (and their emulated bandwidth
        time) ride the completion ring, so concurrent array-level reads —
        different zones, different tenants — overlap instead of queuing
        behind one logical read or a worker-pool's thread count. Safe
        against concurrent appends because the logical write pointer only
        covers member blocks whose appends have fully landed (appends update
        it last, under this lock). Resetting + rewriting a zone while a read
        of it is in flight is a host protocol bug (same contract as
        ``ZonedDevice.read_blocks_view``, and as real ZNS hardware).
        ``timeout`` bounds the join; on expiry the ``TimeoutError`` names
        the member transfer still in flight.
        """
        out = self.submit_read(zone_id, block_off, nblocks).result(timeout)
        out = np.asarray(out)
        out = out.view()               # the gather buffer is private: hand the
        out.flags.writeable = True     # sync caller an owned, mutable stream
        return out

    def _read_jobs(self, zone_id: int, block_off: int,
                   chunks: list[StripeChunk]) -> list:
        """Scatter units for a striped read: direct member reads (coalesced
        while member-locally contiguous — raid0's one-read-per-device fast
        path falls out of this) plus one XOR-reconstruction job per dead-
        member chunk. Caller holds the array lock."""
        jobs: list = []
        open_direct: dict[int, _DirectRead] = {}
        for c in chunks:
            dst = c.logical_off - block_off
            if c.reconstruct:
                jobs.append(self._xor_job(zone_id, c, dst))
                continue
            run = open_direct.get(c.device)
            if run is not None and run.local_off + run.n_blocks == c.local_off:
                run.copies.append((run.n_blocks, dst, c.n_blocks))
                run.n_blocks += c.n_blocks
            else:
                run = _DirectRead(c.device, c.local_off, c.n_blocks,
                                  [(0, dst, c.n_blocks)])
                open_direct[c.device] = run
                jobs.append(run)
        return jobs

    def _xor_job(self, zone_id: int, c: StripeChunk, dst: int) -> _XorReconstruct:
        """Survivor reads + seed buffer reconstructing chunk ``c`` (xor mode,
        its data member OFFLINE). Complete rows XOR the parity chunk with the
        other data chunks; the tail row seeds from the host parity
        accumulator (its parity chunk has not landed) and XORs out the
        survivors' present spans. Caller holds the array lock."""
        s, C = self.stripe_blocks, self.data_columns
        a = c.local_off - c.row * s          # offset within the stripe row
        data_devs, parity = self._row_devices(c.row)
        reads: list[tuple[int, int, int]] = []
        if self._wp[zone_id] >= (c.row + 1) * C * s:   # row complete
            seed = np.zeros((c.n_blocks, self.block_bytes), np.uint8)
            for c2, d in enumerate(data_devs):
                if c2 != c.col:
                    reads.append((d, c.local_off, c.n_blocks))
            reads.append((parity, c.local_off, c.n_blocks))
        else:
            if zone_id in self._pacc_lost:
                raise ZoneStateError(
                    f"zone {zone_id} tail-row chunk {c.index} is "
                    f"unrecoverable: its parity never landed and the "
                    f"accumulator was recovered with a member already "
                    f"offline (tail data lost)")
            rem = self._wp[zone_id] - c.row * C * s
            rc, partial = divmod(rem, s)
            seed = self._pacc_for(zone_id)[a: a + c.n_blocks].copy()
            for c2, d in enumerate(data_devs):
                if c2 == c.col:
                    continue
                avail = s if c2 < rc else (partial if c2 == rc else 0)
                n2 = min(c.n_blocks, max(0, avail - a))
                if n2 > 0:
                    reads.append((d, c.local_off, n2))
        return _XorReconstruct(reads, seed, dst, c.n_blocks)

    def submit_read(self, zone_id: int, block_off: int, nblocks: int, *,
                    dtype: Optional[np.dtype | str] = None,
                    ring: Optional[CompletionRing] = None) -> IoFuture:
        """Asynchronous striped read: in-flight member transfers gathered
        into logical stripe order as their completions retire; the returned
        future retires with the last member's, valued as the read-only
        interleaved extent (``dtype``-typed when given). Chunks owned by an
        OFFLINE member are served degraded — raid1 redirects to the mirror
        partner, xor XORs the surviving row members — on the SAME completion
        ring (no extra threads; reconstruction is completion-time work on
        the gather pool).

        A member failing mid-fan-out fails the aggregate through the job
        barrier: already-submitted member completions settle their slots as
        they retire, the unsubmitted remainder settles with the error, so
        the aggregate ALWAYS retires (no orphaned futures, no hanging
        callers).
        """
        if dtype is not None:
            dtype = block_aligned_dtype(self.block_bytes, dtype)
        with self._lock:
            z = self.zone(zone_id)
            if z.state is ZoneState.OFFLINE:
                raise ZoneStateError(f"logical zone {zone_id} is offline")
            if block_off < 0 or nblocks < 0 or block_off + nblocks > z.write_pointer:
                raise OutOfBoundsError(
                    f"read [{block_off},{block_off + nblocks}) beyond write pointer "
                    f"{z.write_pointer} of logical zone {zone_id}"
                )
            agg = IoFuture(op="read", zone_id=zone_id, block_off=block_off,
                           nblocks=nblocks, ring=ring)
            agg.device = "array"
            out = np.empty((nblocks, self.block_bytes), np.uint8)

            def finalize():
                self._c_gather_bytes.inc(out.nbytes)
                flat = out.reshape(-1)
                if dtype is not None:
                    flat = flat.view(dtype)
                flat.flags.writeable = False
                return flat

            if nblocks == 0:
                agg.complete(finalize())
                return agg
            chunks = self._plan_chunks(zone_id, block_off, nblocks)
            n_degraded = sum(1 for c in chunks if c.degraded)
            if n_degraded:
                self._c_degraded_reads.inc(n_degraded)
            jobs = self._read_jobs(zone_id, block_off, chunks)
            barrier = CompletionBarrier(
                len(jobs),
                lambda _vals, err: agg.fail(err) if err is not None
                else agg.complete(finalize()))
            submitted: list[tuple[int, object]] = []
            member_futs: list[IoFuture] = []
            service = 0.0
            for ji, job in enumerate(jobs):
                try:
                    futs = job.submit(self, zone_id)
                except BaseException as e:
                    for rest in range(ji, len(jobs)):
                        barrier.settle(rest, e)
                    break
                submitted.append((ji, job))
                for f in futs:
                    member_futs.append(f)
                    service = max(service, f.service_seconds)
            agg.service_seconds = service
            agg.waits_on = member_futs  # stuck-op diagnosis in result(timeout)
        # attach OUTSIDE the lock: inline completions (the non-emulated fast
        # path) then gather on the submitting thread without holding the
        # array lock; reactor-retired completions route through the gather
        # pool (detected by thread — the pump never memcpys)
        for ji, job in submitted:
            job.attach(self, out, barrier, ji)
        if n_degraded:
            self.note_degraded_serving(zone_id)
        return agg

    def note_degraded_serving(self, zone_id: int) -> None:
        """Publish the once-per-zone (until reset) operator event the first
        time a logical zone serves reads via reconstruction/redirect —
        per-read volume lives in the ``degraded_reads`` counter. Every read
        planner (the direct submit path and the offload scheduler's chunk
        planner) calls this outside the array lock; the lock is re-taken
        only for the announced-set check."""
        with self._lock:
            if zone_id in self._degraded_announced:
                return
            self._degraded_announced.add(zone_id)
        _publish_event(
            "array.degraded_read", severity=_Sev.WARNING,
            message=f"logical zone {zone_id} now serving degraded reads "
                    f"({self.redundancy})",
            zone=zone_id, redundancy=self.redundancy)

    def read_blocks_view(self, zone_id: int, block_off: int, nblocks: int) -> np.ndarray:
        """Minimal-copy read for the ``ZonedDevice`` view contract: a striped
        extent is not contiguous in any member buffer, so the stripe gather
        into logical order IS the single unavoidable copy."""
        out = self.read_blocks(zone_id, block_off, nblocks)
        out.flags.writeable = False
        return out

    def read_extent(self, zone_id: int, block_off: int, nblocks: int,
                    dtype: np.dtype | str) -> np.ndarray:
        """Dtype-typed minimal-copy read (one gather copy; the reinterpreting
        view is free — block alignment exceeds any element alignment)."""
        dtype = block_aligned_dtype(self.block_bytes, dtype)
        return self.read_blocks_view(zone_id, block_off, nblocks).view(dtype)

    def read_zone(self, zone_id: int) -> np.ndarray:
        return self.read_blocks(zone_id, 0, self.zone(zone_id).write_pointer)

    # ---------------------------------------------------- zone management
    def _member_write_pointers(self, w: int) -> list[int]:
        """Member write pointers implied by logical write pointer ``w``:
        member ``d`` owns exactly the blocks its mode maps there (under xor
        the parity chunks of FULL rows have landed, the tail row's has not).
        Pure address math — also the rebuild target a reconstructed member
        zone must reach before cutover."""
        s, C = self.stripe_blocks, self.data_columns
        full_rows, rem = divmod(int(w), s * C)
        rem_chunks, partial = divmod(rem, s)

        def tail(col: int) -> int:
            if col < rem_chunks:
                return s
            return partial if col == rem_chunks else 0

        if self.redundancy == "raid0":
            return [full_rows * s + tail(c) for c in range(C)]
        if self.redundancy == "raid1":
            return [full_rows * s + tail(d // 2)
                    for d in range(self.n_devices)]
        data_devs, _parity = self._row_devices(full_rows)
        wps = [full_rows * s] * self.n_devices
        for c in range(C):
            wps[data_devs[c]] += tail(c)
        return wps

    def _set_write_pointer(self, zone_id: int, w: int) -> None:
        """Distribute a logical write pointer across members (checkpoint
        recovery): member ``d`` owns the blocks its mode maps there. Under
        xor the parity members of full rows are assumed landed, and the
        tail-row parity accumulator is recomputed from the surviving
        members' data."""
        s, C = self.stripe_blocks, self.data_columns
        with self._lock:
            if zone_id in self._rebuilding:
                raise ZoneStateError(
                    f"logical zone {zone_id} write pointer frozen: member "
                    f"{self._rebuilding[zone_id]} rebuild in progress")
            full_rows, rem = divmod(int(w), s * C)
            rem_chunks, partial = divmod(rem, s)

            def tail(col: int) -> int:
                if col < rem_chunks:
                    return s
                return partial if col == rem_chunks else 0

            for d, wp in enumerate(self._member_write_pointers(w)):
                self.devices[d].zone(zone_id).write_pointer = wp
            self._wp[zone_id] = int(w)
            if self.redundancy == "xor":
                data_devs, _parity = self._row_devices(full_rows)
                acc = self._pacc_for(zone_id)
                acc[:] = 0
                self._pacc_lost.discard(zone_id)
                for c in range(C):
                    av = tail(c)
                    if not av:
                        continue
                    dev = self.devices[data_devs[c]]
                    if dev.zone(zone_id).state is ZoneState.OFFLINE:
                        # the dead member's tail-row data cannot re-enter the
                        # accumulator (its parity never landed): that span is
                        # GONE — mark it so tail reconstruction raises instead
                        # of silently returning zero bytes
                        self._pacc_lost.add(zone_id)
                        continue
                    acc[:av] ^= dev.read_blocks(
                        zone_id, full_rows * s, av).reshape(-1, self.block_bytes)

    def _zone_transition(self, zone_id: int, what: str,
                         fn: Callable[[ZonedDevice], None]) -> None:
        """Array-wide zone state transition under the array lock (a
        concurrent ``set_offline`` can no longer interleave mid-loop), with
        the OFFLINE guard ``reset_zone`` always had. A member failing
        mid-loop surfaces as :class:`ZoneStateError` naming the partial
        state instead of silently leaving members mixed."""
        with self._lock:
            if self.zone(zone_id).state is ZoneState.OFFLINE:
                raise ZoneStateError(f"logical zone {zone_id} is offline")
            reb = self._rebuilding.get(zone_id)
            done = 0
            try:
                for i, dev in enumerate(self.devices):
                    if dev.zone(zone_id).state is ZoneState.OFFLINE or i == reb:
                        # degraded survivors still transition; a mid-rebuild
                        # member reconciles its state at cutover
                        continue
                    fn(dev)
                    done += 1
            except ZNSError as e:
                raise ZoneStateError(
                    f"partial {what} of logical zone {zone_id}: {done}/"
                    f"{self.n_devices} members transitioned before a member "
                    f"refused: {e}"
                ) from e

    def finish_zone(self, zone_id: int) -> None:
        self._zone_transition(zone_id, "finish",
                              lambda dev: dev.finish_zone(zone_id))

    def set_read_only(self, zone_id: int) -> None:
        self._zone_transition(zone_id, "set_read_only",
                              lambda dev: dev.set_read_only(zone_id))

    def reset_zone(self, zone_id: int) -> None:
        with self._lock:
            if self.zone(zone_id).state is ZoneState.OFFLINE:
                raise ZoneStateError(f"logical zone {zone_id} is offline")
            offline = self._offline_members(zone_id)
            if offline:
                raise ZoneStateError(
                    f"logical zone {zone_id} degraded (members {offline} "
                    f"offline): rebuild before reset")
            for dev in self.devices:
                dev.reset_zone(zone_id)
            self._wp[zone_id] = 0
            self._fenced.discard(zone_id)
            self._degraded_announced.discard(zone_id)
            self._pacc_lost.discard(zone_id)
            if zone_id in self._pacc:
                self._pacc[zone_id][:] = 0

    def set_offline(self, zone_id: int, *, device: Optional[int] = None) -> None:
        """Fault injection: kill the zone on one member (``device``) or all.
        Taken under the array lock so state transitions and read planning
        see a consistent member-health snapshot."""
        with self._lock:
            targets = self.devices if device is None else [self.devices[device]]
            for dev in targets:
                dev.set_offline(zone_id)
        members = list(range(self.n_devices)) if device is None else [device]
        _publish_event(
            "array.member_offline", severity=_Sev.ERROR,
            message=f"zone {zone_id} killed on member(s) {members} "
                    f"({self.redundancy})",
            zone=zone_id, members=members, redundancy=self.redundancy)

    # ----------------------------------------------------- rebuild protocol
    # The low-level contract ArrayManager (repro.array.rebuild) drives:
    #   replace_member       swap a dead member for a spare, mark its zones
    #   begin_member_rebuild revive ONE spare zone EMPTY, freeze the logical wp
    #   <manager copies member_shard() bytes via ordinary appends>
    #   commit_member_rebuild per-zone cutover under the array lock — the zone
    #                        leaves the _rebuilding map (and thus READ_ONLY)
    #                        while later zones are still copying
    # Everything here is metadata under the array lock; the bulk copy itself
    # is ordinary (meterable, failable) member I/O owned by the manager.

    def replace_member(self, member: int, new_device: ZonedDevice) -> list[int]:
        """Swap ``new_device`` (a hot spare) into seat ``member`` and return
        the zone ids whose data must be reconstructed onto it.

        Pending zones enter the ``_rebuilding`` map and the spare's zone is
        parked OFFLINE (quietly — placeholder marking, not a health event)
        until ``begin_member_rebuild`` revives it for the copy. Zones already
        unrecoverable (xor double fault, both raid1 partners dead) are parked
        offline on the spare and NOT returned — their data is gone, rebuild
        cannot invent it. Replacing a member whose data is still live is
        refused when another member is already offline and the swap would
        turn a recoverable zone unrecoverable."""
        if not 0 <= member < self.n_devices:
            raise ValueError(f"member {member} out of range [0,{self.n_devices})")
        d0 = self.devices[0]
        if (new_device.num_zones, new_device.zone_blocks,
                new_device.block_bytes) != (
                d0.num_zones, d0.zone_blocks, d0.block_bytes):
            raise ValueError(
                f"spare geometry {(new_device.num_zones, new_device.zone_blocks, new_device.block_bytes)} "
                f"differs from array {(d0.num_zones, d0.zone_blocks, d0.block_bytes)}")
        with self._lock:
            pending: list[int] = []
            lost: list[int] = []
            plans: list[tuple[int, bool]] = []   # (zone, recoverable)
            for z in range(self.num_zones):
                if self._wp[z] == 0:
                    continue            # nothing landed: spare zone serves as-is
                off_now = self._offline_members(z)
                off_after = sorted(set(i for i in off_now if i != member)
                                   | {member})
                if self._is_unrecoverable(off_after):
                    if not self._is_unrecoverable(off_now):
                        # the seat still holds the only copy of live data —
                        # pulling it is operator error, refuse atomically
                        raise ZoneStateError(
                            f"replacing member {member} would make zone {z} "
                            f"unrecoverable (members {off_now} already "
                            f"offline, redundancy={self.redundancy})")
                    plans.append((z, False))
                else:
                    plans.append((z, True))
            for z, recoverable in plans:
                new_device.set_offline(z, quiet=True)
                if recoverable:
                    self._rebuilding[z] = member
                    pending.append(z)
                else:
                    lost.append(z)
            self.devices[member] = new_device
        _publish_event(
            "array.member_replaced", severity=_Sev.WARNING,
            message=f"member {member} replaced by spare dev{new_device.dev_ordinal}: "
                    f"{len(pending)} zone(s) pending rebuild"
                    + (f", {len(lost)} unrecoverable" if lost else ""),
            member=member, spare=new_device.dev_ordinal,
            pending=len(pending), lost=lost, redundancy=self.redundancy)
        return pending

    def rebuilding_zones(self) -> dict[int, int]:
        """Zones mid-rebuild as ``{zone_id: member index}`` (snapshot)."""
        with self._lock:
            return dict(self._rebuilding)

    def begin_member_rebuild(self, zone_id: int) -> tuple[int, int]:
        """Open one marked zone for reconstruction: revive the spare's
        parked zone EMPTY and return ``(member, logical_wp)`` — the copy
        target. Idempotent/restartable: a partially-copied zone (spare died
        or the manager crashed mid-copy) is re-parked and revived, so the
        copy always restarts from block 0."""
        with self._lock:
            member = self._rebuilding.get(zone_id)
            if member is None:
                raise ZoneStateError(
                    f"zone {zone_id} is not marked for rebuild "
                    f"(replace_member first)")
            dev = self.devices[member]
            mz = dev.zone(zone_id)
            if mz.state is not ZoneState.OFFLINE and mz.write_pointer > 0:
                dev.set_offline(zone_id, quiet=True)   # discard partial copy
            if dev.zone(zone_id).state is ZoneState.OFFLINE:
                dev.revive_zone(zone_id)
            return member, self._wp[zone_id]

    def commit_member_rebuild(self, zone_id: int) -> int:
        """Per-zone cutover: verify the reconstructed member zone reached
        exactly the write pointer the logical geometry implies, reconcile
        its state with the survivors', and lift the zone out of the
        ``_rebuilding`` map — appends resume here while later zones are
        still copying. Returns the member index."""
        with self._lock:
            member = self._rebuilding.get(zone_id)
            if member is None:
                raise ZoneStateError(
                    f"zone {zone_id} has no rebuild in progress to commit")
            dev = self.devices[member]
            mz = dev.zone(zone_id)
            expect = self._member_write_pointers(self._wp[zone_id])[member]
            if mz.state is ZoneState.OFFLINE or mz.write_pointer != expect:
                raise ZoneStateError(
                    f"rebuild cutover of zone {zone_id} refused: member "
                    f"{member} at wp {mz.write_pointer} (state={mz.state}), "
                    f"expected wp {expect}")
            surv = {z.state for i, d in enumerate(self.devices)
                    if i != member
                    and (z := d.zone(zone_id)).state is not ZoneState.OFFLINE}
            if ZoneState.READ_ONLY in surv:
                dev.set_read_only(zone_id)
            elif surv == {ZoneState.FULL} and mz.state is not ZoneState.FULL:
                dev.finish_zone(zone_id)
            del self._rebuilding[zone_id]
            self._degraded_announced.discard(zone_id)
        _publish_event(
            "array.zone_rebuilt", severity=_Sev.INFO,
            message=f"zone {zone_id} rebuilt onto member {member}: "
                    f"writable again",
            zone=zone_id, member=member, redundancy=self.redundancy)
        return member

    def abandon_member_rebuild(self, zone_id: int) -> None:
        """Give up reconstructing one zone (double fault on the source
        side): the partial copy is parked OFFLINE — a half-written member
        must never serve reads — and the zone leaves the rebuild map, so
        its logical state reflects the true member health."""
        with self._lock:
            member = self._rebuilding.pop(zone_id, None)
            if member is None:
                return
            dev = self.devices[member]
            if dev.zone(zone_id).state is not ZoneState.OFFLINE:
                dev.set_offline(zone_id, quiet=True)

    def member_shard(self, member: int, logical: np.ndarray, *,
                     base_block: int = 0) -> np.ndarray:
        """The byte stream member ``member`` stores for the logical extent
        ``[base_block, base_block + len(logical))`` — the rebuild payload.

        ``logical`` is ``(n, block_bytes)`` uint8 in logical block order;
        ``base_block`` must be stripe-row aligned (a multiple of
        ``stripe_blocks * data_columns``), so batched rebuild reads stay
        row-aligned and the xor parity rotation lines up. raid0/raid1
        members store their column's chunks verbatim; an xor member stores
        its data chunks plus, on rows where the rotation makes it the
        parity member, the XOR of the row's data chunks. The (at most one)
        incomplete tail row contributes data chunks only — its parity
        chunk has not landed (the host accumulator stands in for it)."""
        s, C = self.stripe_blocks, self.data_columns
        bb = self.block_bytes
        if not 0 <= member < self.n_devices:
            raise ValueError(f"member {member} out of range [0,{self.n_devices})")
        if base_block % (s * C):
            raise ValueError(
                f"base_block {base_block} not stripe-row aligned "
                f"(row = {s * C} blocks)")
        logical = np.ascontiguousarray(logical).reshape(-1, bb)
        n = len(logical)
        full_rows, rem = divmod(n, s * C)
        rem_chunks, partial = divmod(rem, s)

        def tail(col: int) -> int:
            if col < rem_chunks:
                return s
            return partial if col == rem_chunks else 0

        parts: list[np.ndarray] = []
        if self.redundancy != "xor":
            col = member if self.redundancy == "raid0" else member // 2
            for r in range(full_rows):
                base = (r * C + col) * s
                parts.append(logical[base: base + s])
            t = tail(col)
            if t:
                base = full_rows * s * C + col * s
                parts.append(logical[base: base + t])
        else:
            row0 = base_block // (s * C)
            for r in range(full_rows):
                data_devs, parity = self._row_devices(row0 + r)
                base = r * s * C
                if member == parity:
                    chunk = logical[base: base + s].copy()
                    for c in range(1, C):
                        chunk ^= logical[base + c * s: base + (c + 1) * s]
                    parts.append(chunk)
                else:
                    c = data_devs.index(member)
                    parts.append(logical[base + c * s: base + (c + 1) * s])
            if rem:
                data_devs, parity = self._row_devices(row0 + full_rows)
                if member != parity:
                    c = data_devs.index(member)
                    t = tail(c)
                    if t:
                        base = full_rows * s * C + c * s
                        parts.append(logical[base: base + t])
        if not parts:
            return np.empty((0, bb), np.uint8)
        return np.concatenate(parts)

    def tail_parity(self, zone_id: int) -> Optional[np.ndarray]:
        """Snapshot of the host-side tail-row parity accumulator (xor mode):
        the value the incomplete row's parity chunk WILL have once the row
        completes — what a scrub checks the tail data against. ``None`` for
        non-xor arrays and for zones whose accumulator was lost at recovery
        (``_pacc_lost``)."""
        with self._lock:
            if self.redundancy != "xor" or zone_id in self._pacc_lost:
                return None
            return self._pacc_for(zone_id).copy()

    # --------------------------------------------------------------- misc
    def flush(self) -> None:
        for dev in self.devices:
            dev.flush()

    def close(self) -> None:
        """Kept for API compatibility: member I/O rides the shared completion
        ring now, so the array holds no worker threads to release."""

    def __enter__(self) -> "StripedZoneArray":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def lba_size(self) -> int:
        return self.block_bytes

    @property
    def stats(self) -> dict:
        """Aggregate member device statistics (NVMe log-page analogue), plus
        the array-level stripe gather copies and degraded-read count."""
        agg: dict[str, int] = {}
        for dev in self.devices:
            for k, v in dev.stats.items():
                agg[k] = agg.get(k, 0) + v
        agg["bytes_copied"] = agg.get("bytes_copied", 0) + self._c_gather_bytes.value
        agg["degraded_reads"] = agg.get("degraded_reads", 0) + self._c_degraded_reads.value
        return agg

    def utilization(self) -> float:
        written = sum(self._wp)
        return written / float(self.num_zones * self.zone_blocks)
