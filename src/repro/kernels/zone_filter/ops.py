"""Public jit'd wrappers for the zone_filter kernel, including the bridge
from verified offload Programs (repro.core) to the Pallas tier."""
from __future__ import annotations

import functools
import time

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np

from repro.core.programs import CMP_OPS, OpCode, Program
from repro.kernels.zone_filter.kernel import (
    filtered_reduce_pallas,
    filtered_reduce_pallas_batched,
)

__all__ = ["zone_filter_count", "zone_reduce", "run_program_kernel",
           "run_program_kernel_batched", "kernel_program",
           "kernel_program_batched", "KERNELIZABLE_TERMINALS", "kernelizable"]

# RED_SUM over ints is NOT kernelized: TPU has no i64 accumulator and f32
# accumulation would silently lose precision vs the verifier-promised i64
# semantics — those programs fall back to the XLA JIT tier.
KERNELIZABLE_TERMINALS = frozenset(
    {OpCode.RED_COUNT, OpCode.RED_SUM, OpCode.RED_MIN, OpCode.RED_MAX})

_TERM_KIND = {
    OpCode.RED_COUNT: "count", OpCode.RED_SUM: "sum",
    OpCode.RED_MIN: "min", OpCode.RED_MAX: "max",
}


def kernelizable(program: Program) -> bool:
    term = program.terminal.op
    if term not in KERNELIZABLE_TERMINALS:
        return False
    if term == OpCode.RED_SUM and np.dtype(program.input_dtype).kind != "f":
        return False
    if any(i.op == OpCode.FIELD for i in program.insns):
        return False  # projection changes block geometry; JIT tier handles it
    return True


def _program_transform(program: Program):
    """Trace the ALU/CMP chain into a fused (vals, mask) transform."""
    def transform(x):
        mask = jnp.ones(x.shape, bool)
        for insn in program.insns[:-1]:
            op, imm = insn.op, insn.imm
            if op in CMP_OPS:
                immt = jnp.asarray(imm, x.dtype)
                mask &= {
                    OpCode.CMP_GT: x > immt, OpCode.CMP_GE: x >= immt,
                    OpCode.CMP_LT: x < immt, OpCode.CMP_LE: x <= immt,
                    OpCode.CMP_EQ: x == immt, OpCode.CMP_NE: x != immt,
                }[op]
            elif op == OpCode.ABS:
                x = jnp.abs(x)
            elif op == OpCode.NEG:
                x = -x
            else:
                immt = jnp.asarray(imm, x.dtype)
                x = {
                    OpCode.ADD: lambda: x + immt, OpCode.SUB: lambda: x - immt,
                    OpCode.MUL: lambda: x * immt, OpCode.AND: lambda: x & immt,
                    OpCode.OR: lambda: x | immt, OpCode.XOR: lambda: x ^ immt,
                    OpCode.SHL: lambda: x << imm, OpCode.SHR: lambda: x >> imm,
                    OpCode.MOD: lambda: x % immt,
                }[op]()
        return x, mask
    return transform


@functools.partial(jax.jit, static_argnames=("threshold", "interpret",
                                             "block_pages"))
def zone_filter_count(pages, threshold, *, interpret: bool = True,
                      block_pages: int = 512):
    """The paper's workload: count zone elements above threshold."""
    thr = threshold
    return filtered_reduce_pallas(
        pages, kind="count",
        transform=lambda x: (x, x > jnp.asarray(thr, x.dtype)),
        block_pages=block_pages, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("kind", "threshold", "interpret",
                                             "block_pages"))
def zone_reduce(pages, kind: str = "count", threshold=None, *,
                interpret: bool = True, block_pages: int = 512):
    if threshold is None:
        transform = None
    else:
        thr = threshold
        transform = lambda x: (x, x > jnp.asarray(thr, x.dtype))
    return filtered_reduce_pallas(pages, kind=kind, transform=transform,
                                  block_pages=block_pages, interpret=interpret)


def run_program_kernel(program: Program, pages: np.ndarray, *,
                       interpret: bool = True):
    """Execute a verified Program on the Pallas tier (the CSD 'hardware
    backend'). Caller guarantees kernelizable(program).

    Convenience entry that re-traces per call; the CSD hot path goes through
    :func:`kernel_program` so the compiled executable lands in the shared
    :class:`~repro.core.cache.CompiledProgramCache`.
    """
    if not kernelizable(program):
        raise ValueError(f"program {program.name} is not kernelizable")
    kind = _TERM_KIND[program.terminal.op]
    transform = _program_transform(program)
    fn = jax.jit(functools.partial(
        filtered_reduce_pallas, kind=kind, transform=transform,
        interpret=interpret))
    return fn(jnp.asarray(pages))


def run_program_kernel_batched(program: Program, pages: np.ndarray, *,
                               interpret: bool = True):
    """Chunk-batched Pallas execution: ``pages[n_chunks, n_pages, page_elems]``
    -> per-chunk reduced values ``[n_chunks]`` from ONE grid-batched kernel
    call (leading grid dimension over the chunk axis)."""
    if not kernelizable(program):
        raise ValueError(f"program {program.name} is not kernelizable")
    kind = _TERM_KIND[program.terminal.op]
    transform = _program_transform(program)
    fn = jax.jit(functools.partial(
        filtered_reduce_pallas_batched, kind=kind, transform=transform,
        interpret=interpret))
    return fn(jnp.asarray(pages))


def _aot_compile(run, spec):
    """AOT lower+compile with the paper's 'JIT time' measured; traced under
    64-bit mode like the XLA JIT tier so int64/float64 zone dtypes keep their
    verified semantics."""
    t0 = time.perf_counter()
    with jax.experimental.enable_x64():
        compiled = jax.jit(run).lower(spec).compile()
    return compiled, time.perf_counter() - t0


def kernel_program(program: Program, n_pages: int, page_elems: int, *,
                   interpret: bool = True):
    """Compile a verified Program to a shaped Pallas executable, returned as a
    :class:`~repro.core.vm.JittedProgram` (so the kernel tier reports compile
    time and caches exactly like the XLA JIT tier)."""
    from repro.core.vm import JittedProgram  # local: keep import DAG one-way
    if not kernelizable(program):
        raise ValueError(f"program {program.name} is not kernelizable")
    kind = _TERM_KIND[program.terminal.op]
    transform = _program_transform(program)
    run = functools.partial(filtered_reduce_pallas, kind=kind,
                            transform=transform, interpret=interpret)
    dtype = np.dtype(program.input_dtype)
    spec = jax.ShapeDtypeStruct((n_pages, page_elems), dtype)
    compiled, compile_seconds = _aot_compile(run, spec)
    return JittedProgram(compiled, compile_seconds, n_pages, page_elems, program)


def kernel_program_batched(program: Program, n_chunks: int, n_pages: int,
                           page_elems: int, *, interpret: bool = True):
    """Compile the chunk-batched Pallas kernel for a fixed
    ``[n_chunks, n_pages, page_elems]`` geometry (the scheduler's striped
    fan-out shape)."""
    from repro.core.vm import JittedProgram
    if not kernelizable(program):
        raise ValueError(f"program {program.name} is not kernelizable")
    kind = _TERM_KIND[program.terminal.op]
    transform = _program_transform(program)
    run = functools.partial(filtered_reduce_pallas_batched, kind=kind,
                            transform=transform, interpret=interpret)
    dtype = np.dtype(program.input_dtype)
    spec = jax.ShapeDtypeStruct((n_chunks, n_pages, page_elems), dtype)
    compiled, compile_seconds = _aot_compile(run, spec)
    return JittedProgram(compiled, compile_seconds, n_pages, page_elems, program)